"""The paper's §V.B classroom experiment, simulated end to end.
(Demonstrates: the discrete-event Simulator + cost model. Runs in ~10 s.)

32 heterogeneous volunteers (different speeds) open the URL; some arrive
late (async-start), some close the browser mid-run. The discrete-event
simulator drives the exact queue/dataserver protocol and reports the
runtime, per-volunteer utilization and the Fig. 7-style timeline.

Run:  PYTHONPATH=src python examples/classroom_simulation.py
"""
import pathlib
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.common import classroom_cost, paper_problem  # noqa: E402
from repro.core.simulator import Simulator, VolunteerSpec  # noqa: E402


def main():
    problem = paper_problem(reduced=True)
    rng = np.random.RandomState(0)

    specs = []
    for i in range(32):
        specs.append(VolunteerSpec(
            f"student{i:02d}",
            speed=float(rng.uniform(0.6, 1.6)),        # heterogeneous laptops
            join_time=float(rng.uniform(0, 20)),       # async-start
            # a third of the class closes the tab partway through
            leave_time=float(rng.uniform(60, 240)) if i % 3 == 0
            else float("inf")))

    sim = Simulator(problem, specs, cost=classroom_cost(problem),
                    visibility_timeout=30.0)
    res = sim.run()

    print(f"classroom run: {res.makespan / 60:.1f} min, "
          f"{res.final_version} model versions")
    print(f"tasks requeued after disconnects: {res.requeues}")
    print(f"bytes over the 'network': {res.bytes_sent / 1e6:.1f} MB")
    print("\nper-volunteer tasks (top 10):")
    top = sorted(res.tasks_by_worker.items(), key=lambda kv: -kv[1])[:10]
    for vid, n in top:
        busy = res.busy_time.get(vid, 0.0)
        print(f"  {vid}: {n:3d} tasks, {busy:6.1f}s busy "
              f"({100 * busy / res.makespan:4.1f}% of wall)")
    assert res.final_version == problem.n_versions, "training must complete"
    print("\ntraining completed despite churn — no tasks lost "
          "(paper §IV fault tolerance).")


if __name__ == "__main__":
    main()
