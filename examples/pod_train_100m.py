"""End-to-end driver: train a ~100M-parameter decoder LM for a few hundred
steps with the production SPMD train_step (the paper's map/reduce schedule
compiled: microbatch grads accumulate in a scan, one reduce applies RMSprop
and bumps the model version).
(Demonstrates: the jax_pallas production stack — sharded train_step, data
pipeline, checkpoint store. Runs ~minutes at --steps 20; tens of minutes for
the full 300 steps on one CPU.)

This runs the REAL stack — sharded train_step, data pipeline, checkpoint
store — on whatever devices exist (1 CPU here; the same code lowers to the
16x16 pod in repro.launch.dryrun).

Run:   PYTHONPATH=src python examples/pod_train_100m.py            # 300 steps
Quick: PYTHONPATH=src python examples/pod_train_100m.py --steps 20
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointStore
from repro.configs.base import ArchConfig, InputShape
from repro.data.text import TextTask, repo_corpus
from repro.distributed import steps as ST
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models.runtime import Runtime
from repro.optim import rmsprop


def config_100m(vocab: int) -> ArchConfig:
    """~100M params: 12L, d_model 640, GQA 10/5, SwiGLU — stablelm-style."""
    return ArchConfig(
        name="repro-100m", family="dense", source="this repo",
        n_layers=12, d_model=640, n_heads=10, n_kv_heads=5,
        d_ff=1792, vocab=vocab, mlp="swiglu", norm="rmsnorm",
        rope_fraction=1.0, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--micro", type=int, default=2)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    # char-level corpus = this repo's own source (the paper's self-hosting move)
    data = TextTask.build(repo_corpus(max_chars=400_000),
                          sample_len=args.seq)
    cfg = config_100m(max(data.vocab.size, 128))
    mesh = make_host_mesh()
    rt = Runtime(remat=False, attn_impl="flash", kv_chunk=64)
    shape = InputShape("ex", args.seq, args.batch, "train")
    opt = rmsprop(1e-3)
    bound = ST.bind_train(mesh, cfg, rt, opt, shape,
                          num_microbatches=args.micro)

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"model: {n / 1e6:.1f}M params, vocab {cfg.vocab}, "
          f"micro={bound['n_micro']}")
    opt_state = opt.init(params)
    store = CheckpointStore(args.ckpt, keep=2)

    def batch_at(step):
        b = data.batch(epoch=step // 64, batch=step % 64,
                       batch_size=args.batch)
        # window ids -> next-token LM tokens [B, S+1]
        starts = data.starts(step // 64, step % 64, args.batch)
        idx = starts[:, None] + np.arange(args.seq + 1)[None]
        return {"tokens": jnp.asarray(data.ids[idx], jnp.int32)}

    t0 = time.time()
    losses = []
    for step in range(args.steps):
        params, opt_state, mets = bound["step"](params, opt_state,
                                                batch_at(step))
        losses.append(float(mets["loss"]))
        if step % 25 == 0 or step == args.steps - 1:
            print(f"  step {step:4d}  loss {losses[-1]:.4f}  "
                  f"({(time.time() - t0) / (step + 1):.2f}s/step)")
        if (step + 1) % 100 == 0:
            v = (store.latest() or 0) + 1
            store.save(v, {"params": params}, meta={"step": step + 1})
            print(f"  checkpoint v{v} -> {args.ckpt}")

    assert np.isfinite(losses).all()
    k = min(20, len(losses) // 2)
    first, last = float(np.mean(losses[:k])), float(np.mean(losses[-k:]))
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({time.time() - t0:.0f}s total)")
    assert last < first, "the 100M model must be learning"


if __name__ == "__main__":
    main()
