"""Quickstart — the paper in 60 seconds. (Runs in ~1 minute on one CPU.)

Demonstrates the two headline invariances of this repro on the JSDoop
workload (2x50-cell LSTM, char-level next-character prediction on this
repo's own source code):

  1. **Worker-count/churn invariance** (paper Table 4): training through the
     volunteer runtime with the default ``policy="sync"`` — 3 workers, then
     5 workers with mid-run churn — is BIT-IDENTICAL to the sequential
     accumulated-gradient schedule.
  2. **Policy as a config axis** (PR 4): the same run under
     ``policy="staleness:2"`` (barrierless async SGD) bit-matches ITS exact
     sequential reference, ``sequential_async`` — a different consistency
     model, the same determinism guarantee.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs.paper_lstm import TrainParams
from repro.core.coordinator import Coordinator
from repro.core.mapreduce import (TrainingProblem, sequential_accumulated,
                                  sequential_async)


def bitmatch(a, b):
    return all(bool((np.asarray(x) == np.asarray(y)).all())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def main():
    # scaled-down Table 2/3 so the demo finishes in ~a minute
    tp = TrainParams(batch_size=32, examples_per_epoch=256, num_epochs=1,
                     sample_len=40, mini_batch_size=8,
                     mini_batches_to_accumulate=4)
    problem = TrainingProblem.paper_problem(tp=tp)   # corpus = this repo
    print(f"corpus vocab={problem.cfg.vocab}, "
          f"{problem.n_versions} model versions to train")

    print("\n[1] sequential (accumulated) ...")
    params_seq, _, losses = sequential_accumulated(problem)
    print(f"    loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    print("[2] 3 volunteers via QueueServer/DataServer (policy='sync') ...")
    res3 = Coordinator(problem, n_workers=3, policy="sync").run()
    print(f"    final version {res3.final_version}, "
          f"tasks/worker {res3.tasks_by_worker}")

    print("[3] 5 volunteers, two leave mid-run, one joins ...")
    churn = [(4, "leave", "w0"), (8, "leave", "w1"), (10, "join", "w7")]
    res5 = Coordinator(problem, n_workers=5, policy="sync", churn=churn).run()
    print(f"    requeues after disconnects: {res5.requeues}")

    assert bitmatch(params_seq, res3.params)
    assert bitmatch(params_seq, res5.params)
    print("All three sync-policy models are BIT-IDENTICAL — the paper's "
          "worker-count/churn invariance (Table 4).")

    print("\n[4] same workload, policy='staleness:2' (async, no barrier) ...")
    n_async = 2                                      # 2 rounds = 8 updates
    n_mb = problem.tp.mini_batches_to_accumulate
    params_ref, _, _ = sequential_async(problem, n_updates=n_async * n_mb)
    res_async = Coordinator(problem, n_workers=3, policy="staleness:2",
                            n_versions=n_async).run()
    assert bitmatch(params_ref, res_async.params)
    print(f"    {res_async.final_version} per-gradient updates committed, "
          f"bit-identical to sequential_async — the consistency model is a "
          f"config axis, not a code path.")


if __name__ == "__main__":
    main()
