"""Quickstart — the paper in 60 seconds.

Trains the JSDoop workload (2x50-cell LSTM, char-level next-character
prediction on this repo's own source code) three ways and shows that the
final model is BIT-IDENTICAL (paper Table 4):

  1. sequentially, with the accumulated map/reduce schedule,
  2. through the L1 volunteer runtime with 3 workers,
  3. through the L1 runtime with 5 workers and mid-run churn.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs.paper_lstm import TrainParams
from repro.core.coordinator import Coordinator
from repro.core.mapreduce import TrainingProblem, sequential_accumulated


def bitmatch(a, b):
    return all(bool((np.asarray(x) == np.asarray(y)).all())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def main():
    # scaled-down Table 2/3 so the demo finishes in ~a minute
    tp = TrainParams(batch_size=32, examples_per_epoch=256, num_epochs=1,
                     sample_len=40, mini_batch_size=8,
                     mini_batches_to_accumulate=4)
    problem = TrainingProblem.paper_problem(tp=tp)   # corpus = this repo
    print(f"corpus vocab={problem.cfg.vocab}, "
          f"{problem.n_versions} model versions to train")

    print("\n[1] sequential (accumulated) ...")
    params_seq, _, losses = sequential_accumulated(problem)
    print(f"    loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    print("[2] 3 volunteers via QueueServer/DataServer ...")
    res3 = Coordinator(problem, n_workers=3).run()
    print(f"    final version {res3.final_version}, "
          f"tasks/worker {res3.tasks_by_worker}")

    print("[3] 5 volunteers, two leave mid-run, one joins ...")
    churn = [(4, "leave", "w0"), (8, "leave", "w1"), (10, "join", "w7")]
    res5 = Coordinator(problem, n_workers=5, churn=churn).run()
    print(f"    requeues after disconnects: {res5.requeues}")

    assert bitmatch(params_seq, res3.params)
    assert bitmatch(params_seq, res5.params)
    print("\nAll three trained models are BIT-IDENTICAL — the paper's "
          "worker-count/churn invariance (Table 4).")


if __name__ == "__main__":
    main()
