"""Batched serving example — the decode-shape path executed for real.
(Demonstrates: prefill + cached decode through the sharded serve_step on a
reduced architecture. Runs in ~1-2 minutes on one CPU.)

Loads a (reduced) assigned architecture, prefills a batch of prompts and
decodes with the KV/SSM cache through the sharded serve_step — the same
code path the dry-run lowers for decode_32k/long_500k at pod scale.

Run:  PYTHONPATH=src python examples/serve_batch.py --arch jamba-v0.1-52b
"""
import argparse

from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="jamba-v0.1-52b")
    args = ap.parse_args()
    serve.main(["--arch", args.arch, "--requests", "6", "--batch", "2",
                "--prompt", "24", "--tokens", "12"])


if __name__ == "__main__":
    main()
