"""Render the committed BENCH_*.json perf records as the README's results
tables. Deterministic output (file order, record order), so the README can
embed it verbatim and CI can diff for drift:

  PYTHONPATH=src python scripts/bench_table.py            # print markdown
  PYTHONPATH=src python scripts/check_docs.py             # verifies no drift
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _fmt(v) -> str:
    if v is None:
        return "—"
    if isinstance(v, bool):
        return str(v).lower()
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e6:
            return f"{v / 1e6:.1f}M"
        if abs(v) >= 1e3:
            return f"{v / 1e3:.1f}k"
        return f"{v:.3g}"
    if isinstance(v, int) and abs(v) >= 1e6:
        return f"{v / 1e6:.1f}M"
    return str(v)


def _params(p: dict) -> str:
    return ", ".join(f"{k}={_fmt(v)}" for k, v in p.items())


def render() -> str:
    lines = []
    for path in sorted(ROOT.glob("BENCH_*.json")):
        records = json.loads(path.read_text())
        suite = path.stem[len("BENCH_"):]
        lines.append(f"**`{suite}`** ({len(records)} records, "
                     f"`{path.name}`)")
        lines.append("")
        lines.append("| params | makespan (s) | events | bytes |")
        lines.append("|---|---|---|---|")
        for rec in records:
            lines.append(f"| {_params(rec['params'])} "
                         f"| {_fmt(rec['makespan'])} "
                         f"| {_fmt(rec['events'])} "
                         f"| {_fmt(rec['bytes'])} |")
        lines.append("")
    return "\n".join(lines).rstrip() + "\n"


if __name__ == "__main__":
    sys.stdout.write(render())
