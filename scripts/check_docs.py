"""Docs CI leg: the README is executable documentation, so CI executes it.

Three checks (any failure exits non-zero):

1. **Quickstart blocks run green.** Every fenced ```bash block in README.md
   is executed with ``bash -euo pipefail`` from the repo root (PYTHONPATH
   pre-set), EXCEPT blocks immediately preceded by an HTML comment containing
   ``docs-ci: skip`` (the long-running proofs CI already covers elsewhere).
2. **The results tables match the committed BENCH_*.json.** The section
   between the BENCH markers must equal ``scripts/bench_table.py`` output —
   regenerate with ``python scripts/check_docs.py --write-bench`` after
   refreshing benchmark records.
3. **docs/protocol.md documents every wire message.** Each registered
   request/reply/notification type and task body must be named in the doc,
   so a new message cannot ship undocumented.

Usage:
  PYTHONPATH=src python scripts/check_docs.py              # check (CI)
  PYTHONPATH=src python scripts/check_docs.py --write-bench
"""
from __future__ import annotations

import argparse
import os
import pathlib
import re
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
README = ROOT / "README.md"
PROTOCOL_DOC = ROOT / "docs" / "protocol.md"

BENCH_BEGIN = "<!-- BENCH:BEGIN"
BENCH_END = "<!-- BENCH:END -->"

_FENCE = re.compile(
    r"(?P<prefix>(?:<!--[^\n]*-->\n)?)```bash\n(?P<body>.*?)```",
    re.DOTALL)


def bash_blocks(text: str):
    """Yield (body, skipped) per fenced bash block, in order."""
    for m in _FENCE.finditer(text):
        yield m.group("body"), "docs-ci: skip" in m.group("prefix")


def run_quickstart_blocks(text: str) -> int:
    env = dict(os.environ)
    env["PYTHONPATH"] = f"src{os.pathsep}{env['PYTHONPATH']}" \
        if env.get("PYTHONPATH") else "src"
    failures = 0
    for i, (body, skipped) in enumerate(bash_blocks(text)):
        head = body.strip().splitlines()[0] if body.strip() else "<empty>"
        if skipped:
            print(f"# block {i} skipped (docs-ci: skip): {head}")
            continue
        print(f"# block {i} running: {head}")
        proc = subprocess.run(["bash", "-euo", "pipefail", "-c", body],
                              cwd=ROOT, env=env, timeout=600)
        if proc.returncode != 0:
            failures += 1
            print(f"DOCS-CI FAIL: README bash block {i} exited "
                  f"{proc.returncode} (starts: {head})")
    return failures


def bench_section(text: str):
    start = text.find(BENCH_BEGIN)
    end = text.find(BENCH_END)
    if start < 0 or end < 0 or end < start:
        return None
    # section body = everything after the BEGIN marker's line
    body_start = text.index("\n", start) + 1
    return text[:body_start], text[body_start:end], text[end:]


def check_bench_tables(text: str, *, write: bool = False) -> int:
    sys.path.insert(0, str(ROOT / "scripts"))
    import bench_table
    want = bench_table.render()
    parts = bench_section(text)
    if parts is None:
        print("DOCS-CI FAIL: README is missing the BENCH markers")
        return 1
    head, current, tail = parts
    if current.strip() == want.strip():
        print("# results tables match the committed BENCH_*.json")
        return 0
    if write:
        README.write_text(head + want + tail)
        print("# results tables rewritten from BENCH_*.json")
        return 0
    print("DOCS-CI FAIL: README results tables drifted from BENCH_*.json — "
          "run: PYTHONPATH=src python scripts/check_docs.py --write-bench")
    return 1


def check_protocol_doc() -> int:
    # delegated to the analysis subsystem's SCHEMA-DOC check — one
    # implementation serves both this leg and `python -m repro.analysis`,
    # so the two can't drift
    from repro.analysis import schema
    violations = schema.check_doc(PROTOCOL_DOC)
    if violations:
        for v in violations:
            print(f"DOCS-CI FAIL: {v}")
        return 1
    print(f"# docs/protocol.md covers all {len(schema.registered_types())} "
          f"wire types")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--write-bench", action="store_true",
                    help="rewrite the README results section from "
                         "BENCH_*.json instead of failing on drift")
    ap.add_argument("--no-exec", action="store_true",
                    help="skip executing the quickstart blocks")
    args = ap.parse_args(argv)
    text = README.read_text()
    problems = 0
    problems += check_bench_tables(text, write=args.write_bench)
    problems += check_protocol_doc()
    if not args.no_exec:
        problems += run_quickstart_blocks(README.read_text())
    print("# OK: docs are live" if problems == 0
          else f"# docs check: {problems} problem(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
