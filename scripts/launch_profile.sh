#!/usr/bin/env bash
# Launch profile for perf-measuring legs: pins the JAX/XLA host environment
# so benchmark numbers are comparable across runs and machines.
#
#   scripts/launch_profile.sh python -m benchmarks.applier_bench --quick
#
# - one XLA host device (the benches measure single-server dispatch, and a
#   multi-device host partitions the BLAS threadpool unpredictably);
#   override with LAUNCH_DEVICES=N for sharding experiments
# - f32 default dtype (the wire format and every reference chain is f32;
#   an x64 default would silently double apply costs)
# - tcmalloc via LD_PRELOAD when present (steadier allocation tails than
#   glibc malloc on the 1-core CI box); silently skipped when absent
set -euo pipefail

DEVICES="${LAUNCH_DEVICES:-1}"
export XLA_FLAGS="--xla_force_host_platform_device_count=${DEVICES}${XLA_FLAGS:+ $XLA_FLAGS}"
export JAX_DEFAULT_DTYPE_BITS="${JAX_DEFAULT_DTYPE_BITS:-32}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

for lib in /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
           /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
           /usr/lib/libtcmalloc_minimal.so; do
  if [ -e "$lib" ]; then
    export LD_PRELOAD="$lib${LD_PRELOAD:+:$LD_PRELOAD}"
    break
  fi
done

exec "$@"
