#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): the full test suite, fail-fast.
# Optional dev deps (requirements-dev.txt) improve coverage but are not
# required — the suite is green on a bare container with jax+numpy+msgpack.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# static analysis first — cheapest leg, fails fastest (ISSUE 6). ruff and
# mypy (version-pinned in requirements-dev.txt) are REQUIRED legs in CI:
# when $CI is set their absence is a failure, not a skip. Locally they stay
# optional extras — skipped with a pointer at the install command.
require_or_skip() {
  local tool="$1"
  if command -v "$tool" >/dev/null 2>&1; then
    return 0
  fi
  if [ -n "${CI:-}" ]; then
    echo "# $tool is a required CI leg but is not installed" \
         "(pip install -r requirements-dev.txt)" >&2
    exit 1
  fi
  echo "# $tool not installed — skipping (pip install -r requirements-dev.txt)"
  return 1
}
if require_or_skip ruff; then
  ruff check src tests benchmarks scripts
fi
if require_or_skip mypy; then
  mypy src/repro
fi
# the repo-native pass is NOT optional: layering linter, lock-order race
# detector, wire-schema exhaustiveness checker (strict = stale ignores fail)
python -m repro.analysis --strict

# bounded model checking (ISSUE 8): explore each CI policy's fault world —
# message reordering, drops/dups, lease expiry, crash/rejoin, leave,
# heartbeat/release races — against the full invariant catalog; any
# violation prints a minimized, replayable counterexample. Three legs, one
# per aggregation policy, each within a <20 s budget (<60 s total).
python -m repro.analysis --only mc --mc-policy sync
python -m repro.analysis --only mc --mc-policy staleness:1
python -m repro.analysis --only mc --mc-policy local:2

python -m pytest -x -q "$@"

# smoke the volunteer-scaling benchmark (1k volunteers, ~5 s): proves the
# event-driven coordination win is still >=10x at identical semantics
python benchmarks/volunteer_scaling.py --quick

# 5-seed chaos smoke (<30 s): for fixed seeds x {churn, reshard, mixed,
# snapshot, gateway} schedules, in both event and poll modes — including a
# tight-visibility leg with live lease expiry, a wire-transport leg with
# seeded notification faults (dropped/duplicated/delayed Wake and
# VersionReady deliveries), AND the gateway-kill contract (ISSUE 10): each
# gateway_kill replays the op journal into scratch servers, asserts the
# replay bit-matches the live durable state, and a schedule with kills
# substituted by plain expire sweeps must yield a bit-identical SimResult —
# a sharded federation's SimResult must bit-match the single-server
# SimResult throughout (metamorphic contracts of ISSUEs 2, 3 and 10)
python -m repro.core.chaos --seeds 5

# gateway durability smoke (<90 s), 6 legs (ISSUEs 3 + 5 + 7): (1) an
# out-of-process volunteer over a real TCP socket matches the in-process run;
# (2) a volunteer process kill -9'd mid-task has its lease requeued by the
# WALL-CLOCK sweeper and survivors finish; (3) the server itself is kill -9'd
# mid-run, restarts from its latest snapshot, and the run resumes to the
# uninterrupted final version; (4) a barrierless policy commits through the
# server-side applier — the thin client sends zero PublishModel frames;
# (5) a WebSocket-framed volunteer process and a native-TCP volunteer share
# one gateway port and finish the same run bit-identically; (6) the
# repro.core.browser thin client (WS framing, zero model pushes, asserted)
# completes a barrierless run alongside a TCP volunteer
python -m repro.core.gateway --smoke

# the same 6 legs under runtime lock/invariant instrumentation (ISSUE 6):
# MonitoredLocks record actual acquisition orders across every gateway
# process (the env var rides into the spawned servers/volunteers) and the
# run fails on any LOCK-ORDER inversion, LOCK-BLOCK (blocking call under
# the dispatch lock), or PARKED-HOLDER (PR 5's step-aside deadlock shape)
ANALYSIS_INSTRUMENT=1 python -m repro.core.gateway --smoke

# multi-gateway failover smoke (ISSUE 10): 3 real gateway PROCESSES share a
# consistent-hash ring; the MODEL-owning member is SIGKILLed mid-run; the
# deterministic adopter replays the victim's op log, volunteers fail over
# to surviving ports, and the run completes at the reference version —
# once plain, once under runtime lock/invariant instrumentation (the
# forwarding + failover paths take locks the single-gateway legs never do)
python -m repro.core.gateway --smoke-cluster
ANALYSIS_INSTRUMENT=1 python -m repro.core.gateway --smoke-cluster

# K-gateway perf surface: throughput at K=1/2/3 through the full
# wire + fsync path, and the kill -9 failover gap measured by a probe
# through a survivor (the committed BENCH_multi_gateway.json records)
python -m benchmarks.multi_gateway --quick

# elastic rebalance smoke: every shard join/leave migrates <= 1.5/K of queue
# names, conserves all live state, and keeps per-queue invariants
python benchmarks/rebalance.py --quick

# 3-policy aggregation matrix (ISSUE 4): SyncBSP / BoundedStaleness(s=2) /
# LocalSteps(k=4) on the reduced real problem, in-process + wire — SyncBSP
# must bit-match sequential_accumulated, the async policies their own
# sequential references, over BOTH transports
python -m repro.core.aggregation --smoke

# chaos metamorphic contract per async policy (gateway-kill journal replay
# included via the schedule families above): a seeded fault schedule on a
# sharded federation still bit-matches single-server with no reduce barrier
python -m repro.core.chaos --seeds 2 --policy staleness:2
python -m repro.core.chaos --seeds 2 --policy local:4

# staleness benchmark smoke: BoundedStaleness must strictly beat SyncBSP's
# makespan under a straggler-heavy volunteer pool (final-loss deltas
# printed), and the server-side applier must reduce bytes per async update
python benchmarks/staleness.py --quick

# browser-scale smoke (ISSUE 7, capped: 100k devices, 30 min slice): session
# traces with diurnal churn + heavy-tailed sessions must complete the run at
# every fleet size with makespan flat per policy, and diurnal amplitude must
# leave a measurable availability signature (the committed 1M-device records
# in BENCH_browser_scale.json come from the uncapped --flagship run)
python benchmarks/browser_scale.py --quick

# batched server-applier smoke (ISSUE 9): real-JAX applies through the
# drained SubmitUpdate path must bit-match sequential_async at every batch
# size (asserted inside the bench) while measuring updates/sec single vs
# batched; runs under the pinned launch profile so numbers are comparable
scripts/launch_profile.sh python -m benchmarks.applier_bench --quick

# Pallas kernel perf surface at CI-scale shapes + the roofline derivation
# (structural interpret-mode numbers; the committed BENCH_kernels.json
# records come from the full shapes via `benchmarks.run --full`)
scripts/launch_profile.sh python -m benchmarks.kernel_bench --quick
python -m benchmarks.roofline

# docs leg (ISSUE 5): the README is executable documentation — run every
# quickstart bash block, fail if the results tables drifted from the
# committed BENCH_*.json, and fail if docs/protocol.md misses a wire type
python scripts/check_docs.py

# committed perf records must match the BENCH_<name>.json schema
python -m benchmarks.run --check
