#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): the full test suite, fail-fast.
# Optional dev deps (requirements-dev.txt) improve coverage but are not
# required — the suite is green on a bare container with jax+numpy+msgpack.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q "$@"

# smoke the volunteer-scaling benchmark (1k volunteers, ~5 s): proves the
# event-driven coordination win is still >=10x at identical semantics
python benchmarks/volunteer_scaling.py --quick
