"""whisper-base [audio] — encoder-decoder transformer [arXiv:2212.04356].

6L enc + 6L dec, d_model=512, 8 heads (MHA, kv=8), d_ff=2048, vocab=51865.
The mel-spectrogram + conv frontend is STUBBED per the assignment: input_specs()
supplies precomputed frame embeddings [B, 1500, 512] (30 s of audio at 50 Hz).
"""
from repro.configs.base import ArchConfig, reduced

CONFIG = ArchConfig(
    name="whisper-base",
    family="encdec",
    source="arXiv:2212.04356 (Whisper)",
    n_layers=6,              # decoder layers
    n_encoder_layers=6,
    encoder_seq=1500,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    mlp="gelu",
    norm="layernorm",
    qkv_bias=True,           # whisper uses biases on q/v (we apply to all qkv)
    rope_fraction=0.0,       # whisper uses learned/sinusoidal positions, not RoPE
    tie_embeddings=True,
    notes="conv+mel frontend stubbed; sinusoidal positions; cross-attention decoder",
)


def smoke() -> ArchConfig:
    return reduced(CONFIG)
