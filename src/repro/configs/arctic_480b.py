"""arctic-480b [moe] — dense-MoE hybrid residual [hf:Snowflake/snowflake-arctic-base].

35L, d_model=7168, 56 heads (GQA kv=8), dense d_ff=4864 residual in PARALLEL with a
128-expert top-2 MoE (expert d_ff=4864) on every layer, vocab=32000.
"""
from repro.configs.base import ArchConfig, MoEConfig, reduced

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    source="hf:Snowflake/snowflake-arctic-base",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,               # dense residual branch width
    vocab=32000,
    mlp="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(num_experts=128, top_k=2, d_expert=4864, dense_residual=True,
                  every_k_layers=1),
    notes="dense FFN + 128e top-2 MoE summed per layer (Arctic dense-MoE hybrid)",
)


def smoke() -> ArchConfig:
    return reduced(CONFIG)
