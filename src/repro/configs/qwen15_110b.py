"""qwen1.5-110b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B family scaled].

80L, d_model=8192, 64 heads (GQA kv=8), d_ff=49152, vocab=152064, SwiGLU, QKV bias.
"""
from repro.configs.base import ArchConfig, reduced

CONFIG = ArchConfig(
    name="qwen1.5-110b",
    family="dense",
    source="hf:Qwen/Qwen1.5-110B (card); bias convention per Qwen1.5 series",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab=152064,
    mlp="swiglu",
    norm="rmsnorm",
    qkv_bias=True,
    sliding_window=8192,
    notes="QKV bias; GQA kv=8",
)


def smoke():
    return reduced(CONFIG)
