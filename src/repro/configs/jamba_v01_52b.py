"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave with MoE [arXiv:2403.19887].

32L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab=65536, MoE 16 experts
top-2. Repeating 8-layer block: attention at in-block index 4, MoE every 2nd layer.
"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, HybridConfig, reduced

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    source="arXiv:2403.19887 (Jamba)",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    mlp="swiglu",
    norm="rmsnorm",
    rope_fraction=0.0,       # Jamba uses no positional encoding (Mamba carries order)
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=14336, every_k_layers=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    hybrid=HybridConfig(period=8, attn_index=4, moe_every=2),
    notes="1 attn per 8 layers; MoE on odd layers; Mamba-1 mixer elsewhere",
)


def smoke() -> ArchConfig:
    return reduced(CONFIG)
