"""stablelm-1.6b [dense] — [hf:stabilityai/stablelm-2-1_6b].

24L, d_model=2048, 32 heads (MHA kv=32), d_ff=5632, vocab=100352.
LayerNorm, partial RoPE (25% of head_dim), SwiGLU MLP.
"""
from repro.configs.base import ArchConfig, reduced

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab=100352,
    mlp="swiglu",
    norm="layernorm",
    rope_fraction=0.25,
    sliding_window=8192,      # sub-quadratic variant used for long_500k decode
    notes="MHA; partial rotary 25%; LayerNorm",
)


def smoke():
    return reduced(CONFIG)
