"""The paper's own workload: 2x50-cell stacked LSTM char model (JSDoop §V.A).

Training parameters reproduce Table 2/3 exactly:
batch 128 = 16 mini-batches of 8; 2048 examples/epoch; 5 epochs; lr 0.1; RMSprop;
sample length 40; categorical cross-entropy.
"""
from dataclasses import dataclass

from repro.configs.base import ArchConfig, reduced

CONFIG = ArchConfig(
    name="paper-lstm",
    family="rnn",
    source="JSDoop (IEEE Access 2019) §V.A, Tables 2-3",
    n_layers=2,
    d_model=50,               # LSTM cells per layer
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=0,                  # set from the corpus at runtime
    norm="layernorm",
    dtype="float32",
    notes="2 stacked LSTM layers of 50 cells + dense softmax head",
)


@dataclass(frozen=True)
class TrainParams:
    """Paper Table 2 + Table 3."""
    batch_size: int = 128
    examples_per_epoch: int = 2048
    learning_rate: float = 0.1
    num_epochs: int = 5
    sample_len: int = 40
    mini_batch_size: int = 8
    mini_batches_to_accumulate: int = 16

    @property
    def batches_per_epoch(self) -> int:
        return self.examples_per_epoch // self.batch_size  # 16

    def __post_init__(self):
        assert self.mini_batch_size * self.mini_batches_to_accumulate == self.batch_size


PAPER_PARAMS = TrainParams()


def smoke():
    return reduced(CONFIG, d_model=16, vocab=64)
