"""falcon-mamba-7b [ssm] — attention-free Mamba-1 [arXiv:2410.05355].

64L, d_model=4096, d_inner=8192 (expand=2), d_state=16, d_conv=4, vocab=65024.
No attention anywhere; decode state is O(1) — long_500k is its native regime.
"""
from repro.configs.base import ArchConfig, SSMConfig, reduced

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    source="arXiv:2410.05355 (Falcon Mamba)",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,                   # Mamba block subsumes the MLP
    vocab=65024,
    norm="rmsnorm",
    rope_fraction=0.0,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    notes="pure Mamba-1; RMSNorm; tied embeddings off",
)


def smoke():
    return reduced(CONFIG)
