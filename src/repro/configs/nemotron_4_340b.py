"""nemotron-4-340b [dense] — [arXiv:2402.16819 / 2406.11704].

96L, d_model=18432, 96 heads (GQA kv=8), d_ff=73728, vocab=256000, squared-ReLU.
The memory-pressure stress case of the assignment.
"""
from repro.configs.base import ArchConfig, reduced

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    source="arXiv:2402.16819 (Nemotron-4)",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab=256000,
    mlp="squared_relu",
    norm="layernorm",
    rope_fraction=0.5,
    sliding_window=8192,
    notes="squared-ReLU, no gating; largest assigned dense model",
)


def smoke():
    return reduced(CONFIG)
