"""deepseek-moe-16b [moe] — fine-grained expert segmentation [arXiv:2401.06066].

28L, d_model=2048, 16 heads (MHA kv=16), expert d_ff=1408, vocab=102400,
2 shared experts + 64 routed experts top-6.
"""
from repro.configs.base import ArchConfig, MoEConfig, reduced

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    source="arXiv:2401.06066 (DeepSeekMoE)",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,               # per-expert width (fine-grained)
    vocab=102400,
    mlp="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, d_expert=1408,
                  every_k_layers=1),
    notes="2 shared + 64 routed top-6 fine-grained experts; first layer dense in the "
          "original model — we apply MoE on all layers for uniform scan",
)


def smoke():
    return reduced(CONFIG)
