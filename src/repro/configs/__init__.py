"""Config registry: ``get(name)`` / ``get_smoke(name)`` / ``ARCH_IDS``."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ArchConfig, InputShape, INPUT_SHAPES, reduced  # noqa: F401

# assigned architecture id -> module name
_MODULES: Dict[str, str] = {
    "whisper-base": "whisper_base",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "arctic-480b": "arctic_480b",
    "stablelm-1.6b": "stablelm_1_6b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "minitron-4b": "minitron_4b",
    "qwen1.5-110b": "qwen15_110b",
    "nemotron-4-340b": "nemotron_4_340b",
    "internvl2-1b": "internvl2_1b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    # the paper's own workload
    "paper-lstm": "paper_lstm",
}

ARCH_IDS = [k for k in _MODULES if k != "paper-lstm"]


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown architecture {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ArchConfig:
    return _module(name).smoke()


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]
