"""Architecture / input-shape configuration system.

Every assigned architecture gets one module ``repro/configs/<id>.py`` exporting
``CONFIG`` (the exact published configuration) and ``smoke()`` (a reduced variant
of the same family: <=2 layers, d_model<=512, <=4 experts) used by CPU smoke tests.

``ArchConfig`` is a frozen dataclass so configs are hashable (usable as jit static
args) and impossible to mutate accidentally after registry lookup.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Input shapes (assigned; fixed across architectures)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0          # routed experts (0 = dense model)
    top_k: int = 0
    num_shared: int = 0           # always-on shared experts (DeepSeek-MoE)
    d_expert: int = 0             # per-expert hidden size
    dense_residual: bool = False  # Arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    every_k_layers: int = 1       # MoE applied on layers where (i % k == k-1)
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2               # d_inner = expand * d_model
    dt_rank: int = 0              # 0 => ceil(d_model/16)
    chunk: int = 128              # chunked associative-scan block length


@dataclass(frozen=True)
class HybridConfig:
    period: int = 8               # repeating block length (Jamba: 8)
    attn_index: int = 4           # which layer inside the period is attention
    moe_every: int = 2            # MoE on layers where (i % moe_every == 1)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm | rnn
    source: str                   # citation
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 => d_model // n_heads
    # block flavour
    mlp: str = "swiglu"           # swiglu | gelu | squared_relu
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    qkv_bias: bool = False
    rope_fraction: float = 1.0    # fraction of head_dim that is rotated
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    sliding_window: int = 0       # 0 = full attention; >0 = window (decode/long ctx)
    # sub-configs
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    hybrid: HybridConfig = field(default_factory=HybridConfig)
    # enc-dec (whisper)
    n_encoder_layers: int = 0
    encoder_seq: int = 0          # fixed frame count supplied by the (stubbed) frontend
    # vlm
    vision_prefix: int = 0        # patch-embedding prefix tokens from stubbed ViT
    # numerics
    dtype: str = "bfloat16"
    # embedding-table padding (0 = published size). Padding the vocab to a
    # multiple of the TP axis lets embed/unembed shard on "model" instead of
    # replicating + all-reducing full logits — a §Perf optimization. The
    # padded logit tail is masked to -inf in the loss, so semantics are
    # identical to the published vocab.
    vocab_pad_to: int = 0
    # misc notes for DESIGN/EXPERIMENTS
    notes: str = ""

    # -- derived -----------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        if not self.vocab_pad_to:
            return self.vocab
        p = self.vocab_pad_to
        return -(-self.vocab // p) * p

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def layer_kind(self, i: int) -> str:
        """'attn' | 'ssm' for mixer of layer i (hybrid interleaving)."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid":
            return "attn" if (i % self.hybrid.period) == self.hybrid.attn_index else "ssm"
        return "attn"

    def layer_is_moe(self, i: int) -> bool:
        if self.moe.num_experts == 0:
            return False
        k = self.moe.every_k_layers
        if self.family == "hybrid":
            k = self.hybrid.moe_every
        return (i % k) == (k - 1)

    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model

    def dt_rank(self) -> int:
        r = self.ssm.dt_rank
        return r if r else -(-self.d_model // 16)

    # -- analytics (used by roofline + simulator cost model) ----------------
    def param_count(self) -> int:
        """Exact parameter count of the model this config instantiates."""
        from repro.models.model import param_count  # local import: avoid cycle
        return param_count(self)

    def active_param_count(self) -> int:
        from repro.models.model import param_count
        return param_count(self, active_only=True)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Shrink a config to a smoke-test variant of the same family."""
    d_model = min(cfg.d_model, 128)
    n_heads = min(cfg.n_heads, 4)
    n_kv = min(cfg.n_kv_heads, n_heads)
    # keep GQA structure when the full config has it
    if cfg.n_kv_heads < cfg.n_heads:
        n_kv = max(1, n_heads // 2)
    moe = cfg.moe
    if moe.num_experts:
        moe = dataclasses.replace(
            moe, num_experts=min(moe.num_experts, 4),
            top_k=min(moe.top_k, 2), num_shared=min(moe.num_shared, 1),
            d_expert=min(moe.d_expert, 64) if moe.d_expert else 0)
    hybrid = cfg.hybrid
    n_layers = min(cfg.n_layers, 2)
    if cfg.family == "hybrid":
        # keep one attn + one ssm layer in the reduced block
        hybrid = dataclasses.replace(hybrid, period=2, attn_index=1, moe_every=2)
    kw = dict(
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_ff=min(cfg.d_ff, 256),
        vocab=min(cfg.vocab, 512),
        head_dim=0,
        moe=moe,
        hybrid=hybrid,
        ssm=dataclasses.replace(cfg.ssm, chunk=16),
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        encoder_seq=min(cfg.encoder_seq, 32) if cfg.encoder_seq else 0,
        vision_prefix=min(cfg.vision_prefix, 8) if cfg.vision_prefix else 0,
        dtype="float32",
    )
    kw.update(overrides)
    return cfg.replace(**kw)
