"""minitron-4b [dense] — pruned Nemotron [arXiv:2407.14679].

32L, d_model=3072, 24 heads (GQA kv=8), d_ff=9216, vocab=256000, squared-ReLU MLP.
"""
from repro.configs.base import ArchConfig, reduced

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    source="arXiv:2407.14679 (Minitron)",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=9216,
    vocab=256000,
    mlp="squared_relu",
    norm="layernorm",
    rope_fraction=0.5,
    sliding_window=8192,
    notes="Nemotron family: squared-ReLU, partial RoPE, huge vocab",
)


def smoke():
    return reduced(CONFIG)
