"""internvl2-1b [vlm] — InternViT + Qwen2-0.5B-like LM backbone [arXiv:2404.16821].

LM backbone: 24L, d_model=896, 14 heads (GQA kv=2), d_ff=4864, vocab=151655.
The InternViT vision encoder + MLP projector are STUBBED per the assignment:
input_specs() supplies 256 projected patch embeddings [B, 256, 896] prepended to
the token sequence.
"""
from repro.configs.base import ArchConfig, reduced

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    source="arXiv:2404.16821 (InternVL2); LM = Qwen2-0.5B backbone",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    mlp="swiglu",
    norm="rmsnorm",
    qkv_bias=True,
    vision_prefix=256,
    sliding_window=8192,
    notes="ViT frontend stubbed -> 256 patch embeddings prefix; GQA kv=2",
)


def smoke():
    return reduced(CONFIG)
