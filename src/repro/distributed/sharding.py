"""Per-tensor sharding policy for the production meshes.

Axes: single-pod ``("data", "model")`` = (16, 16); multi-pod adds a leading
``"pod"`` axis = (2, 16, 16).

Policy (DESIGN.md §5), applied leaf-wise with divisibility-checked fallback
chains — a proposed axis is used only when the dim divides the axis size,
otherwise the next candidate is tried, ending at replication:

- tensor parallel ("model"): attention q/o heads, kv heads (falling back to
  head_dim for narrow-head archs like whisper), FFN hidden, MoE expert dim,
  Mamba d_inner, vocab for embed/unembed.
- FSDP ("data"): the largest still-unsharded dim of every weight >= _FSDP_MIN
  elements (ZeRO-3: all-gather at use, reduce-scatter of grads — this is what
  turns the paper's DataServer "one shared model" into a distributed one).
- batch: leading dim of every input -> ("pod", "data") when divisible.
- decode caches: batch -> data when divisible; the KV sequence dim -> "model"
  (sequence-parallel flash-decode); for global_batch=1 (long_500k) the
  sequence dim takes every axis instead.

Nothing here allocates; the policy maps ShapeDtypeStructs / abstract pytrees
to PartitionSpecs, and ``NamedSharding`` binding happens at jit boundaries.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# weights smaller than this stay replicated (norm scales, biases): the
# all-gather latency would cost more than the memory saved.
_FSDP_MIN = 1 << 16

# parameter pytrees whose leading dim is the lax.scan unit axis — never shard
# it (scan iterates over it; sharding it would serialize into dynamic-slices).
_STACKED_ROOTS = ("blocks", "encoder", "decoder")


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Axis-name view of a mesh + the knobs the perf loop flips."""
    axis_names: Tuple[str, ...]
    axis_sizes: Tuple[int, ...]
    fsdp_axes: Tuple[str, ...] = ("data",)   # ZeRO-3 domain
    tp_axis: str = "model"
    seq_parallel: bool = False               # activations seq -> model at unit bounds
    grad_accum_dtype: str = "float32"        # bf16 halves the accumulator (§Perf)
    attn_hd_fallback: bool = True            # narrow-head archs: shard head_dim
                                             # when heads don't divide TP. False
                                             # replicates qkv instead (§Perf: hd
                                             # is a CONTRACTING dim in QK^T, so
                                             # sharding it all-reduces the score
                                             # tensors every layer)

    @classmethod
    def for_mesh(cls, mesh: Mesh, **kw) -> "ShardingPolicy":
        return cls(tuple(mesh.axis_names), tuple(mesh.devices.shape), **kw)

    def size(self, name) -> int:
        if isinstance(name, (tuple, list)):
            return int(np.prod([self.size(n) for n in name]))
        if name is None:
            return 1
        return self.axis_sizes[self.axis_names.index(name)]

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        """Data-parallel axes for the batch dim (pod included when present)."""
        return tuple(a for a in self.axis_names if a in ("pod", "data"))

    @property
    def fsdp_size(self) -> int:
        return self.size(tuple(self.fsdp_axes))

    @property
    def tp_size(self) -> int:
        return self.size(self.tp_axis)

    @property
    def n_devices(self) -> int:
        return int(np.prod(self.axis_sizes))


# ---------------------------------------------------------------------------
# tensor-parallel dim selection (fallback chains)
# ---------------------------------------------------------------------------

def _tp_candidates(path: Tuple[str, ...], shape: Tuple[int, ...],
                   hd_fallback: bool = True):
    """Ordered candidate dims (negative indices) to place on the TP axis."""
    name = path[-1]
    parent = path[-2] if len(path) > 1 else ""
    if parent in ("attn", "cross"):
        hd = (-1,) if hd_fallback else ()
        if name == "wq":
            return (-2,) + hd        # heads, then (optionally) head_dim
        if name in ("wk", "wv"):
            return (-2,) + hd        # kv heads (GQA may not divide)
        if name == "wo":
            return (-3,) + ((-2,) if hd_fallback else ())
        if name in ("bq", "bk", "bv"):
            return (-2,) + hd
    if parent in ("mlp", "shared"):
        return {"wi": (-1,), "wg": (-1,), "wo": (-2,)}.get(name, ())
    if parent == "experts":
        return (-3,)                 # the expert dim => expert parallelism
    if parent == "ssm":
        return {"in_proj": (-1,), "conv_w": (-1,), "conv_b": (-1,),
                "x_proj": (-2,), "dt_proj": (-1,), "dt_bias": (-1,),
                "A_log": (-2,), "Dskip": (-1,), "out_proj": (-2,)}.get(name, ())
    if name == "embed":
        return (0, 1)                # vocab, then d_model
    if name == "unembed":
        return (-1, 0)               # vocab, then d_model
    if parent == "head":             # lstm softmax head
        return (-1,) if name == "w" else ()
    if name == "kernel":             # lstm gate kernel [(d_in+H), 4H]
        return (-1,)
    return ()


def _leaf_size(shape) -> int:
    return int(np.prod(shape)) if shape else 1


def spec_for_param(path: Tuple[str, ...], shape: Tuple[int, ...],
                   policy: ShardingPolicy) -> P:
    """PartitionSpec for one weight leaf."""
    ndim = len(shape)
    assign: Dict[int, Any] = {}

    # 1. tensor parallel
    if policy.tp_axis in policy.axis_names:
        tp = policy.size(policy.tp_axis)
        for cand in _tp_candidates(path, shape, policy.attn_hd_fallback):
            d = cand % ndim if ndim else 0
            if ndim and shape[d] % tp == 0 and shape[d] >= tp:
                assign[d] = policy.tp_axis
                break

    # 2. FSDP over the largest remaining dim
    if policy.fsdp_axes and _leaf_size(shape) >= _FSDP_MIN:
        fs = policy.fsdp_size
        skip0 = path and path[0] in _STACKED_ROOTS
        cands = [d for d in range(ndim)
                 if d not in assign and not (skip0 and d == 0)]
        cands.sort(key=lambda d: -shape[d])
        for d in cands:
            if shape[d] % fs == 0 and shape[d] >= fs:
                ax = policy.fsdp_axes
                assign[d] = ax[0] if len(ax) == 1 else tuple(ax)
                break

    return P(*[assign.get(d) for d in range(ndim)])


def param_specs(params_shape: Any, policy: ShardingPolicy) -> Any:
    """Map a params pytree (arrays or ShapeDtypeStructs) to PartitionSpecs."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        keys = tuple(getattr(k, "key", getattr(k, "idx", str(k))) for k in path)
        keys = tuple(str(k) for k in keys)
        specs.append(spec_for_param(keys, tuple(leaf.shape), policy))
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_state_specs(opt_state: Any, pspecs: Any) -> Any:
    """Optimizer slots mirror their weight's spec; scalars replicate.

    Works for any of our optimizers: slots live under keys ('ms','mu','m','v')
    with the same tree structure as params; 'step' is a scalar.
    """
    out = {}
    for k, v in opt_state.items():
        if k == "step":
            out[k] = P()
        else:
            out[k] = pspecs
    return out


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def batch_specs(batch_shape: Dict[str, Any], policy: ShardingPolicy) -> Any:
    """Shard dim 0 (global batch) of every input over the batch axes."""
    bp = policy.batch_axes

    def spec(leaf):
        b = leaf.shape[0] if leaf.shape else 1
        if b % policy.size(bp) == 0:
            return P(bp, *([None] * (len(leaf.shape) - 1)))
        return P(*([None] * len(leaf.shape)))

    return jax.tree.map(spec, batch_shape)


def cache_specs(cache_shape: Any, policy: ShardingPolicy) -> Any:
    """Decode-cache policy (DESIGN §5).

    Leaves (stacked over units at dim 0):
      k/v   [U, B, Smax, Kv, hd]   — seq-parallel flash-decode
      ck/cv [U, B, Se,  Kv, hd]    — encdec cross kv (Se=1500: replicated)
      conv  [U, B, K-1, Di]        — mamba conv window
      h     [U, B, Di,  N]         — mamba state
      pos   scalar
    """
    bp = policy.batch_axes
    bp_sz = policy.size(bp)
    tp = policy.tp_axis

    def spec(path, leaf):
        keys = [str(getattr(k, "key", k)) for k in path]
        name = keys[-1] if keys else ""
        shape = leaf.shape
        if not shape:
            return P()
        if name in ("k", "v"):
            U, B, S = shape[0], shape[1], shape[2]
            if B % bp_sz == 0:
                s_ax = tp if S % policy.size(tp) == 0 else None
                return P(None, bp, s_ax, None, None)
            # long-context single-request: spread the cache over everything
            all_ax = tuple(policy.axis_names)
            if S % policy.size(all_ax) == 0:
                return P(None, None, all_ax, None, None)
            return P(None, None, None, None, None)
        if name in ("ck", "cv"):
            return P(None, bp if shape[1] % bp_sz == 0 else None,
                     None, None, None)
        if name == "conv":
            di_ax = tp if shape[-1] % policy.size(tp) == 0 else None
            return P(None, bp if shape[1] % bp_sz == 0 else None, None, di_ax)
        if name == "h":
            di_ax = tp if shape[-2] % policy.size(tp) == 0 else None
            return P(None, bp if shape[1] % bp_sz == 0 else None, di_ax, None)
        return P(*([None] * len(shape)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat])


# ---------------------------------------------------------------------------
# binding helpers
# ---------------------------------------------------------------------------

def named(mesh: Mesh, specs: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def activation_spec(policy: ShardingPolicy) -> Optional[P]:
    """Per-unit boundary constraint for activations [B, S, D] (seq parallel)."""
    if not policy.seq_parallel:
        return None
    return P(policy.batch_axes, policy.tp_axis, None)
