"""L2 — the paper's queue-scheduled map/reduce schedule as compiled SPMD.

``sharding``   per-tensor PartitionSpec policy (divisibility-checked fallbacks)
``steps``      train_step (map = microbatch grad in a scan; reduce = the single
               collective + optimizer apply), prefill_step, decode_step
``hierarchy``  shard_map two-stage (intra-pod, inter-pod) gradient reduction —
               the TPU form of JSDoop's "multiple QueueServers" load balancing
"""
from repro.distributed.sharding import (  # noqa: F401
    ShardingPolicy, batch_specs, cache_specs, param_specs, opt_state_specs,
)
from repro.distributed.steps import (  # noqa: F401
    make_train_step, make_prefill_step, make_decode_step,
)
