"""Compiled SPMD steps — the paper's map/reduce schedule in pjit form.

``train_step`` is JSDoop's Fig. 3 as one XLA program:

  map task    -> one microbatch gradient inside a ``lax.scan`` accumulation
                 loop (the MapResultsQueue is the fp32 accumulator),
  reduce task -> the single cross-replica gradient mean + optimizer apply
                 (XLA inserts the reduce-scatter/all-reduce over the data/pod
                 axes), publishing "model version v+1" = the returned params.

The semantics match the L1 runtime exactly: weights are not updated until all
microbatch gradients of the global batch are accumulated, so the trained model
is invariant to how many devices ("volunteers") computed it — paper Table 4.

``decode_step``/``prefill_step`` are the serving-side equivalents used by the
decode input shapes.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model as M
from repro.models.runtime import Runtime
from repro.distributed import sharding as SH


def _microbatch_count(shape, policy: SH.ShardingPolicy,
                      requested: int = 0) -> int:
    """Paper Table 3 wants 16 accumulation steps per batch; on a mesh the
    microbatch must still tile the per-device batch, so we take the largest
    feasible count <= requested (default 16)."""
    want = requested or 16
    dp = policy.size(policy.batch_axes)
    per_device = max(shape.global_batch // dp, 1)
    n = min(want, per_device)
    while per_device % n:
        n -= 1
    return max(n, 1)


def make_train_step(cfg, rt: Runtime, optimizer, shape, policy: SH.ShardingPolicy,
                    *, num_microbatches: int = 0):
    """Returns (train_step, n_micro). train_step(params, opt_state, batch) ->
    (params, opt_state, metrics)."""
    n_micro = _microbatch_count(shape, policy, num_microbatches)
    acc_dt = jnp.dtype(policy.grad_accum_dtype)

    if policy.seq_parallel and rt.act_spec is None:
        import dataclasses
        rt = dataclasses.replace(rt, act_spec=SH.activation_spec(policy))

    def train_step(params, opt_state, batch):
        def micro_loss(p, mb):
            loss, mets = M.loss_fn(p, cfg, rt, mb)
            return loss, mets

        grad_fn = jax.value_and_grad(micro_loss, has_aux=True)

        if n_micro == 1:
            (loss, mets), grads = grad_fn(params, batch)
        else:
            def split(leaf):
                b = leaf.shape[0]
                return leaf.reshape(n_micro, b // n_micro, *leaf.shape[1:])

            mbs = jax.tree.map(split, batch)

            def body(gsum, mb):
                (l, mt), g = grad_fn(params, mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(a.dtype), gsum, g)
                return gsum, (l, mt)

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            gsum, (losses, metss) = jax.lax.scan(body, g0, mbs)
            grads = jax.tree.map(lambda g: (g / n_micro).astype(jnp.float32),
                                 gsum)
            loss = jnp.mean(losses)
            mets = jax.tree.map(jnp.mean, metss)

        new_p, new_s = optimizer.update(params, opt_state, grads)
        metrics = {"loss": loss.astype(jnp.float32), **mets}
        return new_p, new_s, metrics

    return train_step, n_micro


def make_decode_step(cfg, rt: Runtime):
    """serve_step: ONE new token against a KV/SSM cache of seq_len."""
    def decode_step(params, cache, token, pos):
        logits, new_cache = M.decode_step(params, cfg, rt, token, cache, pos)
        return logits, new_cache
    return decode_step


def make_prefill_step(cfg, rt: Runtime):
    def prefill_step(params, batch, cache):
        logits, new_cache = M.prefill(params, cfg, rt, batch, cache)
        return logits, new_cache
    return prefill_step


# ---------------------------------------------------------------------------
# jit binding with the sharding policy
# ---------------------------------------------------------------------------

def bind_train(mesh: Mesh, cfg, rt, optimizer, shape, *,
               policy: Optional[SH.ShardingPolicy] = None,
               num_microbatches: int = 0, donate: bool = True):
    """Build the jitted train_step plus every spec needed to call/lower it.

    Returns dict(step=jitted fn, specs=..., n_micro=...).
    """
    policy = policy or SH.ShardingPolicy.for_mesh(mesh)
    pshape = jax.eval_shape(
        lambda k: M.init_params(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    pspecs = SH.param_specs(pshape, policy)
    oshape = jax.eval_shape(lambda: optimizer.init(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), pshape)))
    ospecs = SH.opt_state_specs(oshape, pspecs)
    bshape = M.train_batch_spec(cfg, shape)
    bspecs = SH.batch_specs(bshape, policy)

    step, n_micro = make_train_step(cfg, rt, optimizer, shape, policy,
                                    num_microbatches=num_microbatches)
    mspec = {"loss": P(), "ce": P(), "aux": P()}
    jitted = jax.jit(
        step,
        in_shardings=(SH.named(mesh, pspecs), SH.named(mesh, ospecs),
                      SH.named(mesh, bspecs)),
        out_shardings=(SH.named(mesh, pspecs), SH.named(mesh, ospecs),
                       SH.named(mesh, {k: mspec[k] for k in ("loss", "ce", "aux")})),
        donate_argnums=(0, 1) if donate else (),
    )
    return dict(step=jitted, param_specs=pspecs, opt_specs=ospecs,
                batch_specs=bspecs, params_shape=pshape, opt_shape=oshape,
                batch_shape=bshape, n_micro=n_micro, policy=policy)


def bind_decode(mesh: Mesh, cfg, rt, shape, *,
                policy: Optional[SH.ShardingPolicy] = None):
    """Jitted serve_step + specs. Cache length = shape.seq_len."""
    policy = policy or SH.ShardingPolicy.for_mesh(mesh)
    pshape = jax.eval_shape(
        lambda k: M.init_params(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    pspecs = SH.param_specs(pshape, policy)
    cshape = jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
    cspecs = SH.cache_specs(cshape, policy)
    bp = policy.batch_axes
    tok_spec = (P(bp) if shape.global_batch % policy.size(bp) == 0 else P(None))

    step = make_decode_step(cfg, rt)
    jitted = jax.jit(
        step,
        in_shardings=(SH.named(mesh, pspecs), SH.named(mesh, cspecs),
                      NamedSharding(mesh, tok_spec), NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, tok_spec
                                     if tok_spec != P(None) else P()),
                       SH.named(mesh, cspecs)),
        donate_argnums=(1,),
    )
    tok_shape, pos_shape = M.decode_spec(cfg, shape)
    return dict(step=jitted, param_specs=pspecs, cache_specs=cspecs,
                params_shape=pshape, cache_shape=cshape,
                token_shape=tok_shape, pos_shape=pos_shape, policy=policy)


def bind_prefill(mesh: Mesh, cfg, rt, shape, *,
                 policy: Optional[SH.ShardingPolicy] = None):
    """Jitted prefill over the prompt, writing cache positions [0, seq_len)."""
    policy = policy or SH.ShardingPolicy.for_mesh(mesh)
    pshape = jax.eval_shape(
        lambda k: M.init_params(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    pspecs = SH.param_specs(pshape, policy)
    cshape = jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
    cspecs = SH.cache_specs(cshape, policy)
    bshape = prefill_batch_spec(cfg, shape)
    bspecs = SH.batch_specs(bshape, policy)
    bp = policy.batch_axes
    logit_spec = (P(bp, None) if shape.global_batch % policy.size(bp) == 0
                  else P(None, None))

    step = make_prefill_step(cfg, rt)
    jitted = jax.jit(
        step,
        in_shardings=(SH.named(mesh, pspecs), SH.named(mesh, bspecs),
                      SH.named(mesh, cspecs)),
        out_shardings=(NamedSharding(mesh, logit_spec), SH.named(mesh, cspecs)),
        donate_argnums=(2,),
    )
    return dict(step=jitted, param_specs=pspecs, cache_specs=cspecs,
                batch_specs=bspecs, params_shape=pshape, cache_shape=cshape,
                batch_shape=bshape, policy=policy)


def prefill_batch_spec(cfg, shape) -> Dict[str, jax.ShapeDtypeStruct]:
    """Prompt batch: seq_len tokens (no +1 label shift)."""
    Bsz, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "encdec":
        return {"frames": jax.ShapeDtypeStruct((Bsz, cfg.encoder_seq,
                                                cfg.d_model), dt),
                "tokens": jax.ShapeDtypeStruct((Bsz, S), jnp.int32)}
    if cfg.family == "vlm":
        St = S - cfg.vision_prefix
        return {"patches": jax.ShapeDtypeStruct((Bsz, cfg.vision_prefix,
                                                 cfg.d_model), dt),
                "tokens": jax.ShapeDtypeStruct((Bsz, St), jnp.int32)}
    return {"tokens": jax.ShapeDtypeStruct((Bsz, S), jnp.int32)}
