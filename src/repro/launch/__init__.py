"""Launchers: production mesh, multi-pod dry-run, real train/serve drivers."""
