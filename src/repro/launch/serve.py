"""Serving driver: batched prefill + decode with the sharded serve_step.

Serves a (reduced by default) assigned architecture on the host mesh with a
continuous-batching-style loop: a queue of requests with different prompt
lengths is packed into fixed batches, prefilled, then decoded token-by-token
with the KV/SSM cache. This is the decode-shape path (decode_32k/long_500k)
of the dry-run, executed for real at small scale.

  PYTHONPATH=src python -m repro.launch.serve --arch falcon-mamba-7b \
      --requests 8 --batch 4 --prompt 32 --tokens 16
"""
from __future__ import annotations

import argparse
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.configs.base import InputShape
from repro.distributed import steps as ST
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models.runtime import Runtime


def greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="stablelm-1.6b", choices=C.ARCH_IDS)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = C.get(args.arch) if args.full else C.get_smoke(args.arch)
    mesh = make_host_mesh()
    rt = Runtime(remat=False)
    max_seq = args.prompt + args.tokens + 8
    shape = InputShape("serve", max_seq, args.batch, "decode")

    rng = np.random.RandomState(args.seed)
    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key)

    pre = ST.bind_prefill(mesh, cfg, rt,
                          InputShape("p", args.prompt, args.batch, "prefill"))
    dec = ST.bind_decode(mesh, cfg, rt, shape)

    # request queue (the JSDoop task queue, serving flavour)
    prompts: List[np.ndarray] = [
        rng.randint(0, cfg.vocab, size=args.prompt).astype(np.int32)
        for _ in range(args.requests)]

    done = 0
    t0 = time.time()
    total_new = 0
    while done < len(prompts):
        batch_p = prompts[done:done + args.batch]
        while len(batch_p) < args.batch:           # pad the last batch
            batch_p.append(np.zeros(args.prompt, np.int32))
        toks = jnp.asarray(np.stack(batch_p))
        batch = {"tokens": toks}
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (args.batch, cfg.vision_prefix, cfg.d_model),
                jnp.dtype(cfg.dtype))
        cache = M.init_cache(cfg, args.batch, max_seq,
                             dtype=jnp.dtype(cfg.dtype))
        logits, cache = pre["step"](params, batch, cache)
        tok = greedy(logits)
        outs = [tok]
        pos = args.prompt + (cfg.vision_prefix if cfg.family == "vlm" else 0)
        for t in range(args.tokens - 1):
            logits, cache = dec["step"](params, cache, tok,
                                        jnp.int32(pos + t))
            tok = greedy(logits)
            outs.append(tok)
        gen = jnp.stack(outs, axis=1)
        assert gen.shape == (args.batch, args.tokens)
        assert not bool(jnp.any(jnp.isnan(logits)))
        total_new += int(min(args.batch, len(prompts) - done)) * args.tokens
        done += args.batch
        print(f"  served {done}/{len(prompts)}  sample: "
              f"{np.asarray(gen[0])[:8].tolist()}")
    dt = time.time() - t0
    print(f"[serve] {cfg.name}: {total_new} tokens in {dt:.1f}s "
          f"({total_new / dt:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
