"""Production meshes (TPU v5e). 256-chip pod = 16x16 (data, model);
2-pod cluster = 2x16x16 (pod, data, model).

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax use,
while tests and benches see the real single CPU device.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Whatever this host actually has (1 CPU device in this container):
    the degenerate (1, 1) mesh used by the real train/serve drivers and tests."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))


# TPU v5e hardware constants (per chip) — the roofline denominators.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link
