"""Production meshes (TPU v5e). 256-chip pod = 16x16 (data, model);
2-pod cluster = 2x16x16 (pod, data, model).

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax use,
while tests and benches see the real single CPU device.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 exposes explicit axis types; 0.4.x (floor: 0.4.37) does not
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def compat_make_mesh(shape, axis_names):
    """jax.make_mesh across the supported jax range: pass axis_types=Auto when
    the installed jax knows about it, plain make_mesh otherwise (0.4.x treats
    every axis as auto already)."""
    if AxisType is not None:
        return jax.make_mesh(shape, axis_names,
                             axis_types=(AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(shape, axis_names)


def compat_set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh:
    ``jax.set_mesh`` where it exists (jax >= 0.6), else the Mesh object
    itself (the 0.4.x ``with mesh:`` idiom)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has (1 CPU device in this container):
    the degenerate (1, 1) mesh used by the real train/serve drivers and tests."""
    n = len(jax.devices())
    return compat_make_mesh((n, 1), ("data", "model"))


# TPU v5e hardware constants (per chip) — the roofline denominators.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link
