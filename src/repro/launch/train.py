"""Real training driver (runs on whatever devices exist — 1 CPU here).

Two modes:
- ``--paper``: the paper's exact experiment — queue-scheduled distributed
  training of the 2x50 LSTM on this repo's own source text (JSDoop §V),
  through the L1 Coordinator with K simulated volunteers.
- ``--arch <id>``: the L2 SPMD path — train a (reduced by default) assigned
  architecture with the sharded train_step on the host mesh, synthetic
  token stream, versioned checkpoints.

Examples:
  PYTHONPATH=src python -m repro.launch.train --paper --workers 4 --versions 8
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --steps 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.checkpoint.store import CheckpointStore
from repro.configs.base import InputShape
from repro.distributed import steps as ST
from repro.distributed import sharding as SH
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models.runtime import Runtime
from repro.optim import make as make_opt


def run_paper(args) -> int:
    from repro.core.coordinator import Coordinator
    from repro.core.mapreduce import TrainingProblem
    prob = TrainingProblem.paper_problem(seed=args.seed)
    n_versions = args.versions or prob.n_versions
    print(f"[paper] vocab={prob.cfg.vocab} params={prob.grad_bytes // 4} "
          f"versions={n_versions} workers={args.workers}")
    t0 = time.time()
    coord = Coordinator(prob, n_workers=args.workers, n_versions=n_versions)
    res = coord.run()
    dt = time.time() - t0
    print(f"[paper] done v{res.final_version} in {dt:.1f}s; "
          f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}; "
          f"requeues={res.requeues}")
    return 0


def run_arch(args) -> int:
    cfg = C.get(args.arch) if args.full else C.get_smoke(args.arch)
    if cfg.family == "rnn":
        raise SystemExit("use --paper for the LSTM workload")
    mesh = make_host_mesh()
    rt = Runtime(remat=not args.no_remat)
    shape = InputShape("cli", args.seq, args.batch, "train")
    opt = make_opt(args.optimizer, args.lr)
    bound = ST.bind_train(mesh, cfg, rt, opt, shape,
                          num_microbatches=args.micro)

    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key)
    opt_state = opt.init(params)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"[arch] {cfg.name} ({'full' if args.full else 'smoke'}) "
          f"params={n_params:,} micro={bound['n_micro']} mesh={mesh.devices.shape}")

    store = CheckpointStore(args.ckpt) if args.ckpt else None
    rng = np.random.RandomState(args.seed)
    spec = bound["batch_shape"]

    def sample_batch():
        out = {}
        for k, s in spec.items():
            if s.dtype == jnp.int32:
                out[k] = jnp.asarray(
                    rng.randint(0, cfg.vocab, size=s.shape), jnp.int32)
            else:
                out[k] = jnp.asarray(rng.randn(*s.shape), s.dtype)
        return out

    t0 = time.time()
    losses = []
    for step in range(args.steps):
        params, opt_state, mets = bound["step"](params, opt_state,
                                                sample_batch())
        losses.append(float(mets["loss"]))
        if step % max(args.steps // 10, 1) == 0 or step == args.steps - 1:
            print(f"  step {step:4d} loss {losses[-1]:.4f} "
                  f"({(time.time() - t0) / (step + 1):.2f}s/step)")
        if store and (step + 1) % args.ckpt_every == 0:
            v = (store.latest() or 0) + 1
            store.save(v, {"params": params, "opt": opt_state},
                       meta={"step": step + 1})
            print(f"  checkpoint v{v}")
    assert np.isfinite(losses).all(), "NaN/inf loss"
    print(f"[arch] loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"in {time.time() - t0:.1f}s")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--paper", action="store_true")
    ap.add_argument("--arch", default=None, choices=C.ARCH_IDS)
    ap.add_argument("--full", action="store_true",
                    help="full published config (NOT for 1-CPU containers)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--micro", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["rmsprop", "sgd", "adamw"])
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--versions", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args(argv)
    if args.paper:
        return run_paper(args)
    if not args.arch:
        raise SystemExit("need --paper or --arch <id>")
    return run_arch(args)


if __name__ == "__main__":
    raise SystemExit(main())
