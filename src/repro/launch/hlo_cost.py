"""Loop-aware cost analysis of optimized HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE — under
scan-over-layers + gradient-accumulation scans that undercounts FLOPs by
~U x n_micro (measured 91x on stablelm train_4k). XLA however records
``backend_config={"known_trip_count":{"n":...}}`` on every while op, so we
re-derive the roofline numerators ourselves:

- multiplicity propagation: ENTRY has multiplicity 1; a while body/cond
  inherit caller_mult x trip_count; call/conditional/fusion callees inherit
  caller_mult. Two maps are kept: *materializing* computations (reached
  without passing through a fusion — their buffers live in HBM) and *all*
  computations (for FLOP counting inside fused dots).
- FLOPs: 2 * numel(result) * K for every dot, scaled by multiplicity.
- bytes: for materializing computations, sum (result + operand) bytes of
  every non-trivial op — an HBM-traffic proxy that treats each op as
  read-operands/write-result (fusion internals excluded, fusion boundaries
  included via the fusion op itself).
- collectives: result-shape bytes per op kind, scaled by multiplicity.

All numbers are per-device: the module analyzed is the SPMD-partitioned
per-device program.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1,
                "f8e4m3": 1, "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4,
                "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*\{\s*$")
_LINE_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CDIM_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """Normalize ``Compiled.cost_analysis()`` across jax versions: 0.4.x
    returns a one-element list of dicts (per device assignment), newer jax
    returns the dict directly."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(type_str: str) -> Tuple[int, ...]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return ()
    return tuple(int(d) for d in m.group(2).split(",") if d)


def _numel(type_str: str) -> int:
    n = 1
    for d in _first_shape_dims(type_str):
        n *= d
    return n


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    kind: str
    rest: str          # everything after the opening paren of the op


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    ops: List[Op]
    symbols: Dict[str, str]          # op/param name -> type string


def _split_type(rest: str) -> Tuple[str, str]:
    """rest starts at the type. Returns (type_str, remainder_after_type)."""
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return rest[:i + 1], rest[i + 1:].lstrip()
        return rest, ""
    sp = rest.find(" ")
    if sp < 0:
        return rest, ""
    return rest[:sp], rest[sp + 1:].lstrip()


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _HDR_RE.match(line.strip())
            if m:
                cur = Computation(m.group(2), bool(m.group(1)), [], {})
                # header params: "name: type, name: type"
                for pm in re.finditer(r"([\w.\-]+):\s*([^,()]+(?:\([^)]*\))?"
                                      r"(?:\[[\d,]*\])?(?:\{[\d,]*\})?)",
                                      m.group(3)):
                    cur.symbols[pm.group(1)] = pm.group(2)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        lm = _LINE_RE.match(line)
        if not lm:
            continue
        name, rest = lm.group(1), lm.group(2)
        type_str, after = _split_type(rest)
        om = re.match(r"([\w\-]+)\(", after)
        kind = om.group(1) if om else ""
        cur.symbols[name] = type_str
        cur.ops.append(Op(name, type_str, kind, after))
    return comps


def _multiplicities(comps: Dict[str, Computation]
                    ) -> Tuple[Dict[str, float], Dict[str, float]]:
    """(materializing_mult, flop_mult) per computation name."""
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    mat: Dict[str, float] = defaultdict(float)
    flop: Dict[str, float] = defaultdict(float)
    mat[entry] = flop[entry] = 1.0
    # edges: (callee, factor, through_fusion)
    edges: Dict[str, List[Tuple[str, float, bool]]] = defaultdict(list)
    for c in comps.values():
        for op in c.ops:
            if op.kind == "while":
                trip = 1.0
                tm = _TRIP_RE.search(op.rest)
                if tm:
                    trip = float(tm.group(1))
                for rx in (_BODY_RE, _COND_RE):
                    m = rx.search(op.rest)
                    if m and m.group(1) in comps:
                        edges[c.name].append((m.group(1), trip, False))
            elif op.kind == "fusion":
                m = _CALLS_RE.search(op.rest)
                if m and m.group(1) in comps:
                    edges[c.name].append((m.group(1), 1.0, True))
            elif op.kind in ("call", "async-start"):
                m = (_TOAPPLY_RE.search(op.rest) or _CALLS_RE.search(op.rest))
                if m and m.group(1) in comps:
                    edges[c.name].append((m.group(1), 1.0, False))
            elif op.kind == "conditional":
                m = _BRANCH_RE.search(op.rest)
                if m:
                    for nm in _OPERAND_RE.finditer(m.group(1)):
                        if nm.group(1) in comps:
                            edges[c.name].append((nm.group(1), 1.0, False))
            # reduce/sort/scatter to_apply: scalar lambdas — cost ignored

    # propagate (the call graph is a DAG; iterate until fixpoint to be safe)
    for _ in range(len(comps) + 2):
        changed = False
        for src, outs in edges.items():
            for dst, fac, through_fusion in outs:
                fm = flop[src] * fac
                if fm > flop[dst]:
                    flop[dst] = fm
                    changed = True
                mm = (0.0 if through_fusion else mat[src] * fac)
                if mm > mat[dst]:
                    mat[dst] = mm
                    changed = True
        if not changed:
            break
    return dict(mat), dict(flop)


_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "partition-id", "replica-id", ""}


def analyze(text: str) -> Dict[str, object]:
    comps = parse_module(text)
    mat, flop = _multiplicities(comps)

    flops = 0.0
    bytes_ = 0.0
    dot_bytes = 0.0   # dot operands+results only: TPU-fusion-optimistic HBM proxy
    flash_bytes = 0.0  # the subset belonging to flash-attention score/context
                       # einsums — the Pallas flash kernel keeps these in VMEM,
                       # so a kernel-adjusted memory term subtracts them
    coll = {k: 0.0 for k in _COLLECTIVES}
    coll_count = {k: 0 for k in _COLLECTIVES}
    loops: List[Tuple[str, float]] = []

    for c in comps.values():
        fm = flop.get(c.name, 0.0)
        mm = mat.get(c.name, 0.0)
        for op in c.ops:
            if op.kind == "dot" and fm:
                k = 1
                cm = _CDIM_RE.search(op.rest)
                lhs_name = None
                args = op.rest[op.rest.find("(") + 1:]
                am = _OPERAND_RE.search(args)
                if am:
                    lhs_name = am.group(1)
                if cm and lhs_name and lhs_name in c.symbols:
                    dims = _first_shape_dims(c.symbols[lhs_name])
                    for d in cm.group(1).split(","):
                        if d and int(d) < len(dims):
                            k *= dims[int(d)]
                flops += fm * 2.0 * _numel(op.type_str) * k
                db = shape_bytes(op.type_str)
                args = op.rest[op.rest.find("(") + 1: op.rest.find(")")]
                for nm in _OPERAND_RE.finditer(args):
                    db += shape_bytes(c.symbols.get(nm.group(1), ""))
                dot_bytes += fm * db
                # flash-attention inner einsums (see models.layers._flash_fwd
                # / _fa_bwd): score and context products over the kv-chunk dim
                if ("bqkgd,bckd" in op.rest or "bkgqc,bckd" in op.rest
                        or "bkgqd,bckd" in op.rest or "bkgqc,bqkgd" in op.rest
                        or "bqkgd,bkgqc" in op.rest):
                    flash_bytes += fm * db
            if op.kind == "while" and c.name in mat:
                tm = _TRIP_RE.search(op.rest)
                loops.append((op.name, float(tm.group(1)) if tm else 1.0))
            base = op.kind.replace("-start", "")
            if base in _COLLECTIVES and mm:
                if op.kind.endswith("-done"):
                    continue
                coll[base] += mm * shape_bytes(op.type_str)
                coll_count[base] += 1
            if mm and op.kind not in _SKIP_BYTES \
                    and not op.kind.endswith("-done"):
                b = shape_bytes(op.type_str)
                args = op.rest[op.rest.find("(") + 1: op.rest.find(")")]
                for nm in _OPERAND_RE.finditer(args):
                    b += shape_bytes(c.symbols.get(nm.group(1), ""))
                bytes_ += mm * b

    return dict(flops=flops, bytes=bytes_, dot_bytes=dot_bytes,
                flash_dot_bytes=flash_bytes,
                collective_bytes={**{k: int(v) for k, v in coll.items()},
                                  "total": int(sum(coll.values())),
                                  "_counts": coll_count},
                while_loops=loops,
                n_computations=len(comps))
