import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x mesh)
combination with ShapeDtypeStruct inputs (no allocation), then extract the
roofline terms from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-1.6b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out EXPERIMENTS_dryrun.jsonl

Per combination we record:
  - memory_analysis (bytes per device: args/outputs/temps -> "does it fit"),
  - cost_analysis flops / bytes accessed (per-device, post-partitioning),
  - collective bytes by op kind, parsed from the optimized HLO,
  - the three roofline terms against v5e peaks and the dominant one.
"""
import argparse
import dataclasses
import json
import re
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.launch import hlo_cost
from repro.launch import mesh as MESH
from repro.models import model as M
from repro.models.runtime import Runtime
from repro.distributed import steps as ST
from repro.distributed import sharding as SH
from repro.optim import rmsprop, adamw


# ---------------------------------------------------------------------------
# HLO collective accounting
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in the optimized HLO.

    The result shape is the per-device payload after the collective: for
    all-gather it's the gathered (larger) buffer, for reduce-scatter the
    scattered shard, for all-reduce the reduced buffer — a uniform,
    reproducible proxy for bytes-on-the-wire per device.
    ``-done`` ops are skipped so async pairs aren't double counted.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    count: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if f"{m.group(2)}-done" in line:
            continue
        out[m.group(2)] += _shape_bytes(m.group(1))
        count[m.group(2)] += 1
    out_all = dict(out)
    out_all["_counts"] = count
    out_all["total"] = sum(out[k] for k in _COLLECTIVES)
    return out_all


# ---------------------------------------------------------------------------
# lowering one combination
# ---------------------------------------------------------------------------

def input_specs(cfg, shape, kind: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this combination."""
    if kind == "train":
        return {"batch": M.train_batch_spec(cfg, shape)}
    if kind == "prefill":
        return {"batch": ST.prefill_batch_spec(cfg, shape)}
    # decode
    tok, pos = M.decode_spec(cfg, shape)
    return {"token": tok, "pos": pos}


def _abstract(tree):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree)


def default_runtime(cfg, shape) -> Runtime:
    return Runtime()


def resolve_cfg(arch: str, shape_name: str):
    """Apply per-shape architectural adjustments (DESIGN §5):
    dense archs decode long_500k with a sliding window (sub-quadratic)."""
    cfg = C.get(arch)
    shape = C.get_shape(shape_name)
    if shape_name == "long_500k" and cfg.family in ("dense", "vlm", "encdec") \
            and not cfg.sliding_window:
        cfg = cfg.replace(sliding_window=8192)
    return cfg, shape


def lower_one(arch: str, shape_name: str, mesh, *, rt: Optional[Runtime] = None,
              policy_kw: Optional[dict] = None, num_microbatches: int = 0,
              optimizer: str = "rmsprop", cfg_overrides: Optional[dict] = None):
    """Lower + compile one (arch, shape, mesh). Returns the record dict."""
    cfg, shape = resolve_cfg(arch, shape_name)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    rt = rt or default_runtime(cfg, shape)
    policy = SH.ShardingPolicy.for_mesh(mesh, **(policy_kw or {}))
    t0 = time.time()

    opt = rmsprop(0.1) if optimizer == "rmsprop" else adamw(1e-4)

    with MESH.compat_set_mesh(mesh):
        if shape.kind == "train":
            b = ST.bind_train(mesh, cfg, rt, opt, shape, policy=policy,
                              num_microbatches=num_microbatches, donate=False)
            args = (_abstract(b["params_shape"]), _abstract(b["opt_shape"]),
                    _abstract(b["batch_shape"]))
            lowered = b["step"].lower(*args)
            extra = {"n_micro": b["n_micro"]}
        elif shape.kind == "prefill":
            b = ST.bind_prefill(mesh, cfg, rt, shape, policy=policy)
            args = (_abstract(b["params_shape"]), _abstract(b["batch_shape"]),
                    _abstract(b["cache_shape"]))
            lowered = b["step"].lower(*args)
            extra = {}
        else:  # decode
            b = ST.bind_decode(mesh, cfg, rt, shape, policy=policy)
            args = (_abstract(b["params_shape"]), _abstract(b["cache_shape"]),
                    b["token_shape"], b["pos_shape"])
            lowered = b["step"].lower(*args)
            extra = {}
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = hlo_cost.xla_cost_analysis(compiled)
    hlo = compiled.as_text()
    # loop-aware re-analysis: XLA's cost_analysis counts while bodies once
    # (see hlo_cost docstring); ours scales by known_trip_count.
    la = hlo_cost.analyze(hlo)
    coll = la["collective_bytes"]

    n_chips = int(np.prod(mesh.devices.shape))
    flops_dev = float(la["flops"])
    # HBM-traffic estimate: fusion-optimistic (TPU-like) = dot traffic +
    # one-time arg/output traffic; the all-ops sum is the pessimistic bound.
    mem_d = _mem_dict(mem)
    bytes_opt = (float(la["dot_bytes"]) + mem_d["argument_size_in_bytes"]
                 + mem_d["output_size_in_bytes"])
    bytes_dev = float(la["bytes"])
    # with the L3 Pallas flash kernel the attention score/context tensors
    # never leave VMEM; this is the memory term a kernel-enabled build sees
    bytes_kernel = bytes_opt - float(la["flash_dot_bytes"])
    terms = roofline_terms(flops_dev, bytes_opt, coll["total"])

    rec = dict(
        arch=arch, shape=shape_name,
        mesh="x".join(map(str, mesh.devices.shape)), chips=n_chips,
        kind=shape.kind,
        params=cfg.param_count(), active_params=cfg.active_param_count(),
        flops_per_device=flops_dev, bytes_per_device=bytes_opt,
        bytes_per_device_pessimistic=bytes_dev,
        t_memory_kernel=bytes_kernel / MESH.HBM_BW,
        xla_flops_raw=float(cost.get("flops", 0.0)),
        collective_bytes=coll, memory=mem_d,
        n_while_loops=len(la["while_loops"]),
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        **terms, **extra,
    )
    rec.update(model_flops_terms(cfg, shape, rec))
    return rec


def roofline_terms(flops_dev: float, bytes_dev: float, coll_bytes: int
                   ) -> Dict[str, float]:
    """Per-device seconds for each roofline term (v5e)."""
    t_c = flops_dev / MESH.PEAK_FLOPS_BF16
    t_m = bytes_dev / MESH.HBM_BW
    t_x = coll_bytes / MESH.ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    return dict(t_compute=t_c, t_memory=t_m, t_collective=t_x, bottleneck=dom)


def model_flops_terms(cfg, shape, rec) -> Dict[str, float]:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (fwd); MoE uses active params.
    Ratio over compiled per-device flops * chips = useful-compute fraction."""
    n = rec["active_params"] if cfg.moe.num_experts else rec["params"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mf = 6.0 * n * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mf = 2.0 * n * tokens
    else:
        tokens = shape.global_batch  # one token per request
        mf = 2.0 * n * tokens
    hlo_total = rec["flops_per_device"] * rec["chips"]
    return dict(model_flops=mf,
                useful_fraction=(mf / hlo_total) if hlo_total else 0.0)


def _mem_dict(mem) -> Dict[str, int]:
    return {k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "alias_size_in_bytes",
             "generated_code_size_in_bytes")}


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def elastic_plan(arch: str, shape_name: str, *, steps=((4, 16), (8, 16),
                                                       (16, 16))):
    """The TPU-idiomatic form of JSDoop's elastic membership (DESIGN §3):
    when "volunteers" (slices) join or leave, the driver re-lowers the same
    train_step for the new data-parallel size. This dry-runs the re-mesh
    sequence and reports per-step compile cost + roofline terms, proving the
    schedule is valid at every membership size.
    """
    recs = []
    for shape_dp in steps:
        mesh = MESH.compat_make_mesh(shape_dp, ("data", "model"))
        rec = lower_one(arch, shape_name, mesh)
        print(f"[elastic] dp={shape_dp[0]:3d} x tp={shape_dp[1]} "
              f"compile={rec['compile_s']:.1f}s "
              f"t_c={rec['t_compute']:.2e} t_x={rec['t_collective']:.2e} "
              f"bottleneck={rec['bottleneck']}")
        recs.append(rec)
    return recs


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id (see configs)")
    ap.add_argument("--shape", default=None, choices=list(C.INPUT_SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) combination")
    ap.add_argument("--elastic-plan", action="store_true",
                    help="re-lower the same step across growing data-parallel"
                         " sizes (elastic membership, DESIGN §3)")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--fsdp-pod", action="store_true",
                    help="extend the FSDP domain over the pod axis")
    ap.add_argument("--no-fsdp", action="store_true",
                    help="TP-only weight sharding (serving-friendly)")
    ap.add_argument("--no-hd-fallback", action="store_true",
                    help="replicate qkv instead of sharding head_dim when "
                         "heads don't divide the TP axis")
    ap.add_argument("--grad-accum-dtype", default="float32")
    ap.add_argument("--pad-vocab", type=int, default=0,
                    help="pad vocab to a multiple (enables vocab TP)")
    ap.add_argument("--moe-shard", action="store_true",
                    help="pin MoE dispatch buffers to expert/data axes")
    ap.add_argument("--micro", type=int, default=0,
                    help="requested grad-accumulation microbatches (default 16)")
    ap.add_argument("--optimizer", default="rmsprop",
                    choices=["rmsprop", "adamw"])
    args = ap.parse_args(argv)

    if args.elastic_plan:
        elastic_plan(args.arch or "stablelm-1.6b",
                     args.shape or "train_4k")
        return 0

    combos = []
    archs = C.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(C.INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    ok = fail = 0
    for arch, shape_name, mp in combos:
        mesh = MESH.make_production_mesh(multi_pod=mp)
        policy_kw = dict(seq_parallel=args.seq_parallel,
                         grad_accum_dtype=args.grad_accum_dtype)
        if args.fsdp_pod and mp:
            policy_kw["fsdp_axes"] = ("pod", "data")
        if args.no_fsdp:
            policy_kw["fsdp_axes"] = ()
        if args.no_hd_fallback:
            policy_kw["attn_hd_fallback"] = False
        cfg_overrides = {}
        if args.pad_vocab:
            cfg_overrides["vocab_pad_to"] = args.pad_vocab
        rt = None
        if args.moe_shard:
            tok = ("pod", "data") if mp else ("data",)
            if args.seq_parallel:
                # residual stream is (batch, seq)-sharded; the flattened token
                # dim of the MoE sees both axes
                tok = tok + ("model",)
            rt = Runtime(moe_expert_axis="model", moe_token_axes=tok)
        tag = f"{arch} x {shape_name} x {'2x16x16' if mp else '16x16'}"
        try:
            rec = lower_one(arch, shape_name, mesh, policy_kw=policy_kw,
                            num_microbatches=args.micro, rt=rt,
                            optimizer=args.optimizer,
                            cfg_overrides=cfg_overrides or None)
            ok += 1
            print(f"[ok]   {tag}: bottleneck={rec['bottleneck']} "
                  f"t_c={rec['t_compute']:.3e}s t_m={rec['t_memory']:.3e}s "
                  f"t_x={rec['t_collective']:.3e}s "
                  f"temp={rec['memory']['temp_size_in_bytes']/2**30:.2f}GiB "
                  f"args={rec['memory']['argument_size_in_bytes']/2**30:.2f}GiB")
        except Exception as e:  # noqa: BLE001 — a dry-run failure IS the signal
            fail += 1
            rec = dict(arch=arch, shape=shape_name,
                       mesh="2x16x16" if mp else "16x16",
                       error=f"{type(e).__name__}: {e}")
            print(f"[FAIL] {tag}: {rec['error'][:300]}")
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    print(f"\n{ok} ok / {fail} failed / {len(combos)} total")
    return 1 if fail else 0


if __name__ == "__main__":
    sys.exit(main())
