"""Mixture-of-Experts with sort-based expert-parallel dispatch.

Two implementations share one parameter layout:

- ``"sort"`` (production): tokens are argsorted by routed expert id, scattered into
  a capacity-bounded ``[E, C, D]`` buffer (sharded on E -> the ``model`` mesh axis,
  so the scatter/gather lower to all-to-all-class collectives), batched per-expert
  matmuls, then gathered+combined. Tokens beyond capacity are dropped (standard
  GShard/Switch semantics).
- ``"dense"`` (oracle): every expert computed for every token, combined by gate.
  Exact (no dropping); used as the correctness reference in tests and for tiny
  smoke configs.

Variants covered: top-k routing, shared (always-on) experts (DeepSeek-MoE),
dense residual branch in parallel (Arctic).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_moe(key, cfg, dtype):
    D = cfg.d_model
    m = cfg.moe
    F = m.d_expert or cfg.d_ff
    E = m.num_experts
    ks = jax.random.split(key, 8)
    out_scale = 0.02 / math.sqrt(2 * max(cfg.n_layers, 1))
    gated = cfg.mlp == "swiglu"
    p = {"router": dense_init(ks[0], (D, E), jnp.float32, scale=0.02)}
    experts = {"wi": dense_init(ks[1], (E, D, F), dtype),
               "wo": dense_init(ks[2], (E, F, D), dtype, scale=out_scale)}
    if gated:
        experts["wg"] = dense_init(ks[3], (E, D, F), dtype)
    p["experts"] = experts
    if m.num_shared:
        shared = {"wi": dense_init(ks[4], (D, m.num_shared * F), dtype),
                  "wo": dense_init(ks[5], (m.num_shared * F, D), dtype,
                                   scale=out_scale)}
        if gated:
            shared["wg"] = dense_init(ks[6], (D, m.num_shared * F), dtype)
        p["shared"] = shared
    return p


def _expert_ffn(ep, h, kind: str):
    """h [E, C, D] -> [E, C, D] with per-expert weights."""
    up = jnp.einsum("ecd,edf->ecf", h, ep["wi"])
    if kind == "swiglu":
        up = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, ep["wg"])) * up
    elif kind == "squared_relu":
        up = jnp.square(jax.nn.relu(up))
    else:
        up = jax.nn.gelu(up, approximate=True)
    return jnp.einsum("ecf,efd->ecd", up, ep["wo"])


def _shared_ffn(sp, x, kind: str):
    up = x @ sp["wi"]
    if kind == "swiglu":
        up = jax.nn.silu(x @ sp["wg"]) * up
    elif kind == "squared_relu":
        up = jnp.square(jax.nn.relu(up))
    else:
        up = jax.nn.gelu(up, approximate=True)
    return up @ sp["wo"]


def router_probs(p, x):
    """x [T, D] -> router softmax probs [T, E] (fp32)."""
    logits = x.astype(jnp.float32) @ p["router"]
    return jax.nn.softmax(logits, axis=-1)


def load_balance_loss(probs, idx, E: int) -> jnp.ndarray:
    """Switch-style aux loss: E * sum_e f_e * P_e."""
    # f_e: fraction of tokens whose top-1 (any of top-k) routes to e
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)        # [T, k, E]
    f = jnp.mean(jnp.sum(onehot, axis=1), axis=0)             # [E]
    P = jnp.mean(probs, axis=0)                               # [E]
    return E * jnp.sum(f * P) / max(idx.shape[-1], 1)


def apply_moe_dense(p, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle: compute all experts for all tokens. x [T, D]."""
    m = cfg.moe
    probs = router_probs(p, x)
    gate, idx = jax.lax.top_k(probs, m.top_k)                 # [T,k]
    gate = (gate / jnp.sum(gate, axis=-1, keepdims=True)).astype(x.dtype)
    ep = p["experts"]
    # all experts on all tokens: h_e [E, T, D]
    hT = jnp.einsum("td,edf->etf", x, ep["wi"])
    if "wg" in ep:
        hT = jax.nn.silu(jnp.einsum("td,edf->etf", x, ep["wg"])) * hT
    elif cfg.mlp == "squared_relu":
        hT = jnp.square(jax.nn.relu(hT))
    else:
        hT = jax.nn.gelu(hT, approximate=True)
    yT = jnp.einsum("etf,efd->etd", hT, ep["wo"])             # [E, T, D]
    combine = jnp.zeros((x.shape[0], cfg.moe.num_experts), x.dtype)
    combine = combine.at[jnp.arange(x.shape[0])[:, None], idx].add(gate)
    out = jnp.einsum("te,etd->td", combine, yT)
    aux = load_balance_loss(probs, idx, m.num_experts)
    return out, aux


def apply_moe_sort(p, x, cfg, capacity_factor: float, *, expert_axis=None,
                   token_axes=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort-based capacity dispatch. x [T, D] -> ([T, D], aux_loss).

    With ``expert_axis``/``token_axes`` set (requires a mesh context), the
    expert buffer is pinned to the expert-parallel axis and the token arrays
    to the data axes, so the token<->expert redistribution lowers to
    all-to-all-class collectives instead of a full all-gather (§Perf).
    """
    from jax.sharding import PartitionSpec as P
    m = cfg.moe
    T, D = x.shape
    E, k = m.num_experts, m.top_k
    C = max(int(k * T * capacity_factor / E), 1)

    def tok_pin(t):
        if token_axes is None:
            return t
        return jax.lax.with_sharding_constraint(
            t, P(*([token_axes] + [None] * (t.ndim - 1))))

    def exp_pin(t):
        if expert_axis is None:
            return t
        return jax.lax.with_sharding_constraint(
            t, P(*([expert_axis] + [None] * (t.ndim - 1))))

    probs = router_probs(p, x)
    gate, idx = jax.lax.top_k(probs, k)                       # [T, k]
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    flat_e = idx.reshape(-1)                                  # [T*k]
    order = jnp.argsort(flat_e)                               # stable
    sorted_e = flat_e[order]
    token_of = order // k
    # position within the expert group (sorted layout => first-occurrence trick)
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = pos_in_e < C
    pos_cl = jnp.minimum(pos_in_e, C - 1)

    # scatter tokens into the expert buffer [E, C, D] (sharded on E downstream)
    gathered = tok_pin(x[token_of] * keep[:, None].astype(x.dtype))
    buf = jnp.zeros((E, C, D), x.dtype)
    buf = exp_pin(buf.at[sorted_e, pos_cl].add(gathered, mode="drop"))

    y_e = exp_pin(_expert_ffn(p["experts"], buf, cfg.mlp))    # [E, C, D]

    # gather back + gate-combine (unsorted scatter-add over tokens)
    y_sorted = y_e[sorted_e, pos_cl] * keep[:, None].astype(x.dtype)
    g_sorted = gate.reshape(-1)[order].astype(x.dtype)
    out = jnp.zeros((T, D), x.dtype)
    out = tok_pin(out.at[token_of].add(y_sorted * g_sorted[:, None],
                                       mode="drop"))
    aux = load_balance_loss(probs, idx, E)
    return out, aux


def apply_moe(p, x, cfg, rt) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B, S, D] -> ([B, S, D], aux scalar). Shared experts / dense residual
    are the caller's (block's) responsibility via apply_shared/dense branches."""
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    if rt.moe_impl == "dense" or cfg.moe.num_experts <= 1:
        out, aux = apply_moe_dense(p, xt, cfg)
    else:
        out, aux = apply_moe_sort(p, xt, cfg, rt.cf(cfg),
                                  expert_axis=rt.moe_expert_axis,
                                  token_axes=rt.moe_token_axes)
    out = out.reshape(B, S, D)
    if "shared" in p:
        out = out + _shared_ffn(p["shared"], x, cfg.mlp)
    return out, aux
