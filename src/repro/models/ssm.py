"""Mamba-1 selective-state-space block (falcon-mamba / jamba mixer).

TPU adaptation: the recurrence h_t = a_t * h_{t-1} + b_t is evaluated as a
*chunked parallel scan* — ``lax.scan`` over sequence chunks carrying the state,
``lax.associative_scan`` (Blelloch, VPU-friendly) within each chunk. This bounds
the materialized [B, chunk, d_inner, d_state] tensor to one chunk (the full
[B, S, d_inner, d_state] expansion at train_4k on falcon-mamba-7b would be
16 GB/device), while keeping the MXU-sized projections dense.

Decode carries O(1) state: conv window [B, d_conv-1, d_inner] + h [B, d_inner, N].
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, zeros


def init_ssm(key, cfg, dtype):
    D = cfg.d_model
    Di = cfg.d_inner()
    N = cfg.ssm.d_state
    R = cfg.dt_rank()
    K = cfg.ssm.d_conv
    ks = jax.random.split(key, 6)
    # S4D-real initialization of A
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (Di, 1))
    # dt bias st. softplus(dt_bias) in [1e-3, 1e-1] (mamba default)
    u = jax.random.uniform(ks[0], (Di,), jnp.float32)
    dt = jnp.exp(u * (math.log(1e-1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "in_proj": dense_init(ks[1], (D, 2 * Di), dtype),
        "conv_w": dense_init(ks[2], (K, Di), dtype, scale=0.5 / math.sqrt(K)),
        "conv_b": zeros((Di,), dtype),
        "x_proj": dense_init(ks[3], (Di, R + 2 * N), dtype),
        "dt_proj": dense_init(ks[4], (R, Di), dtype, scale=R ** -0.5),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(A),
        "Dskip": jnp.ones((Di,), jnp.float32),
        "out_proj": dense_init(ks[5], (Di, D), dtype,
                               scale=0.02 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv along seq. x [B,S,Di], w [K,Di].

    conv_state [B, K-1, Di] (decode) or None (train: left-pad zeros).
    Returns (y [B,S,Di], new_conv_state [B,K-1,Di]).
    """
    K = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                    # [B, S+K-1, Di]
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K)) + b
    new_state = xp[:, -(K - 1):] if K > 1 else jnp.zeros_like(pad)
    return y, new_state


def _ssm_params(p, x_conv, cfg):
    """x_conv [..., Di] -> (dt [...,Di], B [...,N], C [...,N], A [Di,N])."""
    N = cfg.ssm.d_state
    R = cfg.dt_rank()
    proj = x_conv @ p["x_proj"]
    dt_in, Bm, Cm = jnp.split(proj.astype(jnp.float32), [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                                   # [Di, N]
    return dt, Bm, Cm, A


def _scan_chunk(h0, a, b):
    """h_t = a_t h_{t-1} + b_t within one chunk via associative scan.

    a, b: [B, L, Di, N]; h0 [B, Di, N]. Returns (h_all [B,L,Di,N], h_last)."""
    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2
    a_pref, b_pref = jax.lax.associative_scan(combine, (a, b), axis=1)
    h_all = a_pref * h0[:, None] + b_pref
    return h_all, h_all[:, -1]


def apply_ssm(p, x, cfg, rt, state: Optional[dict] = None
              ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Full-sequence (train/prefill) Mamba mixer. x [B,S,D].

    state: None for train; for prefill pass init state to receive final state.
    Returns (y [B,S,D], new_state or None).
    """
    B, S, D = x.shape
    Di = cfg.d_inner()
    N = cfg.ssm.d_state
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_state = state["conv"] if state is not None else None
    xc, new_conv = _causal_conv(xi, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)
    dt, Bm, Cm, A = _ssm_params(p, xc, cfg)                   # dt [B,S,Di]

    chunk = max(min(rt.sschunk(cfg), S), 1)
    nchunk = -(-S // chunk)
    pad = nchunk * chunk - S

    def pad_seq(t):
        return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))

    xcf = pad_seq(xc.astype(jnp.float32)).reshape(B, nchunk, chunk, Di)
    dtc = pad_seq(dt).reshape(B, nchunk, chunk, Di)
    Bc = pad_seq(Bm).reshape(B, nchunk, chunk, N)
    Cc = pad_seq(Cm).reshape(B, nchunk, chunk, N)

    h0 = (state["h"].astype(jnp.float32) if state is not None
          else jnp.zeros((B, Di, N), jnp.float32))

    def body(h, xs):
        xch, dch, bch, cch = xs
        a = jnp.exp(dch[..., None] * A)                        # [B,L,Di,N]
        bbar = (dch * xch)[..., None] * bch[:, :, None, :]     # [B,L,Di,N]
        h_all, h_last = _scan_chunk(h, a, bbar)
        y = jnp.einsum("bldn,bln->bld", h_all, cch)
        return h_last, y

    h_final, ys = jax.lax.scan(
        body, h0,
        (jnp.moveaxis(xcf, 1, 0), jnp.moveaxis(dtc, 1, 0),
         jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, nchunk * chunk, Di)[:, :S]
    y = y + xc.astype(jnp.float32) * p["Dskip"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    new_state = None
    if state is not None:
        new_state = {"conv": new_conv.astype(state["conv"].dtype),
                     "h": h_final.astype(state["h"].dtype)}
    return out, new_state


def apply_ssm_step(p, x, cfg, state: dict) -> Tuple[jnp.ndarray, dict]:
    """Single decode step. x [B,1,D]; state {conv [B,K-1,Di], h [B,Di,N]}."""
    B = x.shape[0]
    xz = x[:, 0] @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)                          # [B, Di]
    K = cfg.ssm.d_conv
    window = jnp.concatenate([state["conv"].astype(xi.dtype), xi[:, None]], axis=1)
    xc = jnp.einsum("bkd,kd->bd", window, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)
    dt, Bm, Cm, A = _ssm_params(p, xc, cfg)                    # dt [B,Di]
    a = jnp.exp(dt[..., None] * A)                             # [B,Di,N]
    bbar = (dt * xc.astype(jnp.float32))[..., None] * Bm[:, None, :]
    h = a * state["h"].astype(jnp.float32) + bbar
    y = jnp.einsum("bdn,bn->bd", h, Cm) + xc.astype(jnp.float32) * p["Dskip"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = (y @ p["out_proj"])[:, None]
    new_state = {"conv": window[:, 1:].astype(state["conv"].dtype),
                 "h": h.astype(state["h"].dtype)}
    return out, new_state


def init_ssm_state(cfg, batch: int, dtype) -> dict:
    return {"conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, cfg.d_inner()), dtype),
            "h": jnp.zeros((batch, cfg.d_inner(), cfg.ssm.d_state), jnp.float32)}
