"""Transformer/SSM blocks and the scan-over-layers machinery.

A *scan unit* is the repeating parameter structure: one layer for uniform
architectures, the full 8-layer period for Jamba-style hybrids. Units are
initialized per-instance and stacked leaf-wise, so depth costs O(1) HLO via
``lax.scan``. Decode caches are stacked the same way and threaded through the
scan as xs/ys.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S


# ---------------------------------------------------------------------------
# sublayer init
# ---------------------------------------------------------------------------

def _init_sublayer(key, cfg, dtype, layer_idx: int, *, cross: bool = False):
    """One residual layer: norm1 + mixer (+ norms/cross for encdec) + norm2 + ffn."""
    ks = jax.random.split(key, 6)
    kind = cfg.layer_kind(layer_idx)
    p: Dict[str, Any] = {"kind": kind}  # 'kind' is static; stripped before stacking
    p["norm1"] = L.init_norm(cfg.norm, cfg.d_model, dtype)
    if kind == "ssm":
        p["ssm"] = S.init_ssm(ks[0], cfg, dtype)
    else:
        p["attn"] = L.init_attention(ks[0], cfg, dtype)
    if cross:
        p["norm_x"] = L.init_norm(cfg.norm, cfg.d_model, dtype)
        p["cross"] = L.init_attention(ks[1], cfg, dtype, cross=True)
    # ffn (mamba layers in pure-SSM archs have no separate ffn)
    if not (cfg.family == "ssm"):
        p["norm2"] = L.init_norm(cfg.norm, cfg.d_model, dtype)
        if cfg.layer_is_moe(layer_idx):
            p["moe"] = M.init_moe(ks[2], cfg, dtype)
            if cfg.moe.dense_residual:
                p["mlp"] = L.init_mlp(ks[3], cfg, dtype)
        else:
            p["mlp"] = L.init_mlp(ks[3], cfg, dtype)
    return p


def unit_size(cfg) -> int:
    return cfg.hybrid.period if cfg.family == "hybrid" else 1

def num_units(cfg) -> int:
    assert cfg.n_layers % unit_size(cfg) == 0, (cfg.n_layers, unit_size(cfg))
    return cfg.n_layers // unit_size(cfg)


def init_unit(key, cfg, dtype, *, cross: bool = False):
    P = unit_size(cfg)
    ks = jax.random.split(key, P)
    return {f"l{i}": _init_sublayer(ks[i], cfg, dtype, i, cross=cross)
            for i in range(P)}


def strip_static(tree):
    """Remove the non-array 'kind' markers before stacking/scanning."""
    if isinstance(tree, dict):
        return {k: strip_static(v) for k, v in tree.items() if k != "kind"}
    return tree


def init_stacked_units(key, cfg, dtype, *, cross: bool = False):
    U = num_units(cfg)
    keys = jax.random.split(key, U)
    units = [strip_static(init_unit(k, cfg, dtype, cross=cross)) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *units)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_unit_cache(cfg, batch: int, max_seq: int, dtype, *,
                    cross_seq: int = 0):
    """Decode cache for one scan unit (stacked over units by the caller)."""
    cache: Dict[str, Any] = {}
    for i in range(unit_size(cfg)):
        kind = cfg.layer_kind(i)
        if kind == "ssm":
            cache[f"l{i}"] = S.init_ssm_state(cfg, batch, dtype)
        else:
            c = {"k": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.hd), dtype),
                 "v": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.hd), dtype)}
            if cross_seq:
                c["ck"] = jnp.zeros((batch, cross_seq, cfg.n_kv_heads, cfg.hd), dtype)
                c["cv"] = jnp.zeros((batch, cross_seq, cfg.n_kv_heads, cfg.hd), dtype)
            cache[f"l{i}"] = c
    if cross_seq:
        # encdec: every decoder layer has cross kv even if mixer is attention
        for i in range(unit_size(cfg)):
            c = cache[f"l{i}"]
            if "ck" not in c:
                c["ck"] = jnp.zeros((batch, cross_seq, cfg.n_kv_heads, cfg.hd), dtype)
                c["cv"] = jnp.zeros((batch, cross_seq, cfg.n_kv_heads, cfg.hd), dtype)
    return cache


def init_cache(cfg, batch: int, max_seq: int, dtype, *, cross_seq: int = 0):
    U = num_units(cfg)
    unit = init_unit_cache(cfg, batch, max_seq, dtype, cross_seq=cross_seq)
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (U,) + x.shape), unit)


# ---------------------------------------------------------------------------
# sublayer / unit application
# ---------------------------------------------------------------------------

def _apply_sublayer(p, x, cfg, rt, layer_idx: int, *, positions, pos,
                    cache: Optional[dict], memory=None, cross: bool = False,
                    causal: bool = True, window: int = 0):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    kind = cfg.layer_kind(layer_idx)
    h = L.apply_norm(cfg.norm, p["norm1"], x)
    if rt.act_inner_spec is not None:
        # Megatron-SP: norm runs on the seq-sharded residual; its output is
        # gathered HERE, once, for all qkv/mlp consumers (instead of XLA
        # re-gathering per projection)
        h = jax.lax.with_sharding_constraint(h, rt.act_inner_spec)
    new_cache: Dict[str, Any] = {}
    if kind == "ssm":
        if cache is not None and x.shape[1] == 1:
            mix, st = S.apply_ssm_step(p["ssm"], h, cfg, cache)
            new_cache = st
        elif cache is not None:
            mix, st = S.apply_ssm(p["ssm"], h, cfg, rt, state=cache)
            new_cache = st
        else:
            mix, _ = S.apply_ssm(p["ssm"], h, cfg, rt)
    else:
        attn_cache = None
        if cache is not None:
            attn_cache = {"k": cache["k"], "v": cache["v"], "pos": pos}
        mix, nc = L.self_attention(p["attn"], h, cfg, rt, positions=positions,
                                   causal=causal, window=window,
                                   cache=attn_cache,
                                   decode=(cache is not None and x.shape[1] == 1))
        if nc is not None:
            new_cache = {"k": nc["k"], "v": nc["v"]}
            if cache is not None and "ck" in cache:
                new_cache["ck"], new_cache["cv"] = cache["ck"], cache["cv"]
    x = x + mix
    if cross and (memory is not None or (cache is not None and "ck" in cache)):
        hx = L.apply_norm(cfg.norm, p["norm_x"], x)
        if cache is not None and memory is not None:
            # prefill: project the encoder memory once, store per-layer cross kv
            _, ck, cv = L.attention_qkv(p["cross"], hx, xkv=memory)
            new_cache["ck"] = ck.astype(cache["ck"].dtype)
            new_cache["cv"] = cv.astype(cache["cv"].dtype)
            x = x + L.cross_attention(p["cross"], hx, cfg, rt, mem_kv=(ck, cv))
        elif cache is not None:
            mem_kv = (cache["ck"], cache["cv"])
            x = x + L.cross_attention(p["cross"], hx, cfg, rt, mem_kv=mem_kv)
        else:
            x = x + L.cross_attention(p["cross"], hx, cfg, rt, memory=memory)
    if "norm2" in p:
        h2 = L.apply_norm(cfg.norm, p["norm2"], x)
        if rt.act_inner_spec is not None:
            h2 = jax.lax.with_sharding_constraint(h2, rt.act_inner_spec)
        y = jnp.zeros_like(x)
        if "moe" in p:
            ym, aux_m = M.apply_moe(p["moe"], h2, cfg, rt)
            y = y + ym
            aux = aux + aux_m
        if "mlp" in p:
            y = y + L.apply_mlp(p["mlp"], h2, cfg.mlp)
        x = x + y
    return x, new_cache, aux


def apply_unit(up, x, cfg, rt, *, positions, pos, cache=None, memory=None,
               cross: bool = False, causal: bool = True, window: int = 0):
    """Apply one scan unit (1..period sublayers). Returns (x, new_cache, aux)."""
    new_cache = {} if cache is not None else None
    aux = jnp.zeros((), jnp.float32)
    for i in range(unit_size(cfg)):
        key = f"l{i}"
        sub_cache = cache[key] if cache is not None else None
        x, nc, a = _apply_sublayer(
            up[key], x, cfg, rt, i, positions=positions, pos=pos,
            cache=sub_cache, memory=memory, cross=cross, causal=causal,
            window=window)
        if cache is not None:
            new_cache[key] = nc
        aux = aux + a
    return x, new_cache, aux


def scan_units(units_p, x, cfg, rt, *, positions, pos=None, cache=None,
               memory=None, cross: bool = False, causal: bool = True,
               window: int = 0):
    """lax.scan over stacked units. Returns (x, new_cache, aux_total)."""
    fn = functools.partial(apply_unit, cfg=cfg, rt=rt, positions=positions,
                           pos=pos, memory=memory, cross=cross, causal=causal,
                           window=window)

    def body(carry, xs):
        xc, aux = carry
        if rt.act_spec is not None:
            # sequence-parallel activations: the scan carry (the only stored
            # residual under remat) lives sharded on (batch, seq) — §Perf
            xc = jax.lax.with_sharding_constraint(xc, rt.act_spec)
        if cache is not None:
            up, uc = xs
            xc, nc, a = fn(up, xc, cache=uc)
        else:
            up = xs
            xc, nc, a = fn(up, xc, cache=None)
            nc = None
        return (xc, aux + a), nc

    if rt.remat:
        body = jax.checkpoint(body, prevent_cse=False)

    xs = (units_p, cache) if cache is not None else units_p
    (x, aux), new_cache = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, new_cache, aux
