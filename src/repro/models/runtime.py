"""Runtime (non-architectural) execution options.

``ArchConfig`` describes the *published* architecture; ``Runtime`` describes how we
execute it (attention implementation, chunk sizes, remat, sharding-oriented knobs).
Keeping them separate lets the perf loop flip execution strategy without touching
the architecture definition — and lets EXPERIMENTS.md record "paper-faithful
baseline" vs "optimized" as two Runtimes over the same ArchConfig.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Runtime:
    # attention
    attn_impl: str = "flash"      # "flash" (chunked online-softmax) | "plain"
    kv_chunk: int = 512           # flash kv-chunk length
    decode_window_only: bool = True  # decode with sliding window when cfg.sliding_window>0 and seq is long
    # memory
    remat: bool = True            # checkpoint each scanned block
    scan_layers: bool = True      # lax.scan over stacked layer params
    # moe
    moe_impl: str = "sort"        # "sort" (expert-parallel dispatch) | "dense" (all-experts; oracle)
    capacity_factor: float = 0.0  # 0 => take from cfg.moe.capacity_factor
    moe_expert_axis: object = None  # mesh axis for the [E,C,D] buffer's E dim
                                    # (forces all-to-all dispatch; §Perf)
    moe_token_axes: object = None   # mesh axes for the flattened token dim
    # ssm
    ssm_chunk: int = 0            # 0 => cfg.ssm.chunk
    # distribution hints (consumed by repro.distributed.sharding)
    seq_parallel: bool = False    # shard activation seq dim on "model" at block boundaries
    act_spec: object = None       # PartitionSpec applied to the scan carry at
                                  # every unit boundary (set by distributed.steps
                                  # when seq_parallel; needs a mesh context)
    act_inner_spec: object = None  # optional second constraint right after the
                                   # boundary one: storage stays seq-sharded but
                                   # compute sees one explicit gather per layer
                                   # (Megatron-SP AG-at-entry), instead of XLA
                                   # re-gathering x for every projection
    # kernels
    use_pallas: bool = False      # route hot ops through Pallas kernels (interpret on CPU)
    pallas_interpret: bool = True

    def cf(self, cfg) -> float:
        return self.capacity_factor or cfg.moe.capacity_factor

    def sschunk(self, cfg) -> int:
        return self.ssm_chunk or cfg.ssm.chunk


# The paper-era baseline: plain attention, dense-oracle MoE kept only for tests.
BASELINE = Runtime(attn_impl="plain", remat=True, moe_impl="sort")
DEFAULT = Runtime()
