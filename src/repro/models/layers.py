"""Core layers: init helpers, norms, RoPE, attention (flash + plain), MLP variants.

Everything is functional: ``init_*`` returns a params pytree (nested dicts of
jnp arrays); ``apply_*`` consumes it. No module classes, no global state.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            ).astype(dtype)


def zeros(shape, dtype):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(kind: str, d: int, dtype):
    if kind == "rmsnorm":
        return {"scale": ones((d,), dtype)}
    return {"scale": ones((d,), dtype), "bias": zeros((d,), dtype)}


def apply_norm(kind: str, p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding (partial rotation supported)
# ---------------------------------------------------------------------------

def rope_cos_sin(positions, rot_dim: int, theta: float):
    """positions [...,] -> cos/sin [..., rot_dim/2]."""
    half = rot_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, fraction: float, theta: float):
    """x [B, S, H, hd]; rotate the first ``fraction*hd`` dims (rounded to even)."""
    if fraction <= 0.0:
        return x
    hd = x.shape[-1]
    rot = int(hd * fraction) // 2 * 2
    if rot == 0:
        return x
    cos, sin = rope_cos_sin(positions, rot, theta)          # [B, S, rot/2]
    cos = cos[:, :, None, :].astype(jnp.float32)
    sin = sin[:, :, None, :].astype(jnp.float32)
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([rotated.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# attention masks
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, kv_pos, *, causal: bool, window: int, kv_valid_len=None):
    """Return additive bias [..., Sq, Skv] with NEG_INF at masked positions.

    q_pos [B?, Sq], kv_pos [Skv] (absolute positions).
    """
    qp = q_pos[..., :, None]
    kp = kv_pos[None, :]
    ok = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        ok &= kp <= qp
    if window and window > 0:
        ok &= kp > qp - window
    if kv_valid_len is not None:
        ok &= kp < kv_valid_len
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# plain attention (materializes scores) — the paper-era baseline, and the
# decode path (scores are [.., 1, Skv], cheap; sharded-Skv softmax lowers to
# the sequence-parallel all-reduce automatically).
# ---------------------------------------------------------------------------

def plain_attention(q, k, v, q_positions, kv_positions, *, causal: bool,
                    window: int = 0, kv_valid_len=None):
    """q [B,Sq,H,hd]; k,v [B,Skv,Kv,hd]; GQA via head grouping. Returns [B,Sq,H,hd]."""
    B, Sq, H, hd = q.shape
    Kv = k.shape[2]
    G = H // Kv
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, Kv, G, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    bias = _mask_bias(q_positions, kv_positions, causal=causal, window=window,
                      kv_valid_len=kv_valid_len)                 # [B?,Sq,Skv]
    scores = scores + bias[:, None, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# flash attention (chunked online softmax) with recompute backward.
# Memory-feasible form for 32k prefill / 4k train of the big dense archs.
# The Pallas kernel in repro.kernels.flash_attention mirrors this math.
# ---------------------------------------------------------------------------

def _flash_fwd(q, k, v, q_positions, kv_positions, kv_valid_len, *, causal,
               window, chunk):
    B, Sq, H, hd = q.shape
    Kv = k.shape[2]
    G = H // Kv
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    nchunk = -(-Skv // chunk)
    # pad kv to a multiple of chunk; padded slots masked off via kv_valid
    pad = nchunk * chunk - Skv
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kvpos = jnp.pad(kv_positions, (0, pad), constant_values=2**30)
    valid = Skv if kv_valid_len is None else kv_valid_len

    qg = q.reshape(B, Sq, Kv, G, hd).astype(jnp.float32)
    kc = kp.reshape(B, nchunk, chunk, Kv, hd)
    vc = vp.reshape(B, nchunk, chunk, Kv, hd)
    pc = kvpos.reshape(nchunk, chunk)

    def body(carry, xs):
        m, l, o = carry
        kch, vch, pch = xs
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, kch.astype(jnp.float32)) * scale
        bias = _mask_bias(q_positions, pch, causal=causal, window=window,
                          kv_valid_len=valid)                    # [B,Sq,chunk]
        s = s + bias[:, None, None, :, :]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_new = jnp.maximum(m_new, NEG_INF)                      # keep finite
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p, vch.astype(jnp.float32))
        return (m_new, l_new, o_new), None

    m0 = jnp.full((B, Kv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Kv, G, Sq), jnp.float32)
    o0 = jnp.zeros((B, Kv, G, Sq, hd), jnp.float32)
    (m, l, o), _ = jax.lax.scan(
        body, (m0, l0, o0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), pc))
    l_safe = jnp.where(l == 0.0, 1.0, l)
    out = (o / l_safe[..., None])
    lse = m + jnp.log(l_safe)
    out = jnp.moveaxis(out, -2, 1).reshape(B, Sq, H, hd).astype(q.dtype)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def flash_attention(q, k, v, q_positions, kv_positions, causal=True, window=0,
                    chunk=512):
    out, _ = _flash_fwd(q, k, v, q_positions, kv_positions, None,
                        causal=causal, window=window, chunk=chunk)
    return out


def _fa_fwd(q, k, v, q_positions, kv_positions, causal, window, chunk):
    out, lse = _flash_fwd(q, k, v, q_positions, kv_positions, None,
                          causal=causal, window=window, chunk=chunk)
    return out, (q, k, v, q_positions, kv_positions, out, lse)


def _fa_bwd(causal, window, chunk, res, dout):
    q, k, v, q_positions, kv_positions, out, lse = res
    B, Sq, H, hd = q.shape
    Kv = k.shape[2]
    G = H // Kv
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    nchunk = -(-Skv // chunk)
    pad = nchunk * chunk - Skv
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kvpos = jnp.pad(kv_positions, (0, pad), constant_values=2**30)

    qg = q.reshape(B, Sq, Kv, G, hd).astype(jnp.float32)
    dog = dout.reshape(B, Sq, Kv, G, hd).astype(jnp.float32)
    og = out.reshape(B, Sq, Kv, G, hd).astype(jnp.float32)
    dog_bk = jnp.moveaxis(dog, 1, 3)                              # [B,Kv,G,Sq,hd]
    # D_i = rowsum(dout * out)
    Drow = jnp.sum(dog * og, axis=-1)                             # [B,Sq,Kv,G]
    Drow = jnp.moveaxis(Drow, 1, 3)                               # [B,Kv,G,Sq]

    kc = jnp.moveaxis(kp.reshape(B, nchunk, chunk, Kv, hd), 1, 0)
    vc = jnp.moveaxis(vp.reshape(B, nchunk, chunk, Kv, hd), 1, 0)
    pc = kvpos.reshape(nchunk, chunk)

    def body(dq, xs):
        kch, vch, pch = xs
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, kch.astype(jnp.float32)) * scale
        bias = _mask_bias(q_positions, pch, causal=causal, window=window,
                          kv_valid_len=Skv)
        s = s + bias[:, None, None, :, :]
        p = jnp.exp(s - lse[..., None])                           # [B,Kv,G,Sq,c]
        dv_c = jnp.einsum("bkgqc,bkgqd->bckd", p, dog_bk)
        dp = jnp.einsum("bkgqd,bckd->bkgqc", dog_bk, vch.astype(jnp.float32))
        ds = p * (dp - Drow[..., None]) * scale
        dq = dq + jnp.einsum("bkgqc,bckd->bqkgd", ds, kch.astype(jnp.float32))
        dk_c = jnp.einsum("bkgqc,bqkgd->bckd", ds, qg)
        return dq, (dk_c, dv_c)

    dq0 = jnp.zeros((B, Sq, Kv, G, hd), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(body, dq0, (kc, vc, pc))
    dk = jnp.moveaxis(dk_c, 0, 1).reshape(B, nchunk * chunk, Kv, hd)[:, :Skv]
    dv = jnp.moveaxis(dv_c, 0, 1).reshape(B, nchunk * chunk, Kv, hd)[:, :Skv]
    dq = dq.reshape(B, Sq, H, hd)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            jnp.zeros_like(q_positions), jnp.zeros_like(kv_positions))


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ---------------------------------------------------------------------------
# attention module (projections + rope + cache plumbing)
# ---------------------------------------------------------------------------

def init_attention(key, cfg, dtype, *, cross: bool = False):
    D, H, Kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H, hd), dtype),
        "wk": dense_init(ks[1], (D, Kv, hd), dtype),
        "wv": dense_init(ks[2], (D, Kv, hd), dtype),
        "wo": dense_init(ks[3], (H, hd, D), dtype,
                         scale=0.02 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros((H, hd), dtype)
        p["bk"] = zeros((Kv, hd), dtype)
        p["bv"] = zeros((Kv, hd), dtype)
    return p


def attention_qkv(p, x, xkv=None):
    """Project. x [B,S,D] -> q [B,S,H,hd], k/v [B,Skv,Kv,hd]."""
    xkv = x if xkv is None else xkv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def attention_out(p, ctx):
    return jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])


def self_attention(p, x, cfg, rt, *, positions, causal=True, window=0,
                   cache=None, decode=False):
    """Full self-attention with optional KV cache.

    cache: dict(k [B,Smax,Kv,hd], v likewise, pos scalar int32) or None.
    decode: x is [B,1,D] at absolute position cache['pos'].
    Returns (out [B,S,D], new_cache).
    """
    q, k, v = attention_qkv(p, x)
    q = apply_rope(q, positions, cfg.rope_fraction, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_fraction, cfg.rope_theta)
    new_cache = None
    if cache is not None:
        pos = cache["pos"]
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, pos, 0, 0))
        new_cache = {"k": ck, "v": cv, "pos": pos + x.shape[1]}
        if x.shape[1] == 1:
            # decode: plain attention over the (possibly seq-sharded) cache;
            # the softmax over the sharded Skv dim lowers to the sequence-
            # parallel flash-decode all-reduces.
            kv_positions = jnp.arange(cache["k"].shape[1], dtype=jnp.int32)
            valid = pos + x.shape[1]
            out = plain_attention(q, ck, cv, positions, kv_positions,
                                  causal=causal, window=window,
                                  kv_valid_len=valid)
        else:
            # prefill (assumed to start at pos=0): flash over the fresh kv —
            # never materialize [Sq, Smax] scores against the padded cache.
            kv_positions = positions[0] if positions.ndim > 1 else positions
            out = flash_attention(q, k, v, positions, kv_positions, causal,
                                  window, min(rt.kv_chunk, k.shape[1]))
    else:
        kv_positions = positions[0] if positions.ndim > 1 else positions
        if rt.attn_impl == "flash" and not decode:
            out = flash_attention(q, k, v, positions, kv_positions, causal,
                                  window, min(rt.kv_chunk, k.shape[1]))
        else:
            out = plain_attention(q, k, v, positions, kv_positions,
                                  causal=causal, window=window)
    return attention_out(p, out), new_cache


def cross_attention(p, x, cfg, rt, *, memory=None, mem_kv=None):
    """Decoder->encoder attention. memory [B,Se,D] or precomputed (k,v)."""
    if mem_kv is None:
        _, k, v = attention_qkv(p, x, xkv=memory)
    else:
        k, v = mem_kv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    Sq = x.shape[1]
    Se = k.shape[1]
    qpos = jnp.zeros((x.shape[0], Sq), jnp.int32)
    kpos = jnp.arange(Se, dtype=jnp.int32)
    out = plain_attention(q, k, v, qpos, kpos, causal=False)
    return attention_out(p, out)


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, dtype, d_ff: int = 0):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    out_scale = 0.02 / math.sqrt(2 * max(cfg.n_layers, 1))
    if cfg.mlp == "swiglu":
        return {"wi": dense_init(ks[0], (D, F), dtype),
                "wg": dense_init(ks[1], (D, F), dtype),
                "wo": dense_init(ks[2], (F, D), dtype, scale=out_scale)}
    return {"wi": dense_init(ks[0], (D, F), dtype),
            "wo": dense_init(ks[2], (F, D), dtype, scale=out_scale)}


def apply_mlp(p, x, kind: str):
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    elif kind == "squared_relu":
        h = jnp.square(jax.nn.relu(x @ p["wi"]))
    else:  # gelu
        h = jax.nn.gelu(x @ p["wi"], approximate=True)
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# sinusoidal positions (whisper-style, computed on the fly)
# ---------------------------------------------------------------------------

def sinusoidal_positions(positions, d_model: int):
    half = d_model // 2
    freqs = jnp.exp(-math.log(10_000.0)
                    * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
