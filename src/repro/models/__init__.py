from repro.models.runtime import Runtime, DEFAULT, BASELINE  # noqa: F401
from repro.models.model import (  # noqa: F401
    init_params, forward, loss_fn, init_cache, prefill, decode_step,
    train_batch_spec, decode_spec, param_count,
)
