"""Top-level model API: init / loss / prefill / decode for every assigned family.

Batch conventions (all integer tokens int32):
- dense/moe/ssm/hybrid: {"tokens": [B, S+1]} — inputs tokens[:, :-1], labels [:, 1:].
- vlm:    {"patches": [B, P, D] (stubbed ViT output), "tokens": [B, S-P+1]}.
- encdec: {"frames": [B, Se, D] (stubbed conv/mel output), "tokens": [B, S+1]}.

Decode ("serve_step"): one token against a KV/SSM cache of ``max_seq``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models import layers as L
from repro.models import lstm as LSTM
from repro.models.runtime import Runtime, DEFAULT

Params = Dict[str, Any]


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg, key, dtype=None) -> Params:
    if cfg.family == "rnn":
        return LSTM.init_lstm_model(key, cfg, cfg.vocab)
    dt = jnp.dtype(dtype) if dtype is not None else _dtype(cfg)
    ks = jax.random.split(key, 6)
    V = cfg.padded_vocab
    p: Params = {
        "embed": L.dense_init(ks[0], (V, cfg.d_model), dt, scale=0.02),
        "final_norm": L.init_norm(cfg.norm, cfg.d_model, dt),
    }
    if cfg.family == "encdec":
        enc_cfg = cfg.replace(n_layers=cfg.n_encoder_layers, family="dense",
                              rope_fraction=0.0)
        p["encoder"] = B.init_stacked_units(ks[1], enc_cfg, dt)
        p["enc_norm"] = L.init_norm(cfg.norm, cfg.d_model, dt)
        p["decoder"] = B.init_stacked_units(ks[2], cfg, dt, cross=True)
    else:
        p["blocks"] = B.init_stacked_units(ks[1], cfg, dt)
    if not cfg.tie_embeddings:
        p["unembed"] = L.dense_init(ks[3], (cfg.d_model, V), dt, scale=0.02)
    return p


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _logits(cfg, p, x):
    w = p["embed"].T if cfg.tie_embeddings else p["unembed"]
    logits = (x.astype(jnp.float32) @ w.astype(jnp.float32))
    if cfg.padded_vocab != cfg.vocab:
        # mask the padding tail so the softmax matches the published vocab
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits


def _embed(cfg, p, tokens):
    return jnp.take(p["embed"], tokens, axis=0)


def _encode(cfg, rt, p, frames):
    """Whisper encoder over stubbed frame embeddings [B, Se, D]."""
    Se = frames.shape[1]
    pos = jnp.arange(Se, dtype=jnp.int32)
    x = frames + L.sinusoidal_positions(pos, cfg.d_model)[None].astype(frames.dtype)
    enc_cfg = cfg.replace(n_layers=cfg.n_encoder_layers, family="dense",
                          rope_fraction=0.0)
    positions = jnp.broadcast_to(pos[None], frames.shape[:2])
    x, _, _ = B.scan_units(p["encoder"], x, enc_cfg, rt, positions=positions,
                           causal=False)
    return L.apply_norm(cfg.norm, p["enc_norm"], x)


def forward(params, cfg, rt, batch, *, start_pos: int = 0
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. Returns (logits [B, St, V], aux)."""
    if cfg.family == "encdec":
        memory = _encode(cfg, rt, params, batch["frames"])
        tokens = batch["tokens"]
        x = _embed(cfg, params, tokens)
        pos = jnp.arange(tokens.shape[1], dtype=jnp.int32) + start_pos
        x = x + L.sinusoidal_positions(pos, cfg.d_model)[None].astype(x.dtype)
        positions = jnp.broadcast_to(pos[None], tokens.shape)
        x, _, aux = B.scan_units(params["decoder"], x, cfg, rt,
                                 positions=positions, memory=memory, cross=True)
    elif cfg.family == "vlm":
        tokens = batch["tokens"]
        xt = _embed(cfg, params, tokens)
        x = jnp.concatenate([batch["patches"].astype(xt.dtype), xt], axis=1)
        pos = jnp.arange(x.shape[1], dtype=jnp.int32) + start_pos
        positions = jnp.broadcast_to(pos[None], x.shape[:2])
        x, _, aux = B.scan_units(params["blocks"], x, cfg, rt,
                                 positions=positions)
        x = x[:, batch["patches"].shape[1]:]
    else:
        tokens = batch["tokens"]
        x = _embed(cfg, params, tokens)
        pos = jnp.arange(tokens.shape[1], dtype=jnp.int32) + start_pos
        positions = jnp.broadcast_to(pos[None], tokens.shape)
        x, _, aux = B.scan_units(params["blocks"], x, cfg, rt,
                                 positions=positions)
    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    return _logits(cfg, params, x), aux


def loss_fn(params, cfg, rt, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token CE (+ router aux). Returns (loss, metrics)."""
    if cfg.family == "rnn":
        ce = LSTM.lstm_loss(params, batch, use_pallas=rt.use_pallas,
                            interpret=rt.pallas_interpret)
        return ce, {"ce": ce}
    tokens = batch["tokens"]
    inp = dict(batch)
    inp["tokens"] = tokens[:, :-1]
    labels = tokens[:, 1:]
    logits, aux = forward(params, cfg, rt, inp)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(nll)
    loss = ce + cfg.moe.router_aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serve: prefill + single-token decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_seq: int, dtype=None):
    dt = jnp.dtype(dtype) if dtype is not None else _dtype(cfg)
    cross = cfg.encoder_seq if cfg.family == "encdec" else 0
    return B.init_cache(cfg, batch, max_seq, dt, cross_seq=cross)


def prefill(params, cfg, rt, batch, cache) -> Tuple[jnp.ndarray, Any]:
    """Run the prompt through the model, filling the cache from position 0.

    Returns (last-token logits [B, V], cache)."""
    if cfg.family == "encdec":
        memory = _encode(cfg, rt, params, batch["frames"])
        tokens = batch["tokens"]
        x = _embed(cfg, params, tokens)
        pos = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        x = x + L.sinusoidal_positions(pos, cfg.d_model)[None].astype(x.dtype)
        positions = jnp.broadcast_to(pos[None], tokens.shape)
        x, cache, _ = B.scan_units(params["decoder"], x, cfg, rt,
                                   positions=positions, pos=jnp.int32(0),
                                   cache=cache, memory=memory, cross=True)
    elif cfg.family == "vlm":
        xt = _embed(cfg, params, batch["tokens"])
        x = jnp.concatenate([batch["patches"].astype(xt.dtype), xt], axis=1)
        pos = jnp.arange(x.shape[1], dtype=jnp.int32)
        positions = jnp.broadcast_to(pos[None], x.shape[:2])
        x, cache, _ = B.scan_units(params["blocks"], x, cfg, rt,
                                   positions=positions, pos=jnp.int32(0),
                                   cache=cache)
    else:
        tokens = batch["tokens"]
        x = _embed(cfg, params, tokens)
        pos = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        positions = jnp.broadcast_to(pos[None], tokens.shape)
        x, cache, _ = B.scan_units(params["blocks"], x, cfg, rt,
                                   positions=positions, pos=jnp.int32(0),
                                   cache=cache)
    x = L.apply_norm(cfg.norm, params["final_norm"], x[:, -1:])
    return _logits(cfg, params, x)[:, 0], cache


def decode_step(params, cfg, rt, token, cache, pos
                ) -> Tuple[jnp.ndarray, Any]:
    """One decode step. token [B] int32; pos scalar int32 (absolute position).

    Uses the sliding-window mask for long-context dense archs when configured.
    Returns (logits [B, V], new_cache)."""
    x = _embed(cfg, params, token[:, None])
    positions = jnp.full((token.shape[0], 1), pos, jnp.int32)
    if cfg.family == "encdec":
        x = x + L.sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)
        stack, cross = params["decoder"], True
    else:
        stack, cross = params["blocks"], False
    window = cfg.sliding_window if (rt.decode_window_only and cfg.sliding_window)\
        else 0
    x, cache, _ = B.scan_units(stack, x, cfg, rt, positions=positions, pos=pos,
                               cache=cache, cross=cross, window=window)
    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    return _logits(cfg, params, x)[:, 0], cache


# ---------------------------------------------------------------------------
# batch specs (shared by smoke tests, dry-run, data pipeline)
# ---------------------------------------------------------------------------

def train_batch_spec(cfg, shape) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for one global training batch of the given InputShape."""
    Bsz, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return {"frames": jax.ShapeDtypeStruct((Bsz, cfg.encoder_seq, cfg.d_model),
                                               _dtype(cfg)),
                "tokens": jax.ShapeDtypeStruct((Bsz, S + 1), jnp.int32)}
    if cfg.family == "vlm":
        St = S - cfg.vision_prefix
        return {"patches": jax.ShapeDtypeStruct((Bsz, cfg.vision_prefix,
                                                 cfg.d_model), _dtype(cfg)),
                "tokens": jax.ShapeDtypeStruct((Bsz, St + 1), jnp.int32)}
    return {"tokens": jax.ShapeDtypeStruct((Bsz, S + 1), jnp.int32)}


def decode_spec(cfg, shape):
    """(token, pos) specs for serve_step."""
    return (jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32))


# ---------------------------------------------------------------------------
# parameter counting (used by roofline MODEL_FLOPS and the simulator cost model)
# ---------------------------------------------------------------------------

def param_count(cfg, active_only: bool = False) -> int:
    if cfg.family == "rnn":
        cfg = cfg if cfg.vocab else cfg.replace(vocab=96)
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = 1
        for d in leaf.shape:
            n *= d
        if active_only and any(getattr(k, "key", None) == "experts"
                               for k in path):
            m = cfg.moe
            n = n * (m.top_k / max(m.num_experts, 1))
        total += n
    return int(total)
