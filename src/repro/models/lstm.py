"""The paper's workload: stacked-LSTM next-char model (tfjs lstm_text_generation).

Input: one-hot chars [B, sample_len, vocab] (the tfjs example feeds one-hot, no
embedding). Two stacked LSTM layers of ``cfg.d_model`` cells, dense softmax head
over the vocabulary, categorical cross-entropy on the next char. Keras/TF gate
order (i, f, c, o) and unit forget-gate bias, matching TensorFlow.js semantics.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, zeros


def init_lstm_model(key, cfg, vocab: int, dtype=jnp.float32):
    H = cfg.d_model
    n_layers = cfg.n_layers
    ks = jax.random.split(key, n_layers + 1)
    layers = []
    d_in = vocab
    for i in range(n_layers):
        kk, kr = jax.random.split(ks[i])
        # glorot for input kernel, orthogonal-ish (scaled normal) for recurrent
        kernel = dense_init(kk, (d_in + H, 4 * H), dtype,
                            scale=(2.0 / (d_in + 4 * H)) ** 0.5)
        bias = zeros((4 * H,), dtype)
        # unit forget bias (keras default)
        bias = bias.at[H:2 * H].set(1.0)
        layers.append({"kernel": kernel, "bias": bias})
        d_in = H
    head = {"w": dense_init(ks[-1], (H, vocab), dtype,
                            scale=(2.0 / (H + vocab)) ** 0.5),
            "b": zeros((vocab,), dtype)}
    return {"layers": layers, "head": head}


def lstm_cell(p, x, hc, *, use_pallas: bool = False, interpret: bool = True):
    """One step. x [B, d_in]; hc = (h [B,H], c [B,H])."""
    h, c = hc
    if use_pallas:
        from repro.kernels.ops import lstm_cell as pallas_cell
        return pallas_cell(x, h, c, p["kernel"], p["bias"], interpret=interpret)
    z = jnp.concatenate([x, h], axis=-1) @ p["kernel"] + p["bias"]
    i, f, g, o = jnp.split(z, 4, axis=-1)
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def apply_lstm_model(params, onehot, *, use_pallas: bool = False,
                     interpret: bool = True):
    """onehot [B, T, V] -> next-char logits [B, V]."""
    B = onehot.shape[0]
    x_seq = jnp.moveaxis(onehot, 1, 0)                        # [T, B, V]
    for lp in params["layers"]:
        H = lp["kernel"].shape[1] // 4
        h0 = jnp.zeros((B, H), onehot.dtype)
        c0 = jnp.zeros((B, H), onehot.dtype)

        def step(hc, x):
            h_new, c_new = lstm_cell(lp, x, hc, use_pallas=use_pallas,
                                     interpret=interpret)
            return (h_new, c_new), h_new

        (_, _), hs = jax.lax.scan(step, (h0, c0), x_seq)
        x_seq = hs                                            # [T, B, H]
    last = x_seq[-1]                                          # [B, H]
    return last @ params["head"]["w"] + params["head"]["b"]


def lstm_loss(params, batch, *, use_pallas: bool = False, interpret: bool = True):
    """batch: {"x": one-hot [B,T,V], "y": int labels [B]} -> mean CE (nats)."""
    logits = apply_lstm_model(params, batch["x"], use_pallas=use_pallas,
                              interpret=interpret)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["y"][:, None], axis=-1)[:, 0]
    return jnp.mean(nll)
