"""Gradient compression for the paper's bandwidth bottleneck (§III / §VI).

The paper identifies gradient-synchronization bandwidth as the central threat to
validity and cites the standard fixes; we implement both as first-class,
invertible codecs with error feedback:

- ``topk``    — magnitude sparsification (Aji & Heafield 2017): keep the k largest
  |g| entries per tensor; residual is fed back next step.
- ``ternary`` — TernGrad (Wen et al. 2017): g -> s * sign(g) * b, b ~ Bernoulli
  (|g|/s) with s = max|g| (deterministic threshold variant also available for
  reproducibility).

Codecs operate leaf-wise on gradient pytrees and report exact wire byte counts,
which both the L1 simulator (network model) and ``benchmarks/compression.py``
consume. ``EFState`` carries the error-feedback residual.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# codecs (encode returns (payload pytree, nbytes); decode returns dense grads)
# ---------------------------------------------------------------------------

def _leaf_bytes(x) -> int:
    return int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize


def _is_payload(x) -> bool:
    """Payload-dict leaf marker (grads trees are dicts too, so a bare
    isinstance check would stop tree traversal at the root)."""
    return isinstance(x, dict) and "shape" in x and ("t" in x or "idx" in x)


def topk_encode(g, fraction: float):
    """Keep ceil(fraction * n) largest-|g| entries. Returns (payload, nbytes)."""
    def enc(leaf):
        flat = leaf.reshape(-1)
        n = flat.shape[0]
        k = max(int(np.ceil(fraction * n)), 1)
        vals, idx = jax.lax.top_k(jnp.abs(flat), k)
        kept = flat[idx]
        return {"idx": idx.astype(jnp.int32), "val": kept, "shape": leaf.shape}
    payload = jax.tree.map(enc, g, is_leaf=lambda x: hasattr(x, "shape"))
    nbytes = sum(_leaf_bytes(p["idx"]) + _leaf_bytes(p["val"])
                 for p in jax.tree.leaves(payload,
                                          is_leaf=_is_payload))
    return payload, nbytes


def topk_decode(payload):
    def dec(p):
        n = int(np.prod(p["shape"]))
        flat = jnp.zeros((n,), p["val"].dtype)
        flat = flat.at[p["idx"]].set(p["val"])
        return flat.reshape(p["shape"])
    return jax.tree.map(dec, payload, is_leaf=_is_payload)


def ternary_encode(g, key=None):
    """TernGrad: per-leaf scale s=max|g|, stochastic ternarization to {-1,0,1}.

    Deterministic when key is None: b = 1 iff |g| >= s/2 (threshold variant).
    Wire format: 2 bits/element (packed 4/elem byte here for simplicity of
    accounting: ceil(n/4) bytes) + one fp32 scale."""
    leaves, treedef = jax.tree.flatten(g)
    keys = (jax.random.split(key, len(leaves)) if key is not None
            else [None] * len(leaves))

    def enc(leaf, k):
        s = jnp.max(jnp.abs(leaf)).astype(jnp.float32)
        s = jnp.maximum(s, 1e-12)
        prob = jnp.abs(leaf.astype(jnp.float32)) / s
        if k is None:
            b = (prob >= 0.5).astype(jnp.int8)
        else:
            b = (jax.random.uniform(k, leaf.shape) < prob).astype(jnp.int8)
        t = jnp.sign(leaf).astype(jnp.int8) * b
        return {"t": t, "s": s, "shape": leaf.shape}

    payload = treedef.unflatten([enc(l, k) for l, k in zip(leaves, keys)])
    nbytes = sum(-(-int(np.prod(p["shape"])) // 4) + 4
                 for p in jax.tree.leaves(payload,
                                          is_leaf=_is_payload))
    return payload, nbytes


def ternary_decode(payload):
    return jax.tree.map(lambda p: p["t"].astype(jnp.float32) * p["s"],
                        payload, is_leaf=_is_payload)


def dense_bytes(g) -> int:
    return sum(_leaf_bytes(x) for x in jax.tree.leaves(g))


# ---------------------------------------------------------------------------
# error feedback wrapper
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Codec:
    name: str
    encode: Callable  # (grads) -> (payload, nbytes)
    decode: Callable  # (payload) -> grads


def make_codec(name: str, **kw) -> Codec:
    if name == "none":
        return Codec("none", lambda g: (g, dense_bytes(g)), lambda p: p)
    if name == "topk":
        frac = kw.get("fraction", 0.01)
        return Codec(f"topk({frac})",
                     lambda g: topk_encode(g, frac), topk_decode)
    if name == "ternary":
        return Codec("ternary", lambda g: ternary_encode(g), ternary_decode)
    raise KeyError(name)


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress(codec: Codec, grads, residual):
    """Error feedback: compress (g + residual); carry the quantization error.

    Returns (decoded_grads, new_residual, nbytes)."""
    corrected = jax.tree.map(
        lambda g, r: g.astype(jnp.float32) + r, grads, residual)
    payload, nbytes = codec.encode(corrected)
    decoded = codec.decode(payload)
    new_residual = jax.tree.map(lambda c, d: c - d.astype(jnp.float32),
                                corrected, decoded)
    return decoded, new_residual, nbytes
