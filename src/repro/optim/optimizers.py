"""Optimizers (optax-style pure functions, implemented from scratch).

RMSprop follows TF/Keras semantics exactly — the paper trains with tfjs's
``train.rmsprop(learningRate=0.1)`` defaults (rho=0.9, eps=1e-7, no momentum):

    ms <- rho * ms + (1 - rho) * g^2
    w  <- w - lr * g / (sqrt(ms) + eps)

Note Keras adds eps *outside* the sqrt; we match that (it matters at lr=0.1).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any
State = Any


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], State]
    update: Callable[[Params, State, Params], Tuple[Params, State]]
    name: str = "opt"

    def apply(self, params, state, grads):
        """Returns (new_params, new_state)."""
        return self.update(params, state, grads)


def _tmap(f, *trees, is_leaf=None):
    return jax.tree.map(f, *trees, is_leaf=is_leaf)


def rmsprop(lr: float, rho: float = 0.9, eps: float = 1e-7,
            state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        return {"ms": _tmap(lambda p: jnp.zeros(p.shape, state_dtype), params),
                "step": jnp.zeros((), jnp.int32)}

    def update(params, state, grads):
        def upd(p, m, g):
            g32 = g.astype(state_dtype)
            m_new = rho * m + (1.0 - rho) * jnp.square(g32)
            step = p.astype(state_dtype) - lr * g32 / (jnp.sqrt(m_new) + eps)
            return step.astype(p.dtype), m_new
        flat = _tmap(upd, params, state["ms"], grads)
        new_p = _tmap(lambda pair: pair[0], flat,
                      is_leaf=lambda x: isinstance(x, tuple))
        new_m = _tmap(lambda pair: pair[1], flat,
                      is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"ms": new_m, "step": state["step"] + 1}

    return Optimizer(init, update, f"rmsprop(lr={lr})")


def sgd(lr: float, momentum: float = 0.0, state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {"step": jnp.zeros((), jnp.int32)}
        return {"mu": _tmap(lambda p: jnp.zeros(p.shape, state_dtype), params),
                "step": jnp.zeros((), jnp.int32)}

    def update(params, state, grads):
        if momentum == 0.0:
            new_p = _tmap(lambda p, g: (p.astype(jnp.float32)
                                        - lr * g.astype(jnp.float32)
                                        ).astype(p.dtype), params, grads)
            return new_p, {"step": state["step"] + 1}

        def upd(p, mu, g):
            mu_new = momentum * mu + g.astype(state_dtype)
            return (p.astype(state_dtype) - lr * mu_new).astype(p.dtype), mu_new
        flat = _tmap(upd, params, state["mu"], grads)
        new_p = _tmap(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_mu = _tmap(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"mu": new_mu, "step": state["step"] + 1}

    return Optimizer(init, update, f"sgd(lr={lr},m={momentum})")


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"m": _tmap(z, params), "v": _tmap(z, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(params, state, grads):
        step = state["step"] + 1
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v, g):
            g32 = g.astype(state_dtype)
            m_new = b1 * m + (1 - b1) * g32
            v_new = b2 * v + (1 - b2) * jnp.square(g32)
            upd_ = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
            p32 = p.astype(state_dtype)
            p_new = p32 - lr * (upd_ + weight_decay * p32)
            return p_new.astype(p.dtype), m_new, v_new
        flat = _tmap(upd, params, state["m"], state["v"], grads)
        pick = lambda i: _tmap(lambda t: t[i], flat,
                               is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"m": pick(1), "v": pick(2), "step": step}

    return Optimizer(init, update, f"adamw(lr={lr})")


REGISTRY = {"rmsprop": rmsprop, "sgd": sgd, "adamw": adamw}


def make(name: str, lr: float, **kw) -> Optimizer:
    return REGISTRY[name](lr, **kw)
