from repro.optim.optimizers import Optimizer, rmsprop, sgd, adamw, make  # noqa: F401
from repro.optim.compression import (  # noqa: F401
    Codec, make_codec, ef_init, ef_compress, dense_bytes,
)
