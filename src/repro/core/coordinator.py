"""Coordinator — REAL execution of the JSDoop protocol, in process.

K volunteer state machines are interleaved round-robin over the shared
QueueServer/DataServer, actually computing gradients and RMSprop updates with
JAX. The logical clock is the scheduler iteration count (used for visibility
timeouts). Churn is injected as (step, kind, arg) events: 'leave'/'join' of a
volunteer (a leaving volunteer's leased tasks requeue, exactly like closing
the browser tab mid-task), and — when running on a ShardedQueueServer —
'add_shard'/'remove_shard' membership changes, which rebalance the federation
live (queues migrate with their full state; see queue.ShardedQueueServer).

Waiting is event-driven, on the same primitives the Simulator uses: a
volunteer that would block (empty task queue, unpublished model version, or an
unfilled reduce barrier) registers a subscription/watcher and is skipped by
the scheduler until woken. When every volunteer is blocked the logical clock
fast-forwards to the next churn event or visibility deadline instead of
spinning — no step ever busy-polls.

This is the engine behind the paper's invariance claim tests: the final model
must bit-match ``sequential_accumulated`` for ANY worker count and ANY churn.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core.dataserver import DataServer
from repro.core.initiator import enqueue_problem
from repro.core.mapreduce import TrainingProblem
from repro.core.queue import QueueServer, ShardedQueueServer
from repro.core.tasks import (GradResult, INITIAL_QUEUE, MapTask, ReduceTask,
                              results_queue)
from repro.optim.compression import Codec, ef_init, ef_compress


@dataclass
class _Volunteer:
    vid: str
    tag: Optional[int] = None
    task: Any = None
    ef_residual: Any = None     # error-feedback state (when codec is set)
    blocked: bool = False       # waiting on a subscription/watcher wake

    @property
    def busy(self) -> bool:
        return self.task is not None


@dataclass
class RunResult:
    params: Any
    opt_state: Any
    losses: List[float]                   # mean map loss per version
    steps: int
    tasks_by_worker: Dict[str, int]
    requeues: int
    final_version: int


class Coordinator:
    def __init__(self, problem: TrainingProblem, n_workers: int, *,
                 n_versions: Optional[int] = None,
                 churn: Optional[List[Tuple[int, str, str]]] = None,
                 visibility_timeout: float = float("inf"),
                 codec: Optional[Codec] = None, n_shards: int = 1):
        self.problem = problem
        self.qs: Union[QueueServer, ShardedQueueServer] = (
            QueueServer(default_timeout=visibility_timeout) if n_shards <= 1
            else ShardedQueueServer(n_shards,
                                    default_timeout=visibility_timeout))
        self.ds = DataServer()
        self.n_versions = n_versions if n_versions is not None else problem.n_versions
        enqueue_problem(problem, self.qs, self.ds, n_versions=self.n_versions)
        self.volunteers: Dict[str, _Volunteer] = {
            f"w{i}": _Volunteer(f"w{i}") for i in range(n_workers)}
        self.churn = sorted(churn or [])
        self.codec = codec
        self.version_losses: Dict[int, List[float]] = {}
        self.tasks_done: Dict[str, int] = {}
        self.bytes_sent = 0

    # ------------------------------------------------------------------ engine
    def _unblock(self, vid: str):
        """Subscription/watcher wake: mark the volunteer runnable. A wake for a
        departed volunteer passes the event on so no wakeup is lost."""
        v = self.volunteers.get(vid)
        if v is not None:
            v.blocked = False
        else:
            self.qs.kick(INITIAL_QUEUE)

    def _block_on_queue(self, v: _Volunteer, qname: str, *, kind: str = "any"):
        v.blocked = True
        self.qs.subscribe(qname, v.vid, lambda: self._unblock(v.vid),
                          kind=kind)

    def _block_on_version(self, v: _Volunteer, version: int):
        v.blocked = True
        self.ds.watch_version(version, lambda: self._unblock(v.vid))

    def run(self, max_steps: int = 2_000_000) -> RunResult:
        step = 0
        churn_i = 0
        while self.ds.latest_version < self.n_versions:
            if step >= max_steps:
                raise RuntimeError("coordinator did not converge (deadlock?)")
            # churn events
            while churn_i < len(self.churn) and self.churn[churn_i][0] <= step:
                _, kind, vid = self.churn[churn_i]
                churn_i += 1
                if kind == "leave" and vid in self.volunteers:
                    self.qs.unsubscribe(vid)
                    self.qs.drop_consumer(vid)
                    del self.volunteers[vid]
                elif kind == "join" and vid not in self.volunteers:
                    self.volunteers[vid] = _Volunteer(vid)
                elif kind == "add_shard" and \
                        isinstance(self.qs, ShardedQueueServer):
                    self.qs.add_shard()
                elif kind == "remove_shard" and \
                        isinstance(self.qs, ShardedQueueServer) and \
                        len(self.qs.shards) > 1:
                    self.qs.remove_shard(int(vid) % len(self.qs.shards))
            if not self.volunteers:
                # everyone left; semantically the problem just pauses (paper:
                # "If no one is collaborating, the problem simply stops").
                if churn_i >= len(self.churn):
                    raise RuntimeError("no volunteers and no future joins")
                step = max(step + 1, self.churn[churn_i][0])
                continue
            # O(expired): expire_all self-gates on the server's lazy deadline
            # index and returns immediately while nothing is due
            self.qs.expire_all(step)
            ran_any = False
            for vid in list(self.volunteers):
                v = self.volunteers.get(vid)
                if v is not None and not v.blocked:
                    self._step_volunteer(v, step)
                    ran_any = True
            if ran_any:
                step += 1
                continue
            # every volunteer is waiting on a wake: jump the logical clock to
            # the next external event (churn or a visibility-timeout expiry)
            # instead of spinning through empty steps
            candidates = []
            if churn_i < len(self.churn):
                candidates.append(self.churn[churn_i][0])
            dl = self.qs.next_deadline()
            if dl is not None and math.isfinite(dl):
                candidates.append(int(math.ceil(dl)))
            if not candidates:
                raise RuntimeError(
                    "coordinator deadlock: all volunteers blocked with no "
                    "pending churn or visibility deadline")
            step = max(step + 1, min(candidates))
        params, opt_state = self.ds.get_model(self.ds.latest_version)
        losses = [float(np.mean(self.version_losses[k]))
                  for k in sorted(self.version_losses)]
        return RunResult(params, opt_state, losses, step, dict(self.tasks_done),
                         self.qs.total_requeued, self.ds.latest_version)

    # ------------------------------------------------------------------ protocol
    def _step_volunteer(self, v: _Volunteer, now: float):
        if not v.busy:
            got = self.qs.lease(INITIAL_QUEUE, v.vid, now)
            if got is None:
                # task queue empty: sleep until a publish or requeue
                self._block_on_queue(v, INITIAL_QUEUE)
                return
            v.tag, v.task = got
        if isinstance(v.task, MapTask):
            self._try_map(v, now)
        else:
            self._try_reduce(v, now)

    def _try_map(self, v: _Volunteer, now: float):
        t: MapTask = v.task
        if self.ds.latest_version > t.version:
            # obsolete duplicate (we were requeued after someone else's result
            # was already reduced) — ack without compute, at-least-once + idempotent
            self.qs.ack(INITIAL_QUEUE, v.tag)
            v.tag = v.task = None
            return
        blob = self.ds.get_model(t.version, nbytes=self.problem.model_bytes)
        if blob is None:
            # model version not published yet: stay leased, wake on publish
            self._block_on_version(v, t.version)
            return
        params, _ = blob
        grads, loss = self.problem.map_compute(params, t.version, t.mb_index)
        nbytes = self.problem.grad_bytes
        if self.codec is not None:
            if v.ef_residual is None:
                v.ef_residual = ef_init(self.problem.params0)
            grads, v.ef_residual, nbytes = ef_compress(self.codec, grads,
                                                       v.ef_residual)
        self.bytes_sent += nbytes
        self.qs.publish(results_queue(t.version),
                        GradResult(t.version, t.mb_index, grads, nbytes, loss,
                                   v.vid))
        self.qs.ack(INITIAL_QUEUE, v.tag)
        self.tasks_done[v.vid] = self.tasks_done.get(v.vid, 0) + 1
        self.version_losses.setdefault(t.version, []).append(loss)
        v.tag = v.task = None

    def _try_reduce(self, v: _Volunteer, now: float):
        t: ReduceTask = v.task
        if self.ds.latest_version > t.version:
            self.qs.ack(INITIAL_QUEUE, v.tag)  # duplicate reduce, already applied
            v.tag = v.task = None
            return
        rq = results_queue(t.version)
        if self.qs.depth(rq) < t.n_mb:
            # barrier not reached: wake on the next result publish (requeues —
            # including our own nacks below — must not wake the barrier)
            self._block_on_queue(v, rq, kind="publish")
            return
        grads_by_mb: Dict[int, Any] = {}
        tags: List[int] = []
        while True:
            got = self.qs.lease(rq, v.vid, now)
            if got is None:
                break
            tag, res = got
            tags.append(tag)
            grads_by_mb.setdefault(res.mb_index, res.payload)  # dedup by mb
        if len(grads_by_mb) < t.n_mb:
            for tag in tags:
                self.qs.nack(rq, tag)
            self._block_on_queue(v, rq, kind="publish")
            return
        params, opt_state = self.ds.get_model(t.version,
                                              nbytes=self.problem.model_bytes)
        params, opt_state = self.problem.reduce_compute(params, opt_state,
                                                        grads_by_mb)
        self.ds.publish_model(t.version + 1, (params, opt_state),
                              nbytes=self.problem.model_bytes)
        self.ds.gc_models(keep_last=2)
        for tag in tags:
            self.qs.ack(rq, tag)
        self.qs.ack(INITIAL_QUEUE, v.tag)
        self.tasks_done[v.vid] = self.tasks_done.get(v.vid, 0) + 1
        self.bytes_sent += self.problem.model_bytes
        v.tag = v.task = None
