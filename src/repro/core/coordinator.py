"""Coordinator — REAL execution of the JSDoop protocol, in process.

K volunteers are interleaved round-robin, actually computing gradients and
RMSprop updates with JAX. Each volunteer is a ``protocol.VolunteerSession`` —
the sans-IO state machine owning every protocol rule (lease, model-version
wait, reduce barrier, duplicate ack, requeue) — speaking typed messages to the
QueueServer/DataServer through a ``transport`` ("inproc" for direct zero-copy
calls, "wire" to round-trip every message through canonical bytes; either way
the final model is identical). The Coordinator itself owns only engine policy:
the logical clock (scheduler iteration count, used for visibility timeouts),
real compute + gradient compression, and churn.

Churn is injected as (step, kind, arg) events: 'leave'/'join' of a volunteer
(a leaving volunteer Byes — its leased tasks requeue, exactly like closing the
browser tab mid-task), and — when running on a ShardedQueueServer —
'add_shard'/'remove_shard' membership changes, which rebalance the federation
live (queues migrate with their full state; see queue.ShardedQueueServer).

Waiting is event-driven: a session that reports ``Blocked`` subscribes (a
``Wake``/``VersionReady`` notification message un-blocks it) and is skipped by
the scheduler until woken. When every volunteer is blocked the logical clock
fast-forwards to the next churn event or visibility deadline instead of
spinning — no step ever busy-polls.

This is the engine behind the paper's invariance claim tests: the final model
must bit-match ``sequential_accumulated`` for ANY worker count, ANY churn, and
ANY transport — and, per aggregation policy (``policy=``), each barrierless
policy's sequential reference (``sequential_async`` / ``sequential_local``):
the round-robin scheduler serializes barrierless tickets, so worker count
cannot change the float stream.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core.aggregation import PolicyLike, make_policy
from repro.core.dataserver import DataServer
from repro.core.initiator import enqueue_problem
from repro.core.mapreduce import TrainingProblem
from repro.core.protocol import (Blocked, KickQueue, LocalWork, MapWork,
                                 NoTask, ReduceWork, ServerEndpoint, TaskDone,
                                 VolunteerSession)
from repro.core.queue import QueueServer, ShardedQueueServer, VirtualClock
from repro.core.tasks import INITIAL_QUEUE
from repro.core.transport import make_transport
from repro.optim.compression import Codec, ef_init, ef_compress


@dataclass
class _Volunteer:
    vid: str
    sess: VolunteerSession
    ef_residual: Any = None     # error-feedback state (when codec is set)
    blocked: bool = False       # waiting on a Wake/VersionReady notification


@dataclass
class RunResult:
    params: Any
    opt_state: Any
    losses: List[float]                   # mean map loss per version
    steps: int
    tasks_by_worker: Dict[str, int]
    requeues: int
    final_version: int
    stale_discards: int = 0               # barrierless results refused as stale
    policy: str = "sync"


class Coordinator:
    def __init__(self, problem: TrainingProblem, n_workers: int, *,
                 n_versions: Optional[int] = None,
                 churn: Optional[List[Tuple[int, str, str]]] = None,
                 visibility_timeout: float = float("inf"),
                 codec: Optional[Codec] = None, n_shards: int = 1,
                 transport: Union[str, Callable, None] = "inproc",
                 policy: PolicyLike = None,
                 placement: Optional[Callable[[str], str]] = None):
        self.problem = problem
        self.policy = make_policy(policy)
        self.qs: Union[QueueServer, ShardedQueueServer] = (
            QueueServer(default_timeout=visibility_timeout) if n_shards <= 1
            else ShardedQueueServer(n_shards,
                                    default_timeout=visibility_timeout,
                                    placement=placement))
        self.ds = DataServer()
        # lease-time authority: the endpoint stamps leases with the engine's
        # logical clock (mirrors the scheduler's step counter — identical to
        # the client-supplied now, so runs stay bit-identical)
        self._step = 0
        self.endpoint = ServerEndpoint(self.qs, self.ds,
                                       clock=VirtualClock(lambda: self._step))
        self.port = make_transport(transport, self.endpoint)
        self.port.set_deliver(self._on_notify)
        self.n_versions = n_versions if n_versions is not None else problem.n_versions
        # the run's commit target: the policy maps BSP rounds to versions
        # (sync: 1 per round; async: 1 per gradient; local: 1 per k steps)
        self.n_updates = self.policy.n_updates(problem, self.n_versions)
        enqueue_problem(problem, self.qs, self.ds, n_versions=self.n_versions,
                        policy=self.policy)
        self.volunteers: Dict[str, _Volunteer] = {
            f"w{i}": self._make_volunteer(f"w{i}") for i in range(n_workers)}
        self.churn = sorted(churn or [])
        self.codec = codec
        self.version_losses: Dict[int, List[float]] = {}
        self.tasks_done: Dict[str, int] = {}
        self.bytes_sent = 0
        self.stale_discards = 0

    def _make_volunteer(self, vid: str) -> _Volunteer:
        return _Volunteer(vid, VolunteerSession(
            vid, self.port, model_nbytes=self.problem.model_bytes,
            policy=self.policy))

    # ------------------------------------------------------------------ engine
    def _on_notify(self, vid: str, msg) -> None:
        """Notification sink: mark the volunteer runnable. A wake for a
        departed volunteer is passed on so no wakeup is lost."""
        v = self.volunteers.get(vid)
        if v is not None:
            v.blocked = False
        else:
            self.port.call(KickQueue(INITIAL_QUEUE))

    def run(self, max_steps: int = 2_000_000) -> RunResult:
        step = 0
        churn_i = 0
        while self.ds.latest_version < self.n_updates:
            self._step = step              # keep the lease clock in sync
            if step >= max_steps:
                raise RuntimeError("coordinator did not converge (deadlock?)")
            # churn events
            while churn_i < len(self.churn) and self.churn[churn_i][0] <= step:
                _, kind, vid = self.churn[churn_i]
                churn_i += 1
                if kind == "leave" and vid in self.volunteers:
                    self.volunteers[vid].sess.bye()
                    del self.volunteers[vid]
                elif kind == "join" and vid not in self.volunteers:
                    self.volunteers[vid] = self._make_volunteer(vid)
                elif kind == "add_shard" and \
                        isinstance(self.qs, ShardedQueueServer):
                    self.qs.add_shard()
                elif kind == "remove_shard" and \
                        isinstance(self.qs, ShardedQueueServer) and \
                        len(self.qs.shards) > 1:
                    self.qs.remove_shard(int(vid) % len(self.qs.shards))
            if not self.volunteers:
                # everyone left; semantically the problem just pauses (paper:
                # "If no one is collaborating, the problem simply stops").
                if churn_i >= len(self.churn):
                    raise RuntimeError("no volunteers and no future joins")
                step = max(step + 1, self.churn[churn_i][0])
                continue
            # O(expired): expire_all self-gates on the server's lazy deadline
            # index and returns immediately while nothing is due
            self.qs.expire_all(step)
            ran_any = False
            for vid in list(self.volunteers):
                v = self.volunteers.get(vid)
                if v is not None and not v.blocked:
                    self._step_volunteer(v, step)
                    ran_any = True
            if ran_any:
                step += 1
                continue
            # every volunteer is waiting on a wake: jump the logical clock to
            # the next external event (churn or a visibility-timeout expiry)
            # instead of spinning through empty steps
            candidates = []
            if churn_i < len(self.churn):
                candidates.append(self.churn[churn_i][0])
            dl = self.qs.next_deadline()
            if dl is not None and math.isfinite(dl):
                candidates.append(int(math.ceil(dl)))
            if not candidates:
                raise RuntimeError(
                    "coordinator deadlock: all volunteers blocked with no "
                    "pending churn or visibility deadline")
            step = max(step + 1, min(candidates))
        params, opt_state = self.ds.get_model(self.ds.latest_version)
        losses = [float(np.mean(self.version_losses[k]))
                  for k in sorted(self.version_losses)]
        return RunResult(params, opt_state, losses, step, dict(self.tasks_done),
                         self.qs.total_requeued, self.ds.latest_version,
                         self.stale_discards, self.policy.spec)

    # ------------------------------------------------------------------ compute
    def _step_volunteer(self, v: _Volunteer, now: float):
        """One scheduler slice: drive the session one protocol move; answer
        MapWork/ReduceWork with real JAX compute."""
        sess = v.sess
        if sess.task is None:
            if isinstance(sess.lease(now), NoTask):
                v.blocked = True
                sess.subscribe_idle()      # sleep until a publish or requeue
                return
        out = sess.advance(now)
        if isinstance(out, Blocked):
            v.blocked = True
            sess.subscribe(out)
            return
        if isinstance(out, TaskDone):      # obsolete duplicate, acked
            return
        if isinstance(out, MapWork):
            if self.policy.barrier:
                self._do_map(v, out)
            else:
                self._do_async(v, out)
        elif isinstance(out, ReduceWork):
            self._do_reduce(v, out)
        elif isinstance(out, LocalWork):
            self._do_local(v, out)
        else:
            # Busy is unreachable here (compute is synchronous, so nothing
            # can redeliver a wake mid-task) — keep the invariant loud
            raise RuntimeError(f"{v.vid}: unexpected session outcome {out!r}")

    def _compute_grads(self, v: _Volunteer, params, version: int,
                       mb_index: int):
        """One mini-batch gradient (+ optional codec round-trip with error
        feedback). Returns (grads, loss, wire nbytes)."""
        grads, loss = self.problem.map_compute(params, version, mb_index)
        nbytes = self.problem.grad_bytes
        if self.codec is not None:
            if v.ef_residual is None:
                v.ef_residual = ef_init(self.problem.params0)
            grads, v.ef_residual, nbytes = ef_compress(self.codec, grads,
                                                       v.ef_residual)
        return grads, loss, nbytes

    def _do_map(self, v: _Volunteer, work: MapWork):
        t = work.task
        params = work.model[0]             # blob = (params, opt_state)
        grads, loss, nbytes = self._compute_grads(v, params, t.version,
                                                  t.mb_index)
        self.bytes_sent += nbytes
        done = v.sess.finish_map(grads, nbytes, loss)
        if not done.stale:
            self.tasks_done[v.vid] = self.tasks_done.get(v.vid, 0) + 1
            self.version_losses.setdefault(t.version, []).append(loss)

    def _do_async(self, v: _Volunteer, work: MapWork):
        """BoundedStaleness: gradient at the fetched (latest) version, then
        the admission edge; an admitted gradient applies to the CURRENT model
        and commits the next version, all in this scheduler slice."""
        t = work.task
        params = work.model[0]
        grads, loss, nbytes = self._compute_grads(v, params, t.version,
                                                  t.mb_index)
        self.bytes_sent += nbytes
        out = v.sess.finish_update(v.sess.grad_result(grads, nbytes, loss))
        if isinstance(out, TaskDone):      # too stale: discarded + requeued
            self.stale_discards += 1
            return
        params, opt_state = out.model
        params, opt_state = self.problem.apply_one(params, opt_state, grads)
        v.sess.commit_update((params, opt_state), self.problem.model_bytes,
                             gc_keep=2)
        self.bytes_sent += self.problem.model_bytes
        self.tasks_done[v.vid] = self.tasks_done.get(v.vid, 0) + 1
        self.version_losses.setdefault(out.version, []).append(loss)

    def _do_local(self, v: _Volunteer, work: LocalWork):
        """LocalSteps: k local optimizer steps from the fetched model; the
        weighted delta applies to the CURRENT model via commit_update.
        (The stale branch mirrors _do_async for accounting consistency; it
        is unreachable under this engine's serialized round-robin scheduler,
        where admission always sees a fresh model.)"""
        t = work.task
        p0, s0 = work.model
        delta, loss = self.problem.local_compute(p0, s0, t.start, t.k)
        self.bytes_sent += self.problem.model_bytes      # delta pushed up
        out = v.sess.finish_update(
            v.sess.delta_result(delta, self.problem.model_bytes, loss))
        if isinstance(out, TaskDone):
            self.stale_discards += 1
            return
        params, opt_state = out.model
        params, opt_state = self.problem.apply_delta(
            params, opt_state, delta, self.policy.weight)
        v.sess.commit_update((params, opt_state), self.problem.model_bytes,
                             gc_keep=2)
        self.bytes_sent += self.problem.model_bytes      # model pulled down
        self.tasks_done[v.vid] = self.tasks_done.get(v.vid, 0) + 1
        self.version_losses.setdefault(out.version, []).append(loss)

    def _do_reduce(self, v: _Volunteer, work: ReduceWork):
        params, opt_state = v.sess.fetch_model(self.problem.model_bytes)
        params, opt_state = self.problem.reduce_compute(params, opt_state,
                                                        work.results)
        v.sess.finish_reduce((params, opt_state), self.problem.model_bytes,
                             gc_keep=2)
        self.tasks_done[v.vid] = self.tasks_done.get(v.vid, 0) + 1
        self.bytes_sent += self.problem.model_bytes
