"""Coordinator — REAL execution of the JSDoop protocol, in process.

K volunteers are interleaved round-robin, actually computing gradients and
RMSprop updates with JAX. Each volunteer is a ``protocol.VolunteerSession`` —
the sans-IO state machine owning every protocol rule (lease, model-version
wait, reduce barrier, duplicate ack, requeue) — speaking typed messages to the
QueueServer/DataServer through a ``transport`` ("inproc" for direct zero-copy
calls, "wire" to round-trip every message through canonical bytes; either way
the final model is identical). The Coordinator itself owns only engine policy:
the logical clock (scheduler iteration count, used for visibility timeouts),
real compute + gradient compression, and churn.

Churn is injected as (step, kind, arg) events: 'leave'/'join' of a volunteer
(a leaving volunteer Byes — its leased tasks requeue, exactly like closing the
browser tab mid-task), and — when running on a ShardedQueueServer —
'add_shard'/'remove_shard' membership changes, which rebalance the federation
live (queues migrate with their full state; see queue.ShardedQueueServer).

Waiting is event-driven: a session that reports ``Blocked`` subscribes (a
``Wake``/``VersionReady`` notification message un-blocks it) and is skipped by
the scheduler until woken. When every volunteer is blocked the logical clock
fast-forwards to the next churn event or visibility deadline instead of
spinning — no step ever busy-polls.

This is the engine behind the paper's invariance claim tests: the final model
must bit-match ``sequential_accumulated`` for ANY worker count, ANY churn, and
ANY transport.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core.dataserver import DataServer
from repro.core.initiator import enqueue_problem
from repro.core.mapreduce import TrainingProblem
from repro.core.protocol import (Blocked, KickQueue, MapWork, NoTask,
                                 ReduceWork, ServerEndpoint, TaskDone,
                                 VolunteerSession)
from repro.core.queue import QueueServer, ShardedQueueServer
from repro.core.tasks import INITIAL_QUEUE
from repro.core.transport import make_transport
from repro.optim.compression import Codec, ef_init, ef_compress


@dataclass
class _Volunteer:
    vid: str
    sess: VolunteerSession
    ef_residual: Any = None     # error-feedback state (when codec is set)
    blocked: bool = False       # waiting on a Wake/VersionReady notification


@dataclass
class RunResult:
    params: Any
    opt_state: Any
    losses: List[float]                   # mean map loss per version
    steps: int
    tasks_by_worker: Dict[str, int]
    requeues: int
    final_version: int


class Coordinator:
    def __init__(self, problem: TrainingProblem, n_workers: int, *,
                 n_versions: Optional[int] = None,
                 churn: Optional[List[Tuple[int, str, str]]] = None,
                 visibility_timeout: float = float("inf"),
                 codec: Optional[Codec] = None, n_shards: int = 1,
                 transport: Union[str, Callable, None] = "inproc"):
        self.problem = problem
        self.qs: Union[QueueServer, ShardedQueueServer] = (
            QueueServer(default_timeout=visibility_timeout) if n_shards <= 1
            else ShardedQueueServer(n_shards,
                                    default_timeout=visibility_timeout))
        self.ds = DataServer()
        self.endpoint = ServerEndpoint(self.qs, self.ds)
        self.port = make_transport(transport, self.endpoint)
        self.port.set_deliver(self._on_notify)
        self.n_versions = n_versions if n_versions is not None else problem.n_versions
        enqueue_problem(problem, self.qs, self.ds, n_versions=self.n_versions)
        self.volunteers: Dict[str, _Volunteer] = {
            f"w{i}": self._make_volunteer(f"w{i}") for i in range(n_workers)}
        self.churn = sorted(churn or [])
        self.codec = codec
        self.version_losses: Dict[int, List[float]] = {}
        self.tasks_done: Dict[str, int] = {}
        self.bytes_sent = 0

    def _make_volunteer(self, vid: str) -> _Volunteer:
        return _Volunteer(vid, VolunteerSession(
            vid, self.port, model_nbytes=self.problem.model_bytes))

    # ------------------------------------------------------------------ engine
    def _on_notify(self, vid: str, msg) -> None:
        """Notification sink: mark the volunteer runnable. A wake for a
        departed volunteer is passed on so no wakeup is lost."""
        v = self.volunteers.get(vid)
        if v is not None:
            v.blocked = False
        else:
            self.port.call(KickQueue(INITIAL_QUEUE))

    def run(self, max_steps: int = 2_000_000) -> RunResult:
        step = 0
        churn_i = 0
        while self.ds.latest_version < self.n_versions:
            if step >= max_steps:
                raise RuntimeError("coordinator did not converge (deadlock?)")
            # churn events
            while churn_i < len(self.churn) and self.churn[churn_i][0] <= step:
                _, kind, vid = self.churn[churn_i]
                churn_i += 1
                if kind == "leave" and vid in self.volunteers:
                    self.volunteers[vid].sess.bye()
                    del self.volunteers[vid]
                elif kind == "join" and vid not in self.volunteers:
                    self.volunteers[vid] = self._make_volunteer(vid)
                elif kind == "add_shard" and \
                        isinstance(self.qs, ShardedQueueServer):
                    self.qs.add_shard()
                elif kind == "remove_shard" and \
                        isinstance(self.qs, ShardedQueueServer) and \
                        len(self.qs.shards) > 1:
                    self.qs.remove_shard(int(vid) % len(self.qs.shards))
            if not self.volunteers:
                # everyone left; semantically the problem just pauses (paper:
                # "If no one is collaborating, the problem simply stops").
                if churn_i >= len(self.churn):
                    raise RuntimeError("no volunteers and no future joins")
                step = max(step + 1, self.churn[churn_i][0])
                continue
            # O(expired): expire_all self-gates on the server's lazy deadline
            # index and returns immediately while nothing is due
            self.qs.expire_all(step)
            ran_any = False
            for vid in list(self.volunteers):
                v = self.volunteers.get(vid)
                if v is not None and not v.blocked:
                    self._step_volunteer(v, step)
                    ran_any = True
            if ran_any:
                step += 1
                continue
            # every volunteer is waiting on a wake: jump the logical clock to
            # the next external event (churn or a visibility-timeout expiry)
            # instead of spinning through empty steps
            candidates = []
            if churn_i < len(self.churn):
                candidates.append(self.churn[churn_i][0])
            dl = self.qs.next_deadline()
            if dl is not None and math.isfinite(dl):
                candidates.append(int(math.ceil(dl)))
            if not candidates:
                raise RuntimeError(
                    "coordinator deadlock: all volunteers blocked with no "
                    "pending churn or visibility deadline")
            step = max(step + 1, min(candidates))
        params, opt_state = self.ds.get_model(self.ds.latest_version)
        losses = [float(np.mean(self.version_losses[k]))
                  for k in sorted(self.version_losses)]
        return RunResult(params, opt_state, losses, step, dict(self.tasks_done),
                         self.qs.total_requeued, self.ds.latest_version)

    # ------------------------------------------------------------------ compute
    def _step_volunteer(self, v: _Volunteer, now: float):
        """One scheduler slice: drive the session one protocol move; answer
        MapWork/ReduceWork with real JAX compute."""
        sess = v.sess
        if sess.task is None:
            if isinstance(sess.lease(now), NoTask):
                v.blocked = True
                sess.subscribe_idle()      # sleep until a publish or requeue
                return
        out = sess.advance(now)
        if isinstance(out, Blocked):
            v.blocked = True
            sess.subscribe(out)
            return
        if isinstance(out, TaskDone):      # obsolete duplicate, acked
            return
        if isinstance(out, MapWork):
            self._do_map(v, out)
        elif isinstance(out, ReduceWork):
            self._do_reduce(v, out)
        else:
            # Busy is unreachable here (compute is synchronous, so nothing
            # can redeliver a wake mid-task) — keep the invariant loud
            raise RuntimeError(f"{v.vid}: unexpected session outcome {out!r}")

    def _do_map(self, v: _Volunteer, work: MapWork):
        t = work.task
        params = work.model[0]             # blob = (params, opt_state)
        grads, loss = self.problem.map_compute(params, t.version, t.mb_index)
        nbytes = self.problem.grad_bytes
        if self.codec is not None:
            if v.ef_residual is None:
                v.ef_residual = ef_init(self.problem.params0)
            grads, v.ef_residual, nbytes = ef_compress(self.codec, grads,
                                                       v.ef_residual)
        self.bytes_sent += nbytes
        done = v.sess.finish_map(grads, nbytes, loss)
        if not done.stale:
            self.tasks_done[v.vid] = self.tasks_done.get(v.vid, 0) + 1
            self.version_losses.setdefault(t.version, []).append(loss)

    def _do_reduce(self, v: _Volunteer, work: ReduceWork):
        params, opt_state = v.sess.fetch_model(self.problem.model_bytes)
        params, opt_state = self.problem.reduce_compute(params, opt_state,
                                                        work.results)
        v.sess.finish_reduce((params, opt_state), self.problem.model_bytes,
                             gc_keep=2)
        self.tasks_done[v.vid] = self.tasks_done.get(v.vid, 0) + 1
        self.bytes_sent += self.problem.model_bytes
