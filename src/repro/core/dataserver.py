"""DataServer — the paper's Redis: a KV store holding data + the versioned model.

The model is stored under monotonically increasing versions. ``publish_model``
is the commit point of a reduce task; ``get_model(v)`` returns None until v is
committed, which is exactly the paper's "if the required version is not yet
available, the task waits" synchronization (solution 2 of §IV.F step 5: check
if a datum has been modified before starting).

``watch_version(v, callback)`` turns that wait into a push: the callback fires
the moment ``publish_model(v)`` lands (immediately if v is already committed),
so waiters never poll — the Redis-keyspace-notification analogue.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional


class DataServer:
    def __init__(self):
        self._kv: Dict[str, Any] = {}
        self._models: Dict[int, Any] = {}
        self._latest: int = -1
        self._watchers: Dict[int, List[Callable[[], None]]] = {}
        self.reads = 0
        self.writes = 0
        self.bytes_read = 0
        self.bytes_written = 0
        self.watch_fires = 0

    # -- CRUD -----------------------------------------------------------------
    def put(self, key: str, value: Any, nbytes: int = 0):
        self._kv[key] = value
        self.writes += 1
        self.bytes_written += nbytes

    def get(self, key: str, nbytes: int = 0):
        self.reads += 1
        self.bytes_read += nbytes
        return self._kv.get(key)

    def delete(self, key: str) -> bool:
        return self._kv.pop(key, None) is not None

    # -- versioned model --------------------------------------------------------
    def publish_model(self, version: int, blob: Any, nbytes: int = 0) -> bool:
        """Commit model version. Exactly-once: returns False if already present
        (a duplicate reduce execution after a requeue — the blob is discarded,
        keeping version publication idempotent)."""
        if version in self._models:
            return False
        assert version == self._latest + 1, (
            f"version gap: publishing {version}, latest {self._latest}")
        self._models[version] = blob
        self._latest = version
        self.writes += 1
        self.bytes_written += nbytes
        # versions commit in +1 order, so only exact-version watchers can exist
        for cb in self._watchers.pop(version, []):
            self.watch_fires += 1
            cb()
        return True

    def watch_version(self, version: int, callback: Callable[[], None]) -> None:
        """Fire ``callback`` once model ``version`` is committed — immediately
        if it already is, else at the ``publish_model(version)`` that lands it."""
        if self._latest >= version:
            self.watch_fires += 1
            callback()
            return
        self._watchers.setdefault(version, []).append(callback)

    def get_model(self, version: int, nbytes: int = 0) -> Optional[Any]:
        blob = self._models.get(version)
        if blob is not None:
            self.reads += 1
            self.bytes_read += nbytes
        return blob

    @property
    def latest_version(self) -> int:
        return self._latest

    def gc_models(self, keep_last: int = 2):
        """Drop stale versions (bounded memory, like Redis TTL). Pending
        ``watch_version`` registrations are untouched: a watch names a FUTURE
        commit, and GC only ever removes already-superseded blobs."""
        for v in sorted(self._models):
            if v <= self._latest - keep_last:
                del self._models[v]

    # -- durability ------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Serializable full state: the KV store, every LIVE model version
        (GC'd versions are gone and stay gone — restoring must not resurrect
        them), the latest-version cursor, and the accounting counters.
        Pending watchers are live callbacks and never serialize; see
        ``restore`` for how in-process watchers survive."""
        # lazily-published blobs (the real applier's LazyModelBlob)
        # solidify here: a checkpoint must hold values, not live thunks
        return {"kind": "DataServer",
                "kv": dict(self._kv),
                "models": [[v, b.materialize()
                            if hasattr(b, "materialize") else b]
                           for v, b in sorted(self._models.items())],
                "latest": self._latest,
                "reads": self.reads, "writes": self.writes,
                "bytes_read": self.bytes_read,
                "bytes_written": self.bytes_written,
                "watch_fires": self.watch_fires}

    def restore(self, state: Dict[str, Any]) -> None:
        """Replace this server's state with a snapshot, in place.

        Watchers registered on THIS object survive the restore (they are
        connection/session-bound callbacks, not state): any watch whose
        version the restored state has already committed fires immediately —
        the same guarantee ``watch_version`` makes for an already-published
        version — and watches on still-future versions stay pending. After a
        process crash there are no watchers to keep; reconnecting clients
        re-issue ``WatchVersion``."""
        if state.get("kind") != "DataServer":
            raise ValueError(f"not a DataServer snapshot: {state.get('kind')!r}")
        self._kv = dict(state["kv"])
        self._models = {v: blob for v, blob in state["models"]}
        self._latest = state["latest"]
        self.reads = state["reads"]
        self.writes = state["writes"]
        self.bytes_read = state["bytes_read"]
        self.bytes_written = state["bytes_written"]
        self.watch_fires = state["watch_fires"]
        for v in sorted(list(self._watchers)):
            if v <= self._latest:
                for cb in self._watchers.pop(v):
                    self.watch_fires += 1
                    cb()
