"""Sans-IO volunteer protocol: typed wire messages + the volunteer state machine.

The paper's volunteers talk to the queue/data servers over a network (browser
-> RabbitMQ/Redis); our engines used to hand-roll that conversation as direct
Python calls, each with a private copy of the protocol rules. This module makes
the protocol itself the product, the way Pando's pull/push message contract and
DistML.js's serializable command API do:

- **Messages** — every server interaction is a typed, immutable message
  (``LeaseReq``/``LeaseGrant``, ``Ack``, ``Nack``, ``PublishResult``,
  ``FetchModel``/``ModelBlob``, ``PublishModel``, ``WatchVersion``,
  ``SubscribeQueue`` and the async ``Wake``/``VersionReady`` notifications,
  ``Bye``...) with canonical byte serialization via
  ``checkpoint.serialize`` (msgpack + codec header byte), so any message —
  including a ``GradResult`` carrying a real gradient pytree — round-trips
  bytes losslessly.

- **ServerEndpoint** — the server half: dispatches one request message onto a
  ``QueueServer``/``DataServer`` pair and returns the reply message.
  Subscriptions are registered here; their fires are delivered as ``Wake`` /
  ``VersionReady`` notification messages through a ``notify(consumer, msg)``
  sink (the transport's downstream half). An optional ``LeaseClock`` makes
  the server the lease-time authority (the gateway's wall clock, an engine's
  virtual clock), and an optional ``ServerApplier`` serves the barrierless
  ``SubmitUpdate`` fast path: admission -> apply -> publish -> ack in one
  dispatch, so thin volunteers never fetch the admission-time model or push
  the updated blob.

- **VolunteerSession** — the sans-IO client state machine owning every
  protocol rule the engines used to duplicate: lease from the task queue ->
  (map) fetch model version, compute, publish gradient -> ack, or (reduce)
  check the barrier, drain + dedup the results queue, publish model v+1 ->
  ack — including the at-least-once edges (obsolete-duplicate ack without
  compute, incomplete-barrier nack + re-wait, dead-volunteer abort). The
  session performs **no IO and no compute**: server effects go through a
  ``Transport`` (``repro.core.transport``) one message at a time, and compute
  is handed back to the engine as ``MapWork``/``ReduceWork`` outcomes — the
  Coordinator answers them with real JAX gradients, the Simulator with virtual
  time, and ``repro.core.gateway``'s out-of-process volunteer with synthetic
  blobs over a socket. Waiting is likewise the engine's policy: the session
  says *what* to wait for (a ``Blocked`` outcome); the engine decides push
  (``subscribe``) vs poll.

  The protocol *shape* is set by the session's ``AggregationPolicy``
  (``repro.core.aggregation``): barrier policies run the conversation above;
  barrierless ones (BoundedStaleness async SGD, LocalSteps averaging) run
  fetch-latest -> compute (``MapWork``/``LocalWork``) -> ``finish_update``
  admission on the version-stamped result -> ``commit_update`` — a too-stale
  result is discarded and its ticket nacked for a fresh recompute.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.checkpoint import serialize
from repro.core.aggregation import AggregationPolicy, SyncBSP, make_policy
from repro.core.dataserver import DataServer
from repro.core.tasks import (DeltaResult, GradResult, INITIAL_QUEUE,
                              WIRE_TYPES, results_queue)

# ---------------------------------------------------------------------------
# wire registry + byte codec
# ---------------------------------------------------------------------------

_WIRE_TYPES: Dict[str, type] = {c.__name__: c for c in WIRE_TYPES}

_TAG = "__wire__"
_TUP = "__tuple__"


def wire(cls):
    """Register a dataclass as wire-encodable (by class name). Names are the
    wire schema, so a collision would silently re-route every byte stream —
    fail at import time instead."""
    if cls.__name__ in _WIRE_TYPES:       # not an assert: must survive -O
        raise ValueError(f"wire type name collision: {cls.__name__}")
    _WIRE_TYPES[cls.__name__] = cls
    return cls


def _to_obj(x):
    if dataclasses.is_dataclass(x) and type(x).__name__ in _WIRE_TYPES:
        return {_TAG: type(x).__name__,
                "f": {f.name: _to_obj(getattr(x, f.name))
                      for f in dataclasses.fields(x)}}
    if isinstance(x, dict):
        return {k: _to_obj(v) for k, v in x.items()}
    if isinstance(x, tuple):
        # msgpack would coerce tuples to lists; tag them so pytree structure
        # (e.g. a (params, opt_state) blob) survives the wire exactly.
        # Namedtuples decode as plain tuples.
        return {_TUP: [_to_obj(v) for v in x]}
    if isinstance(x, list):
        return [_to_obj(v) for v in x]
    return x


def _from_obj(x):
    if isinstance(x, dict):
        if _TAG in x:
            cls = _WIRE_TYPES[x[_TAG]]
            return cls(**{k: _from_obj(v) for k, v in x["f"].items()})
        if _TUP in x:
            return tuple(_from_obj(v) for v in x[_TUP])
        return {k: _from_obj(v) for k, v in x.items()}
    if isinstance(x, list):
        return [_from_obj(v) for v in x]
    return x


def encode_message(msg, *, codec: Optional[str] = None) -> bytes:
    """Message -> canonical bytes. Uncompressed by default (protocol messages
    are small and latency-bound); pass codec="zlib"/"zstd" to compress bulky
    payloads (model blobs, dense gradients) through the serialize codecs."""
    return serialize.dumps(_to_obj(msg), compress=codec is not None,
                           codec=codec)


def decode_message(data: bytes):
    return _from_obj(serialize.loads(data))


def wire_size(msg, *, codec: Optional[str] = None) -> int:
    """Encoded size of a message — the cost-model observable."""
    return len(encode_message(msg, codec=codec))


# ---------------------------------------------------------------------------
# messages: requests
# ---------------------------------------------------------------------------

@wire
@dataclass(frozen=True)
class Hello:
    """Bind this connection to a consumer id (gateway registration)."""
    consumer: str


@wire
@dataclass(frozen=True)
class LeaseReq:
    queue: str
    consumer: str
    now: float
    timeout: Optional[float] = None


@wire
@dataclass(frozen=True)
class Ack:
    queue: str
    tag: int


@wire
@dataclass(frozen=True)
class Nack:
    """Voluntary give-back (dependency not ready); requeues at the front."""
    queue: str
    tag: int
    front: bool = True


@wire
@dataclass(frozen=True)
class ExtendLease:
    """Lease renewal (heartbeat): re-stamp the held tag's visibility deadline
    to now + timeout. A live consumer whose compute — or whose barrier wait —
    outlasts the visibility timeout sends this periodically so only DEAD
    consumers' leases expire. With a server clock installed ``now`` is
    ignored, like ``LeaseReq``. ``consumer`` is the receipt check: if the
    lease meanwhile expired and was re-granted to someone else, the renewal
    is refused (Ok(False)) instead of hijacking the new holder's lease."""
    queue: str
    tag: int
    now: float = 0.0
    timeout: Optional[float] = None
    consumer: str = ""


@wire
@dataclass(frozen=True)
class PublishResult:
    """Publish a GradResult onto a results queue."""
    queue: str
    result: Any


@wire
@dataclass(frozen=True)
class FetchModel:
    version: int
    nbytes: int = 0


@wire
@dataclass(frozen=True)
class PublishModel:
    version: int
    blob: Any
    nbytes: int = 0


@wire
@dataclass(frozen=True)
class GcModels:
    keep_last: int = 2


@wire
@dataclass(frozen=True)
class WatchVersion:
    version: int
    consumer: str


@wire
@dataclass(frozen=True)
class SubscribeQueue:
    queue: str
    consumer: str
    kind: str = "any"


@wire
@dataclass(frozen=True)
class KickQueue:
    """Hand a consumed wake back to the next waiter (woken consumer died)."""
    queue: str


@wire
@dataclass(frozen=True)
class DropConsumer:
    consumer: str


@wire
@dataclass(frozen=True)
class DepthReq:
    queue: str


@wire
@dataclass(frozen=True)
class DrainedReq:
    queue: str


@wire
@dataclass(frozen=True)
class LatestReq:
    pass


@wire
@dataclass(frozen=True)
class SubmitUpdate:
    """Barrierless fast path: hand the server a version-stamped result
    (``GradResult``/``DeltaResult``) and let IT run admission -> apply ->
    commit -> ack, so the volunteer never fetches the admission-time model or
    pushes the updated blob. Requires the endpoint to host a
    ``ServerApplier``; ``queue``/``tag`` name the ticket to ack (admitted) or
    nack to the front (too stale)."""
    queue: str
    tag: int
    result: Any


@wire
@dataclass(frozen=True)
class Bye:
    """Volunteer leaves: unsubscribe everywhere + requeue held leases."""
    consumer: str


@wire
@dataclass(frozen=True)
class ExpireAll:
    """Server-authority lease sweep as a PROTOCOL message: requeue every
    lease whose visibility deadline is <= ``now``. ``now`` is stamped by the
    caller that owns time (the gateway's sweeper thread, an engine's virtual
    clock) and is applied verbatim — never re-stamped by the endpoint clock —
    because the op log records this message and failover replay must expire
    exactly the leases the live server expired, at exactly the recorded
    times."""
    now: float


@wire
@dataclass(frozen=True)
class Forward:
    """Inter-gateway routing envelope: gateway ``origin`` did not own the
    ring slice for ``inner``'s routing key, so it forwards the request to the
    owner verbatim. The owner dispatches ``inner`` as if the client were
    local and returns its reply in a ``ForwardReply`` with the same ``seq``
    (the origin runs many forwards concurrently over one peer link).
    Forwards never nest — the origin resolves the final owner before
    sending — and the envelope itself is never op-logged: the dispatched
    ``inner`` is, so failover replay is identical whether traffic arrived
    locally or forwarded."""
    seq: int
    origin: str
    inner: Any


# ---------------------------------------------------------------------------
# messages: replies
# ---------------------------------------------------------------------------

@wire
@dataclass(frozen=True)
class LeaseGrant:
    tag: int
    body: Any
    latest: int = -1          # staleness metadata: the model version current
                              # at grant time (lets a client judge/skip work
                              # without a LatestReq round-trip)


@wire
@dataclass(frozen=True)
class LeaseEmpty:
    pass


@wire
@dataclass(frozen=True)
class Ok:
    """Generic acknowledgement reply; ``value`` carries the op's scalar result
    (ack/nack success, depth, drained, drop count...)."""
    value: Any = None


@wire
@dataclass(frozen=True)
class ModelBlob:
    version: int
    present: bool
    blob: Any = None


@wire
@dataclass(frozen=True)
class LatestVersion:
    version: int


@wire
@dataclass(frozen=True)
class UpdateCommitted:
    """``SubmitUpdate`` reply: the result passed admission; the server
    applied it and published model ``version``, and the ticket is acked."""
    version: int


@wire
@dataclass(frozen=True)
class UpdateRejected:
    """``SubmitUpdate`` reply: too stale at ``latest``; the payload was
    discarded and the ticket nacked to the queue front for a recompute."""
    latest: int


@wire
@dataclass(frozen=True)
class ForwardReply:
    """The owner's reply to a ``Forward``, correlated by ``seq``; ``inner``
    is the reply the dispatched request produced."""
    seq: int
    inner: Any


# ---------------------------------------------------------------------------
# messages: async notifications (server -> client)
# ---------------------------------------------------------------------------

@wire
@dataclass(frozen=True)
class Wake:
    """A queue subscription fired (publish, or requeue for kind="any")."""
    queue: str
    kind: str = "any"


@wire
@dataclass(frozen=True)
class VersionReady:
    """A watched model version committed."""
    version: int


@wire
@dataclass(frozen=True)
class ForwardNotify:
    """A notification (``Wake``/``VersionReady``) owed to consumer
    ``consumer`` whose connection lives on ANOTHER gateway: the slice owner
    wraps the fire and sends it to the consumer's home gateway, which unwraps
    and delivers ``inner`` down the consumer's local connection."""
    consumer: str
    inner: Any


NOTIFICATION_TYPES = (Wake, VersionReady, ForwardNotify)

REQUEST_TYPES = (Hello, LeaseReq, Ack, Nack, ExtendLease, PublishResult,
                 FetchModel, PublishModel, GcModels, WatchVersion,
                 SubscribeQueue, KickQueue, DropConsumer, DepthReq,
                 DrainedReq, LatestReq, SubmitUpdate, Bye, ExpireAll,
                 Forward)

REPLY_TYPES = (LeaseGrant, LeaseEmpty, Ok, ModelBlob, LatestVersion,
               UpdateCommitted, UpdateRejected, ForwardReply)

#: requests that read server state without mutating it — safe to dispatch
#: outside the gateway's guard lock, and never worth op-logging
READONLY_TYPES = (LatestReq, DepthReq, DrainedReq, FetchModel, Hello)

#: requests the op log records (state-changing, connection-independent).
#: ``SubscribeQueue``/``WatchVersion`` are deliberately absent: waiters are
#: session-bound (snapshots exclude them for the same reason) and replaying
#: one would register a phantom waiter against a dead connection.
#: ``SubmitUpdate`` is logged too, but at the ``submit_batch`` layer so a
#: batched drain logs its updates in exact application order. ``Forward``
#: envelopes are never logged — their dispatched ``inner`` is.
OPLOG_TYPES = (LeaseReq, Ack, Nack, ExtendLease, PublishResult, PublishModel,
               GcModels, KickQueue, DropConsumer, Bye, ExpireAll)


# ---------------------------------------------------------------------------
# server half
# ---------------------------------------------------------------------------

@dataclass
class ServerApplier:
    """Server-side async applier (the DistML.js shape: thin clients push
    contributions; the parameter server applies them). Hosted by a
    ``ServerEndpoint``, it serves ``SubmitUpdate`` for barrierless policies:
    admission by ``policy.admit``, then ``apply(model_blob, result, version)``
    produces the next blob, which the endpoint publishes as ``version + 1``
    and acks the ticket — one round-trip where the client-applied path costs
    three (admission LatestReq + model fetch + model push)."""

    policy: Any
    apply: Callable[[Any, Any, int], Any]
    model_nbytes: int = 0
    gc_keep: Optional[int] = None
    applied: int = 0
    rejected: int = 0
    # measured wire size: when set, every publish re-measures the encoded
    # blob instead of trusting the constructor constant (which lies as soon
    # as the blob is a real serialized model rather than a synthetic token)
    measure: Optional[Callable[[Any], int]] = None
    # batched fast path: (blob, results, base_version) -> [blob_1..blob_B],
    # the successive post-update blobs for a homogeneous admitted run —
    # installed by appliers that can chain B updates in one jitted dispatch
    apply_batch: Optional[Callable[[Any, List[Any], int], List[Any]]] = None
    batches: int = 0           # drains that applied >= 2 updates in one go
    batched_updates: int = 0   # updates that rode such drains

    def nbytes_for(self, blob) -> int:
        """Wire-accounting size of a freshly produced blob: measured when a
        ``measure`` hook is installed, else the constructor constant."""
        if self.measure is not None:
            self.model_nbytes = int(self.measure(blob))
        return self.model_nbytes


class ServerEndpoint:
    """Dispatch one request message onto (QueueServer, DataServer) and return
    the reply message. Subscription/watch fires leave as ``Wake`` /
    ``VersionReady`` notifications through ``notify(consumer, msg)`` — which a
    transport routes back to the owning engine (possibly over bytes, possibly
    through injected faults).

    ``clock`` (a ``queue.LeaseClock``) makes the SERVER the lease-time
    authority: when set, every ``LeaseReq`` is stamped with ``clock.now()``
    instead of the client-supplied ``now`` — a remote client's idea of time
    never reaches the deadline table. Engines install a ``VirtualClock`` over
    their own event time; the gateway installs a ``WallClock`` plus a sweeper
    thread that drives ``expire_all`` on real deadlines.

    ``applier`` (a ``ServerApplier``) enables the ``SubmitUpdate`` fast path
    for barrierless policies."""

    def __init__(self, qs, ds: DataServer,
                 notify: Optional[Callable[[str, Any], None]] = None, *,
                 clock=None, applier: Optional[ServerApplier] = None):
        self.qs = qs
        self.ds = ds
        self.clock = clock
        self.applier = applier
        # op log sink: when set (the gateway installs one), every successfully
        # dispatched state-changing request (``OPLOG_TYPES`` + each
        # ``SubmitUpdate`` in batch order) is handed to it AFTER dispatch, so
        # a failover replay of the recorded stream reconstructs this
        # endpoint's durable state exactly
        self.op_sink: Optional[Callable[[Any], None]] = None
        # consumers whose connection lives on another gateway (registered by
        # a forwarded SubscribeQueue/WatchVersion): consumer -> origin gid;
        # their notification fires leave as ForwardNotify to the home gateway
        self._remote_consumers: Dict[str, str] = {}
        self._notify = notify if notify is not None else (lambda c, m: None)
        # live (consumer, version) watches: lossy/timed clients re-subscribe
        # defensively, and the queue side dedupes waiters per consumer — this
        # is the matching dedupe for version watches, so a re-watch while the
        # previous registration is live is a no-op instead of stacking
        # duplicate watcher callbacks and duplicate VersionReady frames
        self._watch_keys: set = set()

    def set_notify(self, notify: Callable[[str, Any], None]) -> None:
        self._notify = notify

    def watch_view(self) -> Tuple[Tuple[str, int], ...]:
        """Live ``(consumer, version)`` watches, sorted. Introspection hook
        for ``repro.analysis.mc`` (no-lost-wake invariant + state
        fingerprint); the watcher callbacks themselves stay private."""
        return tuple(sorted(self._watch_keys))

    def disconnect(self, consumer: str) -> int:
        """Server-side cleanup for a consumer whose CONNECTION died (not a
        ``Bye``: that is the volunteer leaving voluntarily, and it also
        requeues held leases). Drops the consumer's queue waiters so they
        stop consuming one-shot wakes nobody can deliver; leases stay —
        lease recovery is deliberately the sweeper's (the volunteer may
        reconnect and heartbeat; only real death expires them)."""
        self._remote_consumers.pop(consumer, None)
        return self.qs.unsubscribe(consumer)

    def _deliver(self, consumer: str, msg) -> None:
        """Route one notification fire: locally-connected consumers get the
        message as-is; a consumer registered through a ``Forward`` gets it
        wrapped in ``ForwardNotify`` addressed to its home gateway's peer
        link (consumer id ``gw:<origin>``)."""
        origin = self._remote_consumers.get(consumer)
        if origin is None:
            self._notify(consumer, msg)
        else:
            self._notify(f"gw:{origin}", ForwardNotify(consumer, msg))

    def now(self, client_now: float = 0.0) -> float:
        """Lease-authority time: the installed clock, else the client's."""
        return client_now if self.clock is None else self.clock.now()

    def _submit_update(self, m: SubmitUpdate):
        return self.submit_batch([m])[0]

    def submit_batch(self, msgs: List[SubmitUpdate]) -> List[Any]:
        """Drained ``SubmitUpdate`` batch — the server-apply fast path.

        Admission is precomputed Python-side in arrival order: within a drain
        the published version advances by exactly one per admitted update, so
        element i is admitted against (and a rejection reports) the version it
        would have observed under one-at-a-time handling. The admitted run is
        then applied — in ONE jitted dispatch per homogeneous segment when the
        applier installs ``apply_batch`` — and every intermediate version is
        published, with measured nbytes, and acked in arrival order.

        Replies are bit-identical to sequential ``handle`` calls per client;
        batching is invisible on the wire. The only internal difference is
        that ``gc_keep`` pruning runs once at drain end instead of after each
        publish — the surviving version set is the same either way, and no
        client observes mid-drain state (the endpoint is held by one drainer).
        An empty or all-rejected drain publishes nothing."""
        ap = self.applier
        if ap is None:
            raise TypeError("SubmitUpdate needs a ServerApplier on the "
                            "endpoint (server-side apply is not enabled)")
        if self.op_sink is not None:
            # arrival order IS application order (admission is precomputed in
            # arrival order), so replaying these one-at-a-time reproduces the
            # drain's state exactly — the batching is invisible to the log
            # just as it is on the wire
            for m in msgs:
                self.op_sink(m)
        replies: List[Any] = [None] * len(msgs)
        base = self.ds.latest_version
        v = base
        admitted: List[Tuple[int, SubmitUpdate]] = []
        for i, m in enumerate(msgs):
            if ap.policy.admit(m.result.computed_at, v):
                admitted.append((i, m))
                v += 1
            else:
                ap.rejected += 1
                self.qs.nack(m.queue, m.tag, front=True)
                replies[i] = UpdateRejected(v)
        if not admitted:
            return replies
        blob = self.ds.get_model(base)
        blobs: List[Any] = []
        pos = 0
        while pos < len(admitted):
            # homogeneous segment: apply_batch chains one result kind only
            # (GradResult vs DeltaResult take different jitted paths)
            kind = type(admitted[pos][1].result)
            end = pos + 1
            while end < len(admitted) and \
                    type(admitted[end][1].result) is kind:
                end += 1
            seg = [m.result for _, m in admitted[pos:end]]
            if len(seg) >= 2 and ap.apply_batch is not None:
                out = ap.apply_batch(blob, seg, base + pos)
                ap.batches += 1
                ap.batched_updates += len(seg)
            else:
                out = []
                for j, r in enumerate(seg):
                    blob = ap.apply(blob, r, base + pos + j)
                    out.append(blob)
            blobs.extend(out)
            blob = out[-1]
            pos = end
        for k, ((i, m), b) in enumerate(zip(admitted, blobs)):
            self.ds.publish_model(base + k + 1, b, nbytes=ap.nbytes_for(b))
            self.qs.ack(m.queue, m.tag)
            ap.applied += 1
            replies[i] = UpdateCommitted(base + k + 1)
        if ap.gc_keep is not None:
            self.ds.gc_models(keep_last=ap.gc_keep)
        return replies

    def handle(self, m):
        """Dispatch one request and return its reply, feeding the op log.

        ``Forward`` unwraps here: the envelope records the origin gateway for
        any session-binding inner (so notification fires route home), then
        the inner request dispatches through this same method — op-logging
        included — and the reply goes back wrapped with the envelope's seq.
        """
        if isinstance(m, Forward):
            inner = m.inner
            if isinstance(inner, (SubscribeQueue, WatchVersion)):
                self._remote_consumers[inner.consumer] = m.origin
            return ForwardReply(m.seq, self.handle(inner))
        reply = self._dispatch(m)
        # logged only after a successful dispatch: a request that raised
        # must not survive into the replay stream
        if self.op_sink is not None and isinstance(m, OPLOG_TYPES):
            self.op_sink(m)
        return reply

    def _dispatch(self, m):
        if isinstance(m, LeaseReq):
            got = self.qs.lease(m.queue, m.consumer, self.now(m.now),
                                m.timeout)
            if got is None:
                return LeaseEmpty()
            return LeaseGrant(got[0], got[1], self.ds.latest_version)
        if isinstance(m, Ack):
            return Ok(self.qs.ack(m.queue, m.tag))
        if isinstance(m, Nack):
            return Ok(self.qs.nack(m.queue, m.tag, front=m.front))
        if isinstance(m, ExtendLease):
            return Ok(self.qs.extend(m.queue, m.tag, self.now(m.now),
                                     m.timeout, m.consumer or None))
        if isinstance(m, PublishResult):
            return Ok(self.qs.publish(m.queue, m.result))
        if isinstance(m, FetchModel):
            blob = self.ds.get_model(m.version, nbytes=m.nbytes)
            if blob is not None and hasattr(blob, "materialize"):
                # a batched real applier publishes lazy blobs; a fetch is
                # exactly the moment the pytree form is actually needed
                blob = blob.materialize()
            return ModelBlob(m.version, blob is not None, blob)
        if isinstance(m, PublishModel):
            return Ok(self.ds.publish_model(m.version, m.blob,
                                            nbytes=m.nbytes))
        if isinstance(m, GcModels):
            self.ds.gc_models(keep_last=m.keep_last)
            return Ok()
        if isinstance(m, WatchVersion):
            key = (m.consumer, m.version)
            if key in self._watch_keys:
                return Ok(False)
            self._watch_keys.add(key)

            def fire(key=key, consumer=m.consumer, version=m.version):
                self._watch_keys.discard(key)
                self._deliver(consumer, VersionReady(version))

            self.ds.watch_version(m.version, fire)
            return Ok(True)
        if isinstance(m, SubscribeQueue):
            self.qs.subscribe(
                m.queue, m.consumer,
                lambda: self._deliver(m.consumer, Wake(m.queue, m.kind)),
                kind=m.kind)
            return Ok()
        if isinstance(m, KickQueue):
            self.qs.kick(m.queue)
            return Ok()
        if isinstance(m, DropConsumer):
            self._remote_consumers.pop(m.consumer, None)
            return Ok(self.qs.drop_consumer(m.consumer))
        if isinstance(m, DepthReq):
            return Ok(self.qs.depth(m.queue))
        if isinstance(m, DrainedReq):
            return Ok(self.qs.drained([m.queue]))
        if isinstance(m, LatestReq):
            return LatestVersion(self.ds.latest_version)
        if isinstance(m, SubmitUpdate):
            return self._submit_update(m)
        if isinstance(m, Bye):
            self._remote_consumers.pop(m.consumer, None)
            self.qs.unsubscribe(m.consumer)
            return Ok(self.qs.drop_consumer(m.consumer))
        if isinstance(m, ExpireAll):
            # m.now applied verbatim (see ExpireAll): replay authority
            return Ok(self.qs.expire_all(m.now))
        if isinstance(m, Hello):
            return Ok(m.consumer)
        raise TypeError(f"unknown protocol message {type(m).__name__}")


# ---------------------------------------------------------------------------
# client half: session outcomes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class NoTask:
    """The task queue is empty; wait for a publish/requeue (or stop if the
    queue is drained and the run is ending)."""


@dataclass(frozen=True)
class TaskLeased:
    task: Any


@dataclass(frozen=True)
class Blocked:
    """What to wait for. Exactly one of (queue, version) is set; the engine
    chooses the mechanism — ``session.subscribe(blocked)`` for push, or its
    own reschedule for poll."""
    queue: Optional[str] = None
    kind: str = "any"
    version: Optional[int] = None


@dataclass(frozen=True)
class MapWork:
    """Model fetched: the engine must produce this map task's gradient (real
    or simulated). Under a barrier policy call ``finish_map``; under a
    barrierless one ``base_version`` is the latest version the model was
    fetched at — stamp it into a ``GradResult`` and call ``finish_update``."""
    task: Any
    model: Any
    base_version: int = -1


@dataclass(frozen=True)
class LocalWork:
    """Latest model fetched (barrierless LocalSteps): the engine must run the
    task's ``k`` local optimizer steps from this model and hand the delta to
    ``finish_update`` as a ``DeltaResult``."""
    task: Any
    model: Any
    base_version: int = -1


@dataclass(frozen=True)
class ApplyWork:
    """A barrierless result passed admission: the engine must apply
    ``result``'s payload to ``model`` (the blob current at version
    ``version``) and call ``commit_update`` with the new blob, which
    publishes model ``version + 1``."""
    task: Any
    model: Any
    version: int
    result: Any


@dataclass(frozen=True)
class ReduceWork:
    """Barrier met, results drained + deduped: the engine must produce model
    version+1 (real or simulated) and call ``finish_reduce``."""
    task: Any
    results: Dict[int, Any]           # mb_index -> gradient payload


@dataclass(frozen=True)
class TaskDone:
    task: Any
    stale: bool = False               # acked an obsolete duplicate, no work


@dataclass(frozen=True)
class UpdateDone:
    """Outcome of ``submit_update`` (server-applied barrierless commit):
    ``version`` is the model version the server published (-1 when the result
    was rejected as stale — the ticket is already nacked server-side)."""
    task: Any
    stale: bool
    version: int = -1


@dataclass(frozen=True)
class Busy:
    """A compute was already handed out (``MapWork``/``ReduceWork``) and not
    finished: the wake that triggered this advance is spurious (duplicate or
    delayed delivery) and must be dropped, not acted on."""
    task: Any


class VolunteerSession:
    """One volunteer's sans-IO protocol state machine.

    Drive it with ``lease`` -> ``advance`` -> (``finish_map`` |
    ``finish_reduce``); every server effect is a message through ``port.call``.
    The session owns the protocol rules; the engine owns time, compute, and
    the waiting mechanism.
    """

    def __init__(self, vid: str, port, *, model_nbytes: int = 0,
                 policy: Optional[AggregationPolicy] = None):
        self.vid = vid
        self.port = port
        self.model_nbytes = model_nbytes  # accounting hint for FetchModel
        self.policy = make_policy(policy) # aggregation/consistency semantics
        self.tag: Optional[int] = None
        self.task: Any = None
        self.lease_latest: int = -1       # LeaseGrant staleness metadata
        self._rtags: list = []            # leased results-queue tags (reduce)
        self._handed = False              # compute handed out, not yet finished
        self._base: int = -1              # barrierless: version compute is on
        self._apply_version: int = -1     # barrierless: version apply is on

    # -- plumbing -----------------------------------------------------------
    def _call(self, msg):
        return self.port.call(msg)

    def latest(self) -> int:
        return self._call(LatestReq()).version

    def _clear(self):
        self.tag = self.task = None
        self._rtags = []
        self._handed = False
        self._base = self._apply_version = -1

    # -- introspection (repro.analysis.mc) ----------------------------------
    @property
    def holding(self) -> bool:
        """True while a leased ticket is held (heartbeat/release are legal)."""
        return self.tag is not None

    @property
    def computing(self) -> bool:
        """True while compute is handed out and not yet finished."""
        return self._handed

    def state_view(self) -> Dict[str, Any]:
        """The session's protocol-visible state as plain data, for the model
        checker's state fingerprint. ``load_view`` is the inverse; together
        they let an explorer branch a session without deep-copying the
        transport it is bound to."""
        return {"tag": self.tag, "task": self.task,
                "lease_latest": self.lease_latest,
                "rtags": list(self._rtags), "handed": self._handed,
                "base": self._base, "apply_version": self._apply_version}

    def load_view(self, view: Dict[str, Any]) -> None:
        """Restore state captured by ``state_view`` (model-checker replay)."""
        self.tag = view["tag"]
        self.task = view["task"]
        self.lease_latest = view["lease_latest"]
        self._rtags = list(view["rtags"])
        self._handed = view["handed"]
        self._base = view["base"]
        self._apply_version = view["apply_version"]

    # -- protocol: lease ----------------------------------------------------
    def lease(self, now: float):
        """Try to lease the next task from the task queue."""
        assert self.task is None, f"{self.vid}: lease while holding a task"
        r = self._call(LeaseReq(INITIAL_QUEUE, self.vid, now))
        if isinstance(r, LeaseEmpty):
            return NoTask()
        self.tag, self.task = r.tag, r.body
        self.lease_latest = r.latest
        return TaskLeased(self.task)

    # -- protocol: advance a held task up to its compute --------------------
    def advance(self, now: float):
        """Move the held task forward until it blocks, completes as a stale
        duplicate, or is ready for engine compute. Re-entrant: call again
        after a wake (or poll tick) while it returns ``Blocked``."""
        t = self.task
        assert t is not None, f"{self.vid}: advance with no task"
        if self._handed:                  # spurious wake mid-compute
            return Busy(t)
        if not self.policy.barrier:
            return self._advance_update(t)
        # staleness-metadata fast path: latest is monotone, so a task the
        # policy already refused at GRANT time can never become admissible —
        # the LatestReq round-trip is skipped for it
        latest = self.lease_latest
        if self.policy.admit(t.version, latest):
            latest = self.latest()
        if not self.policy.admit(t.version, latest):
            # obsolete duplicate (requeued after someone else's result was
            # reduced) — ack without compute: at-least-once + idempotent
            self._call(Ack(INITIAL_QUEUE, self.tag))
            done = TaskDone(t, stale=True)
            self._clear()
            return done
        if t.kind == "map":
            r = self._call(FetchModel(t.version, self.model_nbytes))
            if not r.present:
                return Blocked(version=t.version)
            self._handed = True
            return MapWork(t, r.blob)
        return self._advance_reduce(now, t)

    def _advance_reduce(self, now: float, t):
        rq = results_queue(t.version)
        if self._call(DepthReq(rq)).value < t.n_mb:
            # barrier not reached: wait for the next result publish (requeues
            # — including our own nacks below — must not wake the barrier)
            return Blocked(queue=rq, kind="publish")
        tags, results = [], {}
        while True:
            r = self._call(LeaseReq(rq, self.vid, now))
            if isinstance(r, LeaseEmpty):
                break
            tags.append(r.tag)
            results.setdefault(r.body.mb_index, r.body.payload)  # dedup by mb
        if len(results) < t.n_mb:
            for tg in tags:
                self._call(Nack(rq, tg, front=True))
            return Blocked(queue=rq, kind="publish")
        self._rtags = tags
        self._handed = True
        return ReduceWork(t, results)

    # -- protocol: barrierless (BoundedStaleness / LocalSteps) ---------------
    def _advance_update(self, t):
        """Barrierless policies never wait on a model version: fetch the
        LATEST model (always present) and hand the compute to the engine.
        Staleness is judged when the result comes back (``finish_update``)."""
        latest = self.latest()
        r = self._call(FetchModel(latest, self.model_nbytes))
        assert r.present, f"{self.vid}: latest model v{latest} not fetchable"
        self._handed = True
        self._base = latest
        if t.kind == "local":
            return LocalWork(t, r.blob, latest)
        return MapWork(t, r.blob, latest)

    def grad_result(self, payload, nbytes: int, loss: float) -> GradResult:
        """Version-stamped async gradient for ``finish_update``."""
        t = self.task
        return GradResult(t.version, t.mb_index, payload, nbytes, loss,
                          self.vid, computed_at=self._base)

    def delta_result(self, payload, nbytes: int, loss: float) -> DeltaResult:
        """Version-stamped local-steps delta for ``finish_update``."""
        t = self.task
        return DeltaResult(t.slot, self._base, payload, nbytes, loss,
                           self.vid, n_steps=t.k,
                           weight=getattr(self.policy, "weight", 1.0))

    def finish_update(self, result):
        """Admission edge for a barrierless result (a ``GradResult`` or
        ``DeltaResult``, version-stamped with ``computed_at``). Too stale ->
        the payload is discarded and the ticket nacked to the queue front for
        a fresh-version recompute. Admitted -> the current model blob is
        fetched and handed back as ``ApplyWork``; the engine applies the
        payload and calls ``commit_update``."""
        t = self.task
        latest = self.latest()
        if not self.policy.admit(result.computed_at, latest):
            self._call(Nack(INITIAL_QUEUE, self.tag, front=True))
            done = TaskDone(t, stale=True)
            self._clear()
            return done
        r = self._call(FetchModel(latest, self.model_nbytes))
        self._apply_version = latest
        return ApplyWork(t, r.blob, latest, result)

    def commit_update(self, blob, nbytes: int = 0,
                      gc_keep: Optional[int] = None):
        """Publish the applied model as version ``apply_version + 1`` and ack
        the ticket. Must be called in the same engine event as
        ``finish_update`` (the admission fetch and this publish are one
        atomic commit under the engines' single-threaded clocks)."""
        t = self.task
        self._call(PublishModel(self._apply_version + 1, blob, nbytes))
        if gc_keep is not None:
            self._call(GcModels(gc_keep))
        self._call(Ack(INITIAL_QUEUE, self.tag))
        done = TaskDone(t)
        self._clear()
        return done

    def submit_update(self, result) -> UpdateDone:
        """Server-applied barrierless commit: one ``SubmitUpdate`` round-trip
        replaces the client-applied ``finish_update`` -> ``commit_update``
        pair — the server runs admission, applies the payload to the current
        model, publishes, and acks/nacks the ticket itself, so the volunteer
        pays a result push instead of a model push. Requires the endpoint to
        host a ``ServerApplier``."""
        t = self.task
        r = self._call(SubmitUpdate(INITIAL_QUEUE, self.tag, result))
        self._clear()
        if isinstance(r, UpdateRejected):
            return UpdateDone(t, stale=True)
        return UpdateDone(t, stale=False, version=r.version)

    # -- protocol: completions ----------------------------------------------
    def finish_map(self, payload, nbytes: int, loss: float):
        """Publish the gradient and ack the map task (re-checking admission:
        in virtual-time engines the version may have advanced mid-compute)."""
        t = self.task
        if not self.policy.admit(t.version, self.latest()):
            self._call(Ack(INITIAL_QUEUE, self.tag))
            done = TaskDone(t, stale=True)
            self._clear()
            return done
        self._call(PublishResult(
            results_queue(t.version),
            GradResult(t.version, t.mb_index, payload, nbytes, loss,
                       self.vid, computed_at=t.version)))
        self._call(Ack(INITIAL_QUEUE, self.tag))
        done = TaskDone(t)
        self._clear()
        return done

    def fetch_model(self, nbytes: int = 0):
        """Fetch the held (reduce) task's model blob — engine compute input."""
        return self._call(FetchModel(self.task.version, nbytes)).blob

    def result_message(self, payload, nbytes: int, loss: float) -> PublishResult:
        """The PublishResult ``finish_map`` would send — lets a measuring
        engine price the push before committing to it."""
        t = self.task
        return PublishResult(
            results_queue(t.version),
            GradResult(t.version, t.mb_index, payload, nbytes, loss, self.vid,
                       computed_at=t.version))

    def model_message(self, blob, nbytes: int = 0) -> PublishModel:
        """The PublishModel ``finish_reduce`` would send (pricing, as above)."""
        return PublishModel(self.task.version + 1, blob, nbytes)

    def finish_reduce(self, blob, nbytes: int = 0,
                      gc_keep: Optional[int] = None):
        """Publish model version+1, then ack the drained results and the
        reduce task. Duplicate publishes are absorbed by the DataServer."""
        t = self.task
        self._call(PublishModel(t.version + 1, blob, nbytes))
        if gc_keep is not None:
            self._call(GcModels(gc_keep))
        rq = results_queue(t.version)
        for tg in self._rtags:
            self._call(Ack(rq, tg))
        self._call(Ack(INITIAL_QUEUE, self.tag))
        done = TaskDone(t)
        self._clear()
        return done

    def release(self, *, front: bool = False) -> bool:
        """Voluntarily give the held ticket back (nack) and go idle. The
        liveness escape hatch for a version-blocked map: stepping aside to
        the BACK of the queue is order-safe (the task cannot run before its
        model version commits anyway) and frees this volunteer to take the
        front task — which may be the very map the open reduce barrier is
        missing. Safe on an already-expired lease (the nack is a no-op)."""
        ok = self._call(Nack(INITIAL_QUEUE, self.tag, front=front)).value
        self._clear()
        return ok

    def queue_depth(self) -> int:
        """Pending tasks on the task queue (is there other leasable work?)."""
        return self._call(DepthReq(INITIAL_QUEUE)).value

    # -- protocol: lease renewal ---------------------------------------------
    def heartbeat(self, now: float = 0.0) -> bool:
        """Renew the held ticket's visibility deadline (see ``ExtendLease``).
        Call periodically from long computes or long barrier waits so the
        sweeper only ever expires DEAD volunteers. Returns False when the
        renewal lost the race (the lease already expired and requeued)."""
        if self.tag is None:
            return False
        return self._call(ExtendLease(INITIAL_QUEUE, self.tag, now,
                                      consumer=self.vid)).value

    # -- protocol: waits ----------------------------------------------------
    def subscribe(self, blocked: Blocked) -> None:
        """Push-mode wait: register for exactly the wake ``blocked`` names."""
        if blocked.version is not None:
            self._call(WatchVersion(blocked.version, self.vid))
        else:
            self._call(SubscribeQueue(blocked.queue, self.vid, blocked.kind))

    def subscribe_idle(self) -> None:
        """Idle wait: wake on the next task-queue publish or requeue."""
        self._call(SubscribeQueue(INITIAL_QUEUE, self.vid, "any"))

    def queue_drained(self) -> bool:
        return self._call(DrainedReq(INITIAL_QUEUE)).value

    # -- protocol: departure -------------------------------------------------
    def abort(self, *, kick_if_empty: bool = False) -> int:
        """The volunteer died mid-protocol: requeue everything it held —
        DropConsumer covers the task lease AND any drained results-queue
        leases in one sweep. A consumed wake it can no longer serve is passed
        on (``kick_if_empty``) so no event is lost. Returns the number of
        requeued leases."""
        n = self._call(DropConsumer(self.vid)).value
        if n == 0 and kick_if_empty:
            self._call(KickQueue(INITIAL_QUEUE))
        self._clear()
        return n

    def bye(self) -> int:
        """Clean departure: unsubscribe everywhere + requeue held leases."""
        n = self._call(Bye(self.vid)).value
        self._clear()
        return n
