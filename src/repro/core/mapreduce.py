"""The map/reduce compute of JSDoop's distributed SGD (paper §IV.G, Fig. 3).

map(version, mb)   = gradient of the mini-batch loss at model version v
reduce(version, *) = mean of the n_mb gradients (sorted by mb_index so the sum
                     order — and hence the floats — are independent of which
                     volunteer computed what, making the paper's Table-4
                     invariance an exact, testable equality), then the RMSprop
                     apply, producing model version v+1.

``TrainingProblem`` packages the model, optimizer, data schedule and jitted
compute; the Initiator, Coordinator and Simulator all consume it.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_lstm import CONFIG as LSTM_CONFIG, TrainParams, PAPER_PARAMS
from repro.data.text import TextTask
from repro.models import model as M
from repro.models.runtime import Runtime
from repro.optim import Optimizer, rmsprop, dense_bytes


@dataclass
class TrainingProblem:
    cfg: Any                     # ArchConfig (vocab resolved)
    rt: Runtime
    tp: TrainParams
    data: TextTask
    optimizer: Optimizer
    params0: Any
    opt_state0: Any

    _grad_fn: Callable = field(default=None, repr=False)
    _acc_apply_fn: Callable = field(default=None, repr=False)

    # ------------------------------------------------------------------ build
    @classmethod
    def paper_problem(cls, *, seed: int = 0, corpus: Optional[str] = None,
                      tp: TrainParams = PAPER_PARAMS,
                      rt: Runtime = Runtime(remat=False),
                      lr: Optional[float] = None,
                      d_model: Optional[int] = None) -> "TrainingProblem":
        data = TextTask.build(corpus, sample_len=tp.sample_len, seed=seed + 99)
        cfg = LSTM_CONFIG.replace(vocab=data.vocab.size)
        if d_model is not None:
            # shrunk variants for overhead-dominated benchmarks (the paper's
            # browser-device regime); same family, same data, fewer cells
            cfg = cfg.replace(d_model=d_model)
        params0 = M.init_params(cfg, jax.random.PRNGKey(seed))
        opt = rmsprop(lr if lr is not None else tp.learning_rate)
        opt_state0 = opt.init(params0)
        return cls(cfg, rt, tp, data, opt, params0, opt_state0)

    def __post_init__(self):
        cfg, rt = self.cfg, self.rt

        def loss(params, batch):
            return M.loss_fn(params, cfg, rt, batch)[0]

        self._grad_fn = jax.jit(jax.value_and_grad(loss))

        def acc_apply(params, opt_state, grads_stacked):
            g_mean = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads_stacked)
            return self.optimizer.update(params, opt_state, g_mean)

        # per-policy apply fns (repro.core.aggregation): SyncBSP reduces the
        # stacked mini-batch gradients; BoundedStaleness applies ONE gradient
        # per commit; LocalSteps adds a weighted (params, opt_state) delta.
        self._acc_apply_fn = jax.jit(acc_apply)
        self._apply_one_fn = jax.jit(self.optimizer.update)
        # donated variant: params/opt_state buffers are consumed and reused
        # for the outputs instead of copied. ONLY safe when the caller owns
        # them exclusively (the server-side applier's hot state) — donating a
        # DataServer-stored blob destroys it for every later reader.
        self._apply_one_don_fn = jax.jit(self.optimizer.update,
                                         donate_argnums=(0, 1))

        def delta_apply(blob, delta, weight):
            return jax.tree.map(
                lambda c, d: (c + weight * d).astype(c.dtype), blob, delta)

        self._delta_apply_fn = jax.jit(delta_apply)
        self._delta_apply_don_fn = jax.jit(delta_apply, donate_argnums=(0,))
        self._apply_batch_fns: Dict[bool, Callable] = {}

    # ------------------------------------------------------------------ schedule
    @property
    def n_versions(self) -> int:
        return self.tp.num_epochs * self.tp.batches_per_epoch

    def version_to_epoch_batch(self, version: int) -> Tuple[int, int]:
        return divmod(version, self.tp.batches_per_epoch)

    def minibatch(self, version: int, mb_index: int) -> Dict[str, np.ndarray]:
        e, b = self.version_to_epoch_batch(version)
        return self.data.minibatch(e, b, self.tp.batch_size, mb_index,
                                   self.tp.mini_batch_size)

    def stream_slot(self, i: int) -> Tuple[int, int]:
        """The global mini-batch stream shared by every aggregation policy:
        slot i -> (version, mb_index), wrapping at the problem horizon (a
        LocalSteps tail slot may run past n_versions * n_mb)."""
        n_mb = self.tp.mini_batches_to_accumulate
        return divmod(i % (self.n_versions * n_mb), n_mb)

    # ------------------------------------------------------------------ compute
    def map_compute(self, params, version: int, mb_index: int):
        """Returns (grads, loss)."""
        batch = self.minibatch(version, mb_index)
        loss, grads = self._grad_fn(params, batch)
        return grads, float(loss)

    def reduce_compute(self, params, opt_state, grads_by_mb: Dict[int, Any]):
        """grads_by_mb: mb_index -> grads. Deterministic order via sort."""
        ordered = [grads_by_mb[i] for i in sorted(grads_by_mb)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ordered)
        return self._acc_apply_fn(params, opt_state, stacked)

    def apply_one(self, params, opt_state, grads, *, donate: bool = False):
        """BoundedStaleness commit: apply one (possibly stale) gradient.

        ``donate=True`` reuses the params/opt_state buffers for the outputs
        (no copy). The inputs are INVALIDATED — only pass buffers the caller
        owns exclusively, never a blob other readers may still fetch."""
        fn = self._apply_one_don_fn if donate else self._apply_one_fn
        return fn(params, opt_state, grads)

    def local_compute(self, params, opt_state, start: int, k: int):
        """LocalSteps ticket: k local optimizer steps from stream offset
        ``start``. Returns ((delta_params, delta_opt_state), mean_loss)."""
        p0, s0 = params, opt_state
        losses: List[float] = []
        for j in range(k):
            v, mb = self.stream_slot(start + j)
            g, l = self.map_compute(params, v, mb)
            params, opt_state = self._apply_one_fn(params, opt_state, g)
            losses.append(l)
        delta = jax.tree.map(lambda a, b: a - b, (params, opt_state),
                             (p0, s0))
        return delta, float(np.mean(losses))

    def apply_delta(self, params, opt_state, delta, weight: float = 1.0, *,
                    donate: bool = False):
        """LocalSteps commit: current blob + weight * delta (dtype-preserving,
        so the int32 optimizer step counter survives a fractional weight).

        ``donate=True`` consumes the (params, opt_state) buffers — same
        exclusive-ownership contract as ``apply_one(donate=True)``."""
        fn = self._delta_apply_don_fn if donate else self._delta_apply_fn
        return fn((params, opt_state), delta, weight)

    # ------------------------------------------------------------- flat batch
    # The batched server applier applies a whole drain of gradients in ONE
    # jitted dispatch: params and every params-shaped optimizer-state subtree
    # are packed into single contiguous f32 vectors and a lax.scan(unroll=1)
    # chains the per-update optimizer steps over the stacked gradient rows.
    # Bit-exactness with the chained ``apply_one`` reference holds because
    # (a) flatten/unflatten is pure data movement and (b) the scan body is
    # compiled once and reused for every step — the same property that makes
    # ``sequential_async`` a usable reference. Unrolling (scan unroll>1 or a
    # Python loop inside one jit) is FORBIDDEN: cross-step fusion contracts
    # mul+add into FMA differently per compilation and breaks bit-equality
    # (verified empirically; see tests/test_applier.py).

    @functools.cached_property
    def _flat_spec(self):
        """(treedef, shapes, sizes, dtype, tree_keys, scalar_keys) when the
        problem qualifies for the flat fast path, else None. Qualifying means:
        one shared float dtype across params leaves, and an optimizer state
        that is a dict of params-treedef-mirroring subtrees plus scalars."""
        leaves, treedef = jax.tree.flatten(self.params0)
        if not leaves:
            return None
        dtype = leaves[0].dtype
        if any(l.dtype != dtype for l in leaves):
            return None
        if not isinstance(self.opt_state0, dict):
            return None
        tree_keys, scalar_keys = [], []
        for k in sorted(self.opt_state0):
            v = self.opt_state0[k]
            sl, sdef = jax.tree.flatten(v)
            if sdef == treedef and len(sl) == len(leaves) and \
                    all(a.shape == b.shape and a.dtype == dtype
                        for a, b in zip(sl, leaves)):
                tree_keys.append(k)
            elif len(sl) == 1 and sl[0].ndim == 0:
                scalar_keys.append(k)
            else:
                return None
        shapes = tuple(l.shape for l in leaves)
        sizes = tuple(int(np.prod(s)) for s in shapes)
        return (treedef, shapes, sizes, dtype, tuple(tree_keys),
                tuple(scalar_keys))

    @property
    def supports_flat_apply(self) -> bool:
        return self._flat_spec is not None

    def pack_grads(self, grads) -> np.ndarray:
        """Host-side flatten of a gradient pytree into one contiguous row
        (exact: pure reshape/concat, no arithmetic)."""
        treedef = self._flat_spec[0]
        return np.concatenate(
            [np.ravel(np.asarray(x)) for x in treedef.flatten_up_to(grads)])

    def pack_grad_rows(self, grads_seq) -> np.ndarray:
        """Stacked ``pack_grads`` rows built with ONE concatenate — the hot
        drain path (per-row concat + stack allocates and copies twice)."""
        treedef = self._flat_spec[0]
        return np.concatenate(
            [np.ravel(np.asarray(x)) for g in grads_seq
             for x in treedef.flatten_up_to(g)]).reshape(len(grads_seq), -1)

    def _flatten_tree(self, tree):
        treedef = self._flat_spec[0]
        return jnp.concatenate(
            [jnp.ravel(x) for x in treedef.flatten_up_to(tree)])

    def _unflatten_tree(self, vec):
        treedef, shapes, sizes = self._flat_spec[:3]
        splits = np.cumsum(sizes)[:-1]
        parts = jnp.split(vec, splits)
        return jax.tree.unflatten(
            treedef, [p.reshape(s) for p, s in zip(parts, shapes)])

    def flat_carry(self, params, opt_state):
        """Pack (params, opt_state) into the scan carry. Every array in the
        carry is freshly created (copied), so the caller owns it and may pass
        it to the donating ``apply_batch_flat``."""
        _, _, _, _, tree_keys, scalar_keys = self._flat_spec
        vecs = {k: self._flatten_tree(opt_state[k]) for k in tree_keys}
        scalars = {k: jnp.array(opt_state[k]) for k in scalar_keys}
        return (self._flatten_tree(params), vecs, scalars)

    def _unflatten_carry_impl(self, carry):
        fp, vecs, scalars = carry
        state = {k: self._unflatten_tree(v) for k, v in vecs.items()}
        state.update({k: v for k, v in scalars.items()})
        return self._unflatten_tree(fp), state

    @functools.cached_property
    def _unflatten_fn(self):
        # unflatten is pure data movement (split/reshape), so jitting cannot
        # change bits — and it folds the dozens of eager slice dispatches
        # into ONE, which is what makes materializing a lazily-published
        # version (FetchModel, measure, snapshot) cheap
        return jax.jit(self._unflatten_carry_impl)

    def unflatten_carry(self, carry):
        """Inverse of ``flat_carry``: (params, opt_state) pytrees."""
        return self._unflatten_fn(carry)

    @functools.cached_property
    def _unflatten_step_fn(self):
        # fused slice+unflatten, one dispatch; ``i`` traces as a dynamic
        # scalar so one compilation serves every step index (retraced only
        # per distinct leading batch length)
        return jax.jit(lambda steps, i: self._unflatten_carry_impl(
            jax.tree.map(lambda a: a[i], steps)))

    def unflatten_step(self, steps, i: int):
        """(params, opt_state) at row ``i`` of a scan's stacked step outputs
        — eager per-leaf indexing costs ~200us/leaf on this box, which is
        what lazily-published versions must NOT pay per materialize."""
        return self._unflatten_step_fn(steps, i)

    def _flat_step(self, carry, g):
        fp, vecs, scalars = carry
        # single-leaf trees are wrapped in LISTS: the optimizers unzip their
        # per-leaf pair results with is_leaf=isinstance(tuple), which a
        # tuple-wrapped container would defeat
        state = {k: [v] for k, v in vecs.items()}
        state.update(scalars)
        new_p, new_s = self.optimizer.update([fp], state, [g])
        new_carry = (new_p[0],
                     {k: new_s[k][0] for k in vecs},
                     {k: new_s[k] for k in scalars})
        return new_carry, new_carry

    def apply_batch_flat(self, carry, grad_rows, *, donate: bool = True):
        """Apply ``B`` stacked flat gradient rows in ONE jitted dispatch.

        Returns ``(carry', steps)`` where ``steps`` mirrors the carry with a
        leading length-B axis — row i is the full flat model/optimizer state
        after update i (needed because a drain publishes every intermediate
        version). ``donate=True`` consumes the carry buffers (the applier owns
        its hot state, so each drain reuses them in place)."""
        fn = self._apply_batch_fns.get(donate)
        if fn is None:
            fn = jax.jit(
                lambda c, gs: jax.lax.scan(self._flat_step, c, gs),
                donate_argnums=(0,) if donate else ())
            self._apply_batch_fns[donate] = fn
        return fn(carry, grad_rows)

    def apply_batch(self, params, opt_state, grads_seq):
        """Pytree-level batched apply: one scan dispatch over a sequence of
        gradient pytrees. Returns the list of per-step (params, opt_state) —
        bit-identical to folding ``apply_one`` over ``grads_seq``."""
        if not grads_seq:
            return []
        rows = jnp.asarray(self.pack_grad_rows(grads_seq))
        carry = self.flat_carry(params, opt_state)
        _, steps = self.apply_batch_flat(carry, rows, donate=True)
        return [self.unflatten_step(steps, i) for i in range(len(grads_seq))]

    # ------------------------------------------------------------------ sizes
    @functools.cached_property
    def grad_bytes(self) -> int:
        return dense_bytes(self.params0)

    @functools.cached_property
    def model_bytes(self) -> int:
        return dense_bytes(self.params0) + dense_bytes(self.opt_state0)

    def flops_per_map(self) -> float:
        """Analytic cost of one mini-batch fwd+bwd (simulator cost model)."""
        n = M.param_count(self.cfg)
        tokens = self.tp.mini_batch_size * self.tp.sample_len
        return 6.0 * n * tokens

    def flops_per_reduce(self) -> float:
        n = M.param_count(self.cfg)
        return 8.0 * n * self.tp.mini_batches_to_accumulate


# ---------------------------------------------------------------------------
# sequential references (paper §V.C)
# ---------------------------------------------------------------------------

def sequential_accumulated(problem: TrainingProblem, *, n_versions=None,
                           record_every: int = 1):
    """The distributed algorithm run on one in-process worker (exact reference
    for worker-count invariance: must bit-match any Coordinator run)."""
    params, opt_state = problem.params0, problem.opt_state0
    losses: List[float] = []
    n = n_versions if n_versions is not None else problem.n_versions
    for v in range(n):
        grads_by_mb, ls = {}, []
        for mb in range(problem.tp.mini_batches_to_accumulate):
            g, l = problem.map_compute(params, v, mb)
            grads_by_mb[mb] = g
            ls.append(l)
        params, opt_state = problem.reduce_compute(params, opt_state, grads_by_mb)
        if (v % record_every) == 0:
            losses.append(float(np.mean(ls)))
    return params, opt_state, losses


def sequential_async(problem: TrainingProblem, *, n_updates=None):
    """BoundedStaleness run on ONE worker (every gradient is perfectly
    fresh): plain minibatch SGD over the global mini-batch stream. The exact
    reference for ``Coordinator(policy=BoundedStaleness(...))`` — the
    Coordinator's round-robin scheduler serializes barrierless tickets, so
    ANY worker count must bit-match this."""
    params, opt_state = problem.params0, problem.opt_state0
    n_mb = problem.tp.mini_batches_to_accumulate
    n = n_updates if n_updates is not None else problem.n_versions * n_mb
    losses: List[float] = []
    for i in range(n):
        v, mb = problem.stream_slot(i)
        g, l = problem.map_compute(params, v, mb)
        params, opt_state = problem.apply_one(params, opt_state, g)
        losses.append(l)
    return params, opt_state, losses


def sequential_local(problem: TrainingProblem, *, k: int = 4,
                     weight: float = 1.0, n_updates=None):
    """LocalSteps run on ONE worker: k local optimizer steps per round, the
    round's delta applied through the same jitted ``apply_delta`` the
    distributed commit uses (so a 1-worker Coordinator bit-matches)."""
    params, opt_state = problem.params0, problem.opt_state0
    total = problem.n_versions * problem.tp.mini_batches_to_accumulate
    n = n_updates if n_updates is not None else -(-total // k)
    losses: List[float] = []
    for slot in range(n):
        delta, l = problem.local_compute(params, opt_state, slot * k, k)
        params, opt_state = problem.apply_delta(params, opt_state, delta,
                                                weight)
        losses.append(l)
    return params, opt_state, losses


def sequential_fullbatch(problem: TrainingProblem, *, batch_size=None,
                         n_versions=None):
    """TFJS-Sequential-N: plain minibatch SGD at the given batch size (128 for
    the paper's headline sequential baseline, 8 for TFJS-Sequential-8)."""
    tp = problem.tp
    bs = batch_size or tp.batch_size
    params, opt_state = problem.params0, problem.opt_state0
    losses: List[float] = []
    n = n_versions if n_versions is not None else problem.n_versions
    steps_per_version = tp.batch_size // bs
    for v in range(n):
        e, b = problem.version_to_epoch_batch(v)
        starts = problem.data.starts(e, b, tp.batch_size)
        for s in range(steps_per_version):
            batch = problem.data.make_batch(starts[s * bs:(s + 1) * bs])
            loss, grads = problem._grad_fn(params, batch)
            params, opt_state = problem.optimizer.update(params, opt_state, grads)
            losses.append(float(loss))
    return params, opt_state, losses
