"""The map/reduce compute of JSDoop's distributed SGD (paper §IV.G, Fig. 3).

map(version, mb)   = gradient of the mini-batch loss at model version v
reduce(version, *) = mean of the n_mb gradients (sorted by mb_index so the sum
                     order — and hence the floats — are independent of which
                     volunteer computed what, making the paper's Table-4
                     invariance an exact, testable equality), then the RMSprop
                     apply, producing model version v+1.

``TrainingProblem`` packages the model, optimizer, data schedule and jitted
compute; the Initiator, Coordinator and Simulator all consume it.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_lstm import CONFIG as LSTM_CONFIG, TrainParams, PAPER_PARAMS
from repro.data.text import TextTask
from repro.models import model as M
from repro.models.runtime import Runtime
from repro.optim import Optimizer, rmsprop, dense_bytes


@dataclass
class TrainingProblem:
    cfg: Any                     # ArchConfig (vocab resolved)
    rt: Runtime
    tp: TrainParams
    data: TextTask
    optimizer: Optimizer
    params0: Any
    opt_state0: Any

    _grad_fn: Callable = field(default=None, repr=False)
    _acc_apply_fn: Callable = field(default=None, repr=False)

    # ------------------------------------------------------------------ build
    @classmethod
    def paper_problem(cls, *, seed: int = 0, corpus: Optional[str] = None,
                      tp: TrainParams = PAPER_PARAMS,
                      rt: Runtime = Runtime(remat=False),
                      lr: Optional[float] = None) -> "TrainingProblem":
        data = TextTask.build(corpus, sample_len=tp.sample_len, seed=seed + 99)
        cfg = LSTM_CONFIG.replace(vocab=data.vocab.size)
        params0 = M.init_params(cfg, jax.random.PRNGKey(seed))
        opt = rmsprop(lr if lr is not None else tp.learning_rate)
        opt_state0 = opt.init(params0)
        return cls(cfg, rt, tp, data, opt, params0, opt_state0)

    def __post_init__(self):
        cfg, rt = self.cfg, self.rt

        def loss(params, batch):
            return M.loss_fn(params, cfg, rt, batch)[0]

        self._grad_fn = jax.jit(jax.value_and_grad(loss))

        def acc_apply(params, opt_state, grads_stacked):
            g_mean = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads_stacked)
            return self.optimizer.update(params, opt_state, g_mean)

        # per-policy apply fns (repro.core.aggregation): SyncBSP reduces the
        # stacked mini-batch gradients; BoundedStaleness applies ONE gradient
        # per commit; LocalSteps adds a weighted (params, opt_state) delta.
        self._acc_apply_fn = jax.jit(acc_apply)
        self._apply_one_fn = jax.jit(self.optimizer.update)

        def delta_apply(blob, delta, weight):
            return jax.tree.map(
                lambda c, d: (c + weight * d).astype(c.dtype), blob, delta)

        self._delta_apply_fn = jax.jit(delta_apply)

    # ------------------------------------------------------------------ schedule
    @property
    def n_versions(self) -> int:
        return self.tp.num_epochs * self.tp.batches_per_epoch

    def version_to_epoch_batch(self, version: int) -> Tuple[int, int]:
        return divmod(version, self.tp.batches_per_epoch)

    def minibatch(self, version: int, mb_index: int) -> Dict[str, np.ndarray]:
        e, b = self.version_to_epoch_batch(version)
        return self.data.minibatch(e, b, self.tp.batch_size, mb_index,
                                   self.tp.mini_batch_size)

    def stream_slot(self, i: int) -> Tuple[int, int]:
        """The global mini-batch stream shared by every aggregation policy:
        slot i -> (version, mb_index), wrapping at the problem horizon (a
        LocalSteps tail slot may run past n_versions * n_mb)."""
        n_mb = self.tp.mini_batches_to_accumulate
        return divmod(i % (self.n_versions * n_mb), n_mb)

    # ------------------------------------------------------------------ compute
    def map_compute(self, params, version: int, mb_index: int):
        """Returns (grads, loss)."""
        batch = self.minibatch(version, mb_index)
        loss, grads = self._grad_fn(params, batch)
        return grads, float(loss)

    def reduce_compute(self, params, opt_state, grads_by_mb: Dict[int, Any]):
        """grads_by_mb: mb_index -> grads. Deterministic order via sort."""
        ordered = [grads_by_mb[i] for i in sorted(grads_by_mb)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ordered)
        return self._acc_apply_fn(params, opt_state, stacked)

    def apply_one(self, params, opt_state, grads):
        """BoundedStaleness commit: apply one (possibly stale) gradient."""
        return self._apply_one_fn(params, opt_state, grads)

    def local_compute(self, params, opt_state, start: int, k: int):
        """LocalSteps ticket: k local optimizer steps from stream offset
        ``start``. Returns ((delta_params, delta_opt_state), mean_loss)."""
        p0, s0 = params, opt_state
        losses: List[float] = []
        for j in range(k):
            v, mb = self.stream_slot(start + j)
            g, l = self.map_compute(params, v, mb)
            params, opt_state = self._apply_one_fn(params, opt_state, g)
            losses.append(l)
        delta = jax.tree.map(lambda a, b: a - b, (params, opt_state),
                             (p0, s0))
        return delta, float(np.mean(losses))

    def apply_delta(self, params, opt_state, delta, weight: float = 1.0):
        """LocalSteps commit: current blob + weight * delta (dtype-preserving,
        so the int32 optimizer step counter survives a fractional weight)."""
        return self._delta_apply_fn((params, opt_state), delta, weight)

    # ------------------------------------------------------------------ sizes
    @functools.cached_property
    def grad_bytes(self) -> int:
        return dense_bytes(self.params0)

    @functools.cached_property
    def model_bytes(self) -> int:
        return dense_bytes(self.params0) + dense_bytes(self.opt_state0)

    def flops_per_map(self) -> float:
        """Analytic cost of one mini-batch fwd+bwd (simulator cost model)."""
        n = M.param_count(self.cfg)
        tokens = self.tp.mini_batch_size * self.tp.sample_len
        return 6.0 * n * tokens

    def flops_per_reduce(self) -> float:
        n = M.param_count(self.cfg)
        return 8.0 * n * self.tp.mini_batches_to_accumulate


# ---------------------------------------------------------------------------
# sequential references (paper §V.C)
# ---------------------------------------------------------------------------

def sequential_accumulated(problem: TrainingProblem, *, n_versions=None,
                           record_every: int = 1):
    """The distributed algorithm run on one in-process worker (exact reference
    for worker-count invariance: must bit-match any Coordinator run)."""
    params, opt_state = problem.params0, problem.opt_state0
    losses: List[float] = []
    n = n_versions if n_versions is not None else problem.n_versions
    for v in range(n):
        grads_by_mb, ls = {}, []
        for mb in range(problem.tp.mini_batches_to_accumulate):
            g, l = problem.map_compute(params, v, mb)
            grads_by_mb[mb] = g
            ls.append(l)
        params, opt_state = problem.reduce_compute(params, opt_state, grads_by_mb)
        if (v % record_every) == 0:
            losses.append(float(np.mean(ls)))
    return params, opt_state, losses


def sequential_async(problem: TrainingProblem, *, n_updates=None):
    """BoundedStaleness run on ONE worker (every gradient is perfectly
    fresh): plain minibatch SGD over the global mini-batch stream. The exact
    reference for ``Coordinator(policy=BoundedStaleness(...))`` — the
    Coordinator's round-robin scheduler serializes barrierless tickets, so
    ANY worker count must bit-match this."""
    params, opt_state = problem.params0, problem.opt_state0
    n_mb = problem.tp.mini_batches_to_accumulate
    n = n_updates if n_updates is not None else problem.n_versions * n_mb
    losses: List[float] = []
    for i in range(n):
        v, mb = problem.stream_slot(i)
        g, l = problem.map_compute(params, v, mb)
        params, opt_state = problem.apply_one(params, opt_state, g)
        losses.append(l)
    return params, opt_state, losses


def sequential_local(problem: TrainingProblem, *, k: int = 4,
                     weight: float = 1.0, n_updates=None):
    """LocalSteps run on ONE worker: k local optimizer steps per round, the
    round's delta applied through the same jitted ``apply_delta`` the
    distributed commit uses (so a 1-worker Coordinator bit-matches)."""
    params, opt_state = problem.params0, problem.opt_state0
    total = problem.n_versions * problem.tp.mini_batches_to_accumulate
    n = n_updates if n_updates is not None else -(-total // k)
    losses: List[float] = []
    for slot in range(n):
        delta, l = problem.local_compute(params, opt_state, slot * k, k)
        params, opt_state = problem.apply_delta(params, opt_state, delta,
                                                weight)
        losses.append(l)
    return params, opt_state, losses


def sequential_fullbatch(problem: TrainingProblem, *, batch_size=None,
                         n_versions=None):
    """TFJS-Sequential-N: plain minibatch SGD at the given batch size (128 for
    the paper's headline sequential baseline, 8 for TFJS-Sequential-8)."""
    tp = problem.tp
    bs = batch_size or tp.batch_size
    params, opt_state = problem.params0, problem.opt_state0
    losses: List[float] = []
    n = n_versions if n_versions is not None else problem.n_versions
    steps_per_version = tp.batch_size // bs
    for v in range(n):
        e, b = problem.version_to_epoch_batch(v)
        starts = problem.data.starts(e, b, tp.batch_size)
        for s in range(steps_per_version):
            batch = problem.data.make_batch(starts[s * bs:(s + 1) * bs])
            loss, grads = problem._grad_fn(params, batch)
            params, opt_state = problem.optimizer.update(params, opt_state, grads)
            losses.append(float(loss))
    return params, opt_state, losses
