"""Discrete-event simulator of the JSDoop deployment (cluster & classroom).

Reproduces the paper's scalability experiments (Figs. 4-8, Table 4) on one CPU
by simulating heterogeneous volunteers over the *same* queue/dataserver
semantics the real Coordinator uses. Costs:

- network: latency + bytes/bandwidth per transfer (model pull, gradient push),
- compute: task_flops / (volunteer speed * effective_throughput),
- cache effect: the paper attributes its superlinear relative speedup to "more
  of its data can be placed in fast memory" when the work is spread over more
  processors [Foster'95]. We model this mechanistically: a volunteer that must
  cycle the whole working set (model + optimizer + all mini-batches of a batch)
  through its cache sustains a penalized throughput; when k>=2 volunteers split
  the batch, the per-volunteer working set fits and throughput recovers.

All semantics (lease/ack/requeue, version waits, reduce barrier, churn) are
identical to the real Coordinator — asserted by tests.
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.dataserver import DataServer
from repro.core.mapreduce import TrainingProblem
from repro.core.queue import QueueServer
from repro.core.tasks import (INITIAL_QUEUE, GradResult, MapTask, ReduceTask,
                              results_queue)


@dataclass
class VolunteerSpec:
    vid: str
    speed: float = 1.0              # relative device speed
    join_time: float = 0.0
    leave_time: float = math.inf


@dataclass
class CostModel:
    flops_per_sec: float = 2.0e9    # sustained JS/WebGL throughput of one device
    latency: float = 0.030          # one-way message latency (s)
    bandwidth: float = 12.5e6       # bytes/s (100 Mbit LAN)
    poll_interval: float = 0.200    # dependency-wait poll (s)
    # cache-effect model (superlinearity, paper §V.A):
    cache_bytes: float = 4.0e6      # fast-memory budget per device
    thrash_penalty: float = 0.22    # throughput multiplier when set exceeds cache

    def throughput(self, speed: float, working_set: float) -> float:
        base = self.flops_per_sec * speed
        if working_set > self.cache_bytes:
            return base * self.thrash_penalty
        return base

    def xfer(self, nbytes: float) -> float:
        return self.latency + nbytes / self.bandwidth


@dataclass
class TimelineEvent:
    vid: str
    kind: str                        # "Compute" (map) | "Accumulate" (reduce)
    start: float
    end: float
    version: int


@dataclass
class SimResult:
    makespan: float
    timeline: List[TimelineEvent]
    tasks_by_worker: Dict[str, int]
    requeues: int
    final_version: int
    bytes_sent: float
    busy_time: Dict[str, float]


class Simulator:
    """Event loop: volunteers wake, lease, (wait | compute), publish, ack."""

    def __init__(self, problem: TrainingProblem, specs: List[VolunteerSpec], *,
                 cost: CostModel = None, n_versions: Optional[int] = None,
                 visibility_timeout: float = 900.0, grad_bytes=None,
                 model_bytes=None):
        from repro.core.initiator import enqueue_problem
        self.problem = problem
        self.cost = cost or CostModel()
        self.qs = QueueServer(default_timeout=visibility_timeout)
        self.ds = DataServer()
        self.n_versions = (n_versions if n_versions is not None
                           else problem.n_versions)
        enqueue_problem(problem, self.qs, self.ds, n_versions=self.n_versions,
                        store_real_model=False)
        self.specs = {s.vid: s for s in specs}
        self.grad_bytes = grad_bytes if grad_bytes is not None else problem.grad_bytes
        self.model_bytes = model_bytes if model_bytes is not None else problem.model_bytes
        self.map_flops = problem.flops_per_map()
        self.reduce_flops = problem.flops_per_reduce()
        # per-batch working set: model+opt state+minibatch activations per task
        self._heap: List[Tuple[float, int, Callable]] = []
        self._seq = itertools.count()
        self.timeline: List[TimelineEvent] = []
        self.tasks_by_worker: Dict[str, int] = {}
        self.busy: Dict[str, float] = {}
        self.bytes_sent = 0.0
        self.done_time = 0.0

    # ------------------------------------------------------------------ engine
    def _post(self, t: float, fn: Callable):
        heapq.heappush(self._heap, (t, next(self._seq), fn))

    def run(self) -> SimResult:
        for s in self.specs.values():
            self._post(s.join_time, lambda vid=s.vid: self._wake(vid))
        guard = 0
        while self._heap and self.ds.latest_version < self.n_versions:
            guard += 1
            if guard > 5_000_000:
                raise RuntimeError("simulator runaway")
            t, _, fn = heapq.heappop(self._heap)
            self._now = t
            self.qs.expire_all(t)
            fn()
        requeues = sum(q.requeued for q in self.qs.queues.values())
        return SimResult(self.done_time, self.timeline,
                         dict(self.tasks_by_worker), requeues,
                         self.ds.latest_version, self.bytes_sent,
                         dict(self.busy))

    def _alive(self, vid: str) -> bool:
        s = self.specs[vid]
        return s.join_time <= self._now < s.leave_time

    def _wake(self, vid: str):
        """Volunteer becomes idle at _now: try to lease the next task."""
        if self.ds.latest_version >= self.n_versions:
            return
        if not self._alive(vid):
            self.qs.drop_consumer(vid)
            return
        now = self._now
        got = self.qs.lease(INITIAL_QUEUE, vid, now)
        if got is None:
            if not self.qs.drained([INITIAL_QUEUE]):
                self._post(now + self.cost.poll_interval,
                           lambda: self._wake(vid))
            return
        tag, task = got
        self._post(now + self.cost.latency,
                   lambda: self._dispatch(vid, tag, task))

    def _dispatch(self, vid: str, tag: int, task):
        if not self._alive(vid):
            self.qs.drop_consumer(vid)
            return
        if isinstance(task, MapTask):
            self._run_map(vid, tag, task)
        else:
            self._run_reduce(vid, tag, task)

    # ------------------------------------------------------------------ map
    def _run_map(self, vid: str, tag: int, t: MapTask):
        now = self._now
        if self.ds.latest_version > t.version:
            self.qs.ack(INITIAL_QUEUE, tag)
            self._post(now, lambda: self._wake(vid))
            return
        if self.ds.get_model(t.version) is None:
            self._post(now + self.cost.poll_interval,
                       lambda: self._dispatch(vid, tag, t))
            return
        spec = self.specs[vid]
        # working set: a lone volunteer cycles model+opt+the whole 128-batch
        # through cache; k volunteers each hold ~1/k of the batch's tasks.
        active = sum(1 for s in self.specs.values()
                     if s.join_time <= now < s.leave_time)
        share = (self.model_bytes
                 + self.grad_bytes
                 + self._batch_bytes() / max(active, 1))
        thr = self.cost.throughput(spec.speed, share)
        fetch = self.cost.xfer(self.model_bytes)
        compute = self.map_flops / thr
        push = self.cost.xfer(self.grad_bytes)
        start = now + fetch
        end = start + compute + push

        def finish():
            if not self._alive(vid):
                self.qs.drop_consumer(vid)  # task requeues via its lease
                return
            if self.ds.latest_version > t.version:
                self.qs.ack(INITIAL_QUEUE, tag)
            else:
                self.qs.publish(results_queue(t.version),
                                GradResult(t.version, t.mb_index, None,
                                           self.grad_bytes, 0.0, vid))
                self.qs.ack(INITIAL_QUEUE, tag)
                self.timeline.append(TimelineEvent(vid, "Compute", now, end,
                                                   t.version))
                self.tasks_by_worker[vid] = self.tasks_by_worker.get(vid, 0) + 1
                self.busy[vid] = self.busy.get(vid, 0.0) + (end - now)
                self.bytes_sent += self.grad_bytes + self.model_bytes
            self._wake(vid)

        self._post(end, finish)

    def _batch_bytes(self) -> float:
        tp = self.problem.tp
        sample = tp.sample_len * max(self.problem.cfg.vocab, 96) * 4
        return tp.batch_size * sample

    # ------------------------------------------------------------------ reduce
    def _run_reduce(self, vid: str, tag: int, t: ReduceTask):
        now = self._now
        if self.ds.latest_version > t.version:
            self.qs.ack(INITIAL_QUEUE, tag)
            self._post(now, lambda: self._wake(vid))
            return
        rq = results_queue(t.version)
        if self.qs.depth(rq) < t.n_mb:
            self._post(now + self.cost.poll_interval,
                       lambda: self._dispatch(vid, tag, t))
            return
        tags = []
        seen = set()
        while True:
            got = self.qs.lease(rq, vid, now)
            if got is None:
                break
            rtag, res = got
            tags.append(rtag)
            seen.add(res.mb_index)
        if len(seen) < t.n_mb:
            for rtag in tags:
                self.qs.nack(rq, rtag)
            self._post(now + self.cost.poll_interval,
                       lambda: self._dispatch(vid, tag, t))
            return
        spec = self.specs[vid]
        pull = self.cost.xfer(self.grad_bytes * t.n_mb) + self.cost.xfer(
            self.model_bytes)
        compute = self.reduce_flops / (self.cost.flops_per_sec * spec.speed)
        push = self.cost.xfer(self.model_bytes)
        end = now + pull + compute + push

        def finish():
            if not self._alive(vid):
                self.qs.drop_consumer(vid)
                for rtag in tags:
                    self.qs.nack(rq, rtag)
                return
            self.ds.publish_model(t.version + 1, "blob",
                                  nbytes=self.model_bytes)
            for rtag in tags:
                self.qs.ack(rq, rtag)
            self.qs.ack(INITIAL_QUEUE, tag)
            self.timeline.append(TimelineEvent(vid, "Accumulate", now, end,
                                               t.version))
            self.tasks_by_worker[vid] = self.tasks_by_worker.get(vid, 0) + 1
            self.busy[vid] = self.busy.get(vid, 0.0) + (end - now)
            self.bytes_sent += self.grad_bytes * t.n_mb + 2 * self.model_bytes
            self.done_time = max(self.done_time, end)
            self._wake(vid)

        self._post(end, finish)
