"""Discrete-event simulator of the JSDoop deployment (cluster & classroom).

Reproduces the paper's scalability experiments (Figs. 4-8, Table 4) on one CPU
by simulating heterogeneous volunteers over the *same* queue/dataserver
semantics the real Coordinator uses. Costs:

- network: latency + bytes/bandwidth per transfer (model pull, gradient push),
- compute: task_flops / (volunteer speed * effective_throughput),
- cache effect: the paper attributes its superlinear relative speedup to "more
  of its data can be placed in fast memory" when the work is spread over more
  processors [Foster'95]. We model this mechanistically: a volunteer that must
  cycle the whole working set (model + optimizer + all mini-batches of a batch)
  through its cache sustains a penalized throughput; when k>=2 volunteers split
  the batch, the per-volunteer working set fits and throughput recovers.

All semantics (lease/ack/requeue, version waits, reduce barrier, churn) are
identical to the real Coordinator — asserted by tests.

Two coordination modes share every cost and protocol rule:

- ``mode="event"`` (default): waits are push-based. An idle volunteer
  subscribes to the task queue (woken by the next publish/requeue), a map task
  whose model version is missing registers a ``DataServer.watch_version``, and
  a reduce task's barrier subscribes to publishes on its results queue. Total
  events scale with the amount of WORK, not with waiting time.
- ``mode="poll"``: the pre-subscription baseline — every wait reschedules
  itself every ``cost.poll_interval`` seconds, so events scale with
  O(volunteers x makespan / poll_interval). Kept for benchmarking
  (`benchmarks/volunteer_scaling.py`) and the cross-mode equivalence tests.
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple, Union

from repro.core.dataserver import DataServer
from repro.core.mapreduce import TrainingProblem
from repro.core.queue import QueueServer, ShardedQueueServer
from repro.core.tasks import (INITIAL_QUEUE, GradResult, MapTask, ReduceTask,
                              results_queue)


@dataclass
class VolunteerSpec:
    vid: str
    speed: float = 1.0              # relative device speed
    join_time: float = 0.0
    leave_time: float = math.inf


@dataclass(frozen=True)
class _SyntheticTrainParams:
    batch_size: int
    mini_batch_size: int
    mini_batches_to_accumulate: int
    sample_len: int
    batches_per_epoch: int


@dataclass(frozen=True)
class _SyntheticConfig:
    vocab: int


class SyntheticProblem:
    """Duck-typed TrainingProblem stand-in for timing-only simulations.

    The Simulator never calls map_compute/reduce_compute, so scale studies
    (1k-10k volunteers) don't need a jax model at all — just the task schedule
    and the byte/flop sizes the cost model consumes. Constructs in microseconds
    at any scale.
    """

    def __init__(self, *, n_versions: int = 20, n_mb: int = 32,
                 mini_batch_size: int = 8, sample_len: int = 50,
                 vocab: int = 96, model_bytes: float = 2.0e6,
                 grad_bytes: float = 1.0e6, map_flops: float = 1.0e9,
                 reduce_flops: float = 2.0e7, batches_per_epoch: int = 0):
        self.tp = _SyntheticTrainParams(
            batch_size=n_mb * mini_batch_size,
            mini_batch_size=mini_batch_size,
            mini_batches_to_accumulate=n_mb,
            sample_len=sample_len,
            batches_per_epoch=batches_per_epoch or n_versions)
        self.cfg = _SyntheticConfig(vocab=vocab)
        self.n_versions = n_versions
        self.model_bytes = model_bytes
        self.grad_bytes = grad_bytes
        self._map_flops = map_flops
        self._reduce_flops = reduce_flops

    def version_to_epoch_batch(self, version: int):
        return divmod(version, self.tp.batches_per_epoch)

    def flops_per_map(self) -> float:
        return self._map_flops

    def flops_per_reduce(self) -> float:
        return self._reduce_flops


@dataclass
class CostModel:
    flops_per_sec: float = 2.0e9    # sustained JS/WebGL throughput of one device
    latency: float = 0.030          # one-way message latency (s)
    bandwidth: float = 12.5e6       # bytes/s (100 Mbit LAN)
    poll_interval: float = 0.200    # dependency-wait poll (s) — poll mode only
    # cache-effect model (superlinearity, paper §V.A):
    cache_bytes: float = 4.0e6      # fast-memory budget per device
    thrash_penalty: float = 0.22    # throughput multiplier when set exceeds cache

    def throughput(self, speed: float, working_set: float) -> float:
        base = self.flops_per_sec * speed
        if working_set > self.cache_bytes:
            return base * self.thrash_penalty
        return base

    def xfer(self, nbytes: float) -> float:
        return self.latency + nbytes / self.bandwidth


@dataclass
class TimelineEvent:
    vid: str
    kind: str                        # "Compute" (map) | "Accumulate" (reduce)
    start: float
    end: float
    version: int


@dataclass
class SimResult:
    makespan: float
    timeline: List[TimelineEvent]
    tasks_by_worker: Dict[str, int]
    requeues: int
    final_version: int
    bytes_sent: float
    busy_time: Dict[str, float]
    events: int = 0                  # simulator events processed
    poll_events: int = 0             # events that were poll reschedules
    mode: str = "event"
    expire_scans: int = 0            # expiry sweeps actually performed


class Simulator:
    """Event loop: volunteers wake, lease, (wait | compute), publish, ack."""

    def __init__(self, problem: TrainingProblem, specs: List[VolunteerSpec], *,
                 cost: CostModel = None, n_versions: Optional[int] = None,
                 visibility_timeout: float = 900.0, grad_bytes=None,
                 model_bytes=None, mode: str = "event", n_shards: int = 1,
                 max_events: int = 5_000_000):
        from repro.core.initiator import enqueue_problem
        if mode not in ("event", "poll"):
            raise ValueError(f"unknown mode {mode!r}")
        self.problem = problem
        self.cost = cost or CostModel()
        self.mode = mode
        self.max_events = max_events
        self.qs: Union[QueueServer, ShardedQueueServer] = (
            QueueServer(default_timeout=visibility_timeout) if n_shards <= 1
            else ShardedQueueServer(n_shards, default_timeout=visibility_timeout))
        self.ds = DataServer()
        self.n_versions = (n_versions if n_versions is not None
                           else problem.n_versions)
        enqueue_problem(problem, self.qs, self.ds, n_versions=self.n_versions,
                        store_real_model=False)
        self.specs = {s.vid: s for s in specs}
        self.grad_bytes = grad_bytes if grad_bytes is not None else problem.grad_bytes
        self.model_bytes = model_bytes if model_bytes is not None else problem.model_bytes
        self.map_flops = problem.flops_per_map()
        self.reduce_flops = problem.flops_per_reduce()
        # per-batch working set: model+opt state+minibatch activations per task
        self._heap: List[Tuple[float, int, Callable]] = []
        self._seq = itertools.count()
        self.timeline: List[TimelineEvent] = []
        self.tasks_by_worker: Dict[str, int] = {}
        self.busy: Dict[str, float] = {}
        self.bytes_sent = 0.0
        self.done_time = 0.0
        self.events = 0
        self.poll_events = 0
        self.expire_scans = 0
        self.expired = 0                 # messages requeued by expiry sweeps

    # ------------------------------------------------------------------ engine
    def _post(self, t: float, fn: Callable):
        heapq.heappush(self._heap, (t, next(self._seq), fn))

    def _post_poll(self, t: float, fn: Callable):
        self.poll_events += 1
        self._post(t, fn)

    def run(self) -> SimResult:
        for s in self.specs.values():
            self._post(s.join_time, lambda vid=s.vid: self._wake(vid))
        while self._heap and self.ds.latest_version < self.n_versions:
            self.events += 1
            if self.events > self.max_events:
                raise RuntimeError("simulator runaway")
            t, _, fn = heapq.heappop(self._heap)
            self._now = t
            # O(expired), not O(queues x events): sweep only when the earliest
            # live visibility deadline has actually passed — each sweep is then
            # guaranteed to requeue at least one message.
            dl = self.qs.next_deadline()
            if dl is not None and dl <= t:
                self.expire_scans += 1
                self.expired += self.qs.expire_all(t)
            fn()
        return SimResult(self.done_time, self.timeline,
                         dict(self.tasks_by_worker), self.qs.total_requeued,
                         self.ds.latest_version, self.bytes_sent,
                         dict(self.busy), self.events, self.poll_events,
                         self.mode, self.expire_scans)

    def _alive(self, vid: str) -> bool:
        s = self.specs[vid]
        return s.join_time <= self._now < s.leave_time

    # wait primitives: poll reschedules, event subscribes ----------------------
    def _resume(self, fn: Callable):
        """Subscription callback -> simulator event at the current virtual time
        (the wake happens inside whatever event triggered the notify)."""
        self._post(self._now, fn)

    def _wake(self, vid: str):
        """Volunteer becomes idle at _now: try to lease the next task."""
        if self.ds.latest_version >= self.n_versions:
            return
        if not self._alive(vid):
            # a departed volunteer: requeue whatever it held (wakes the next
            # waiter via the requeue notification); if it consumed a wake while
            # holding nothing, pass that wake on so no event is lost
            if self.qs.drop_consumer(vid) == 0:
                self.qs.kick(INITIAL_QUEUE)
            return
        now = self._now
        got = self.qs.lease(INITIAL_QUEUE, vid, now)
        if got is None:
            if not self.qs.drained([INITIAL_QUEUE]):
                if self.mode == "poll":
                    self._post_poll(now + self.cost.poll_interval,
                                    lambda: self._wake(vid))
                else:
                    self.qs.subscribe(INITIAL_QUEUE, vid,
                                      lambda: self._resume(
                                          lambda: self._wake(vid)))
            return
        tag, task = got
        self._post(now + self.cost.latency,
                   lambda: self._dispatch(vid, tag, task))

    def _dispatch(self, vid: str, tag: int, task):
        if not self._alive(vid):
            self.qs.drop_consumer(vid)
            return
        if isinstance(task, MapTask):
            self._run_map(vid, tag, task)
        else:
            self._run_reduce(vid, tag, task)

    # ------------------------------------------------------------------ map
    def _run_map(self, vid: str, tag: int, t: MapTask):
        now = self._now
        if self.ds.latest_version > t.version:
            self.qs.ack(INITIAL_QUEUE, tag)
            self._post(now, lambda: self._wake(vid))
            return
        if self.ds.get_model(t.version) is None:
            if self.mode == "poll":
                self._post_poll(now + self.cost.poll_interval,
                                lambda: self._dispatch(vid, tag, t))
            else:
                self.ds.watch_version(
                    t.version,
                    lambda: self._resume(lambda: self._dispatch(vid, tag, t)))
            return
        spec = self.specs[vid]
        # working set: a lone volunteer cycles model+opt+the whole 128-batch
        # through cache; k volunteers each hold ~1/k of the batch's tasks.
        active = sum(1 for s in self.specs.values()
                     if s.join_time <= now < s.leave_time)
        share = (self.model_bytes
                 + self.grad_bytes
                 + self._batch_bytes() / max(active, 1))
        thr = self.cost.throughput(spec.speed, share)
        fetch = self.cost.xfer(self.model_bytes)
        compute = self.map_flops / thr
        push = self.cost.xfer(self.grad_bytes)
        start = now + fetch
        end = start + compute + push

        def finish():
            if not self._alive(vid):
                self.qs.drop_consumer(vid)  # task requeues via its lease
                return
            if self.ds.latest_version > t.version:
                self.qs.ack(INITIAL_QUEUE, tag)
            else:
                self.qs.publish(results_queue(t.version),
                                GradResult(t.version, t.mb_index, None,
                                           self.grad_bytes, 0.0, vid))
                self.qs.ack(INITIAL_QUEUE, tag)
                self.timeline.append(TimelineEvent(vid, "Compute", now, end,
                                                   t.version))
                self.tasks_by_worker[vid] = self.tasks_by_worker.get(vid, 0) + 1
                self.busy[vid] = self.busy.get(vid, 0.0) + (end - now)
                self.bytes_sent += self.grad_bytes + self.model_bytes
            self._wake(vid)

        self._post(end, finish)

    def _batch_bytes(self) -> float:
        tp = self.problem.tp
        sample = tp.sample_len * max(self.problem.cfg.vocab, 96) * 4
        return tp.batch_size * sample

    # ------------------------------------------------------------------ reduce
    def _run_reduce(self, vid: str, tag: int, t: ReduceTask):
        now = self._now
        if self.ds.latest_version > t.version:
            self.qs.ack(INITIAL_QUEUE, tag)
            self._post(now, lambda: self._wake(vid))
            return
        rq = results_queue(t.version)

        def wait_for_results():
            if self.mode == "poll":
                self._post_poll(now + self.cost.poll_interval,
                                lambda: self._dispatch(vid, tag, t))
            else:
                # woken by the NEXT publish on the results queue — requeues
                # (e.g. our own nacks below) must not wake the barrier
                self.qs.subscribe(rq, vid,
                                  lambda: self._resume(
                                      lambda: self._dispatch(vid, tag, t)),
                                  kind="publish")

        if self.qs.depth(rq) < t.n_mb:
            wait_for_results()
            return
        tags = []
        seen = set()
        while True:
            got = self.qs.lease(rq, vid, now)
            if got is None:
                break
            rtag, res = got
            tags.append(rtag)
            seen.add(res.mb_index)
        if len(seen) < t.n_mb:
            for rtag in tags:
                self.qs.nack(rq, rtag)
            wait_for_results()
            return
        spec = self.specs[vid]
        pull = self.cost.xfer(self.grad_bytes * t.n_mb) + self.cost.xfer(
            self.model_bytes)
        compute = self.reduce_flops / (self.cost.flops_per_sec * spec.speed)
        push = self.cost.xfer(self.model_bytes)
        end = now + pull + compute + push

        def finish():
            if not self._alive(vid):
                self.qs.drop_consumer(vid)
                for rtag in tags:
                    self.qs.nack(rq, rtag)
                return
            self.ds.publish_model(t.version + 1, "blob",
                                  nbytes=self.model_bytes)
            for rtag in tags:
                self.qs.ack(rq, rtag)
            self.qs.ack(INITIAL_QUEUE, tag)
            self.timeline.append(TimelineEvent(vid, "Accumulate", now, end,
                                               t.version))
            self.tasks_by_worker[vid] = self.tasks_by_worker.get(vid, 0) + 1
            self.busy[vid] = self.busy.get(vid, 0.0) + (end - now)
            self.bytes_sent += self.grad_bytes * t.n_mb + 2 * self.model_bytes
            self.done_time = max(self.done_time, end)
            self._wake(vid)

        self._post(end, finish)
