"""Discrete-event simulator of the JSDoop deployment (cluster & classroom).

Reproduces the paper's scalability experiments (Figs. 4-8, Table 4) on one CPU
by simulating heterogeneous volunteers over the *same* protocol the real
Coordinator uses: each volunteer is a ``protocol.VolunteerSession`` speaking
typed messages to the QueueServer/DataServer through a ``transport``. The
Simulator owns only virtual time and costs:

- network: latency + bytes/bandwidth per transfer (model pull, gradient push).
  With ``transport="wire"`` every message round-trips through canonical bytes
  and the cost model prices the MEASURED envelope sizes (plus the logical
  payload bytes the synthetic placeholders stand in for) instead of
  hand-estimating whole exchanges from ``model_bytes``/``grad_bytes``;
- compute: task_flops / (volunteer speed * effective_throughput),
- cache effect: the paper attributes its superlinear relative speedup to "more
  of its data can be placed in fast memory" when the work is spread over more
  processors [Foster'95]. We model this mechanistically: a volunteer that must
  cycle the whole working set (model + optimizer + all mini-batches of a batch)
  through its cache sustains a penalized throughput; when k>=2 volunteers split
  the batch, the per-volunteer working set fits and throughput recovers.

All protocol semantics (lease/ack/requeue, version waits, reduce barrier,
churn) live in the shared ``VolunteerSession`` — identical to the real
Coordinator by construction, and asserted by tests. The consistency model is
the session's ``AggregationPolicy`` (``policy=``): sync-BSP map/reduce,
bounded-staleness async SGD (admit/discard at commit time, ticket nacked on
discard), or local-steps averaging — every policy schedule-deterministic, so
the chaos metamorphic contract holds per policy.

Two coordination modes share every cost and protocol rule:

- ``mode="event"`` (default): waits are push-based. A ``Blocked`` session
  subscribes (task queue, ``DataServer.watch_version``, or the reduce
  barrier's publish-only subscription) and the ``Wake``/``VersionReady``
  notification message resumes it. Total events scale with the amount of
  WORK, not with waiting time.
- ``mode="poll"``: the pre-subscription baseline — every wait reschedules
  itself every ``cost.poll_interval`` seconds, so events scale with
  O(volunteers x makespan / poll_interval). Kept for benchmarking
  (`benchmarks/volunteer_scaling.py`) and the cross-mode equivalence tests.

``faults=FaultSpec(...)`` wraps the transport in a ``FaultyTransport`` that
drops/duplicates/delays notification deliveries; a lost wake strands its
volunteer, and the run recovers through the visibility-timeout expiry path
(the run loop advances the clock to the next deadline when the event heap
would otherwise starve).
"""
from __future__ import annotations

import heapq
import itertools
import math
from bisect import bisect_right
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple, Union

from repro.core.aggregation import PolicyLike, make_policy
from repro.core.dataserver import DataServer
from repro.core.mapreduce import TrainingProblem
from repro.core.protocol import (Blocked, Busy, LocalWork, MapWork, NoTask,
                                 ReduceWork, ServerApplier, ServerEndpoint,
                                 TaskDone, VolunteerSession, wire_size)
from repro.core.queue import QueueServer, ShardedQueueServer, VirtualClock
from repro.core.transport import FaultSpec, FaultyTransport, make_transport


@dataclass
class VolunteerSpec:
    vid: str
    speed: float = 1.0              # relative device speed
    join_time: float = 0.0
    leave_time: float = math.inf


@dataclass(frozen=True)
class _SyntheticTrainParams:
    batch_size: int
    mini_batch_size: int
    mini_batches_to_accumulate: int
    sample_len: int
    batches_per_epoch: int


@dataclass(frozen=True)
class _SyntheticConfig:
    vocab: int


class SyntheticProblem:
    """Duck-typed TrainingProblem stand-in for timing-only simulations.

    The Simulator never calls map_compute/reduce_compute, so scale studies
    (1k-10k volunteers) don't need a jax model at all — just the task schedule
    and the byte/flop sizes the cost model consumes. Constructs in microseconds
    at any scale.
    """

    def __init__(self, *, n_versions: int = 20, n_mb: int = 32,
                 mini_batch_size: int = 8, sample_len: int = 50,
                 vocab: int = 96, model_bytes: float = 2.0e6,
                 grad_bytes: float = 1.0e6, map_flops: float = 1.0e9,
                 reduce_flops: float = 2.0e7, batches_per_epoch: int = 0):
        self.tp = _SyntheticTrainParams(
            batch_size=n_mb * mini_batch_size,
            mini_batch_size=mini_batch_size,
            mini_batches_to_accumulate=n_mb,
            sample_len=sample_len,
            batches_per_epoch=batches_per_epoch or n_versions)
        self.cfg = _SyntheticConfig(vocab=vocab)
        self.n_versions = n_versions
        self.model_bytes = model_bytes
        self.grad_bytes = grad_bytes
        self._map_flops = map_flops
        self._reduce_flops = reduce_flops

    def version_to_epoch_batch(self, version: int):
        return divmod(version, self.tp.batches_per_epoch)

    def flops_per_map(self) -> float:
        return self._map_flops

    def flops_per_reduce(self) -> float:
        return self._reduce_flops


@dataclass
class CostModel:
    flops_per_sec: float = 2.0e9    # sustained JS/WebGL throughput of one device
    latency: float = 0.030          # one-way message latency (s)
    bandwidth: float = 12.5e6       # bytes/s (100 Mbit LAN)
    poll_interval: float = 0.200    # dependency-wait poll (s) — poll mode only
    # cache-effect model (superlinearity, paper §V.A):
    cache_bytes: float = 4.0e6      # fast-memory budget per device
    thrash_penalty: float = 0.22    # throughput multiplier when set exceeds cache
    # server-apply service time: seconds per jitted apply DISPATCH on the
    # parameter server. The applier drains serially, and every commit that
    # arrives while a dispatch is pending rides the next one for free — the
    # batched fast path's economics (benchmarks/applier_bench.py measures the
    # real constant). 0.0 (default) keeps commits inline and every existing
    # run bit-identical.
    dispatch_cost: float = 0.0

    def throughput(self, speed: float, working_set: float) -> float:
        base = self.flops_per_sec * speed
        if working_set > self.cache_bytes:
            return base * self.thrash_penalty
        return base

    def xfer(self, nbytes: float) -> float:
        return self.latency + nbytes / self.bandwidth


@dataclass
class TimelineEvent:
    vid: str
    kind: str                        # "Compute" (map) | "Accumulate" (reduce)
    start: float
    end: float
    version: int


@dataclass
class SimResult:
    makespan: float
    timeline: List[TimelineEvent]
    tasks_by_worker: Dict[str, int]
    requeues: int
    final_version: int
    bytes_sent: float
    busy_time: Dict[str, float]
    events: int = 0                  # simulator events processed
    poll_events: int = 0             # events that were poll reschedules
    mode: str = "event"
    expire_scans: int = 0            # expiry sweeps actually performed
    wire_bytes: float = 0.0          # measured transport bytes (wire mode)
    stale_discards: int = 0          # barrierless results refused as stale
    policy: str = "sync"             # aggregation policy spec


class Simulator:
    """Event loop: volunteers wake, lease, (wait | compute), publish, ack."""

    def __init__(self, problem: TrainingProblem, specs: List[VolunteerSpec], *,
                 cost: CostModel = None, n_versions: Optional[int] = None,
                 visibility_timeout: float = 900.0, grad_bytes=None,
                 model_bytes=None, mode: str = "event", n_shards: int = 1,
                 max_events: int = 5_000_000,
                 transport: str = "inproc",
                 faults: Optional[FaultSpec] = None, fault_seed: int = 0,
                 watchdog: Optional[bool] = None,
                 policy: PolicyLike = None,
                 placement: Optional[Callable[[str], str]] = None,
                 server_apply: bool = False):
        from repro.core.initiator import enqueue_problem
        if mode not in ("event", "poll"):
            raise ValueError(f"unknown mode {mode!r}")
        self.problem = problem
        self.policy = make_policy(policy)
        self.cost = cost or CostModel()
        self.mode = mode
        self.max_events = max_events
        self.qs: Union[QueueServer, ShardedQueueServer] = (
            QueueServer(default_timeout=visibility_timeout) if n_shards <= 1
            else ShardedQueueServer(n_shards,
                                    default_timeout=visibility_timeout,
                                    placement=placement))
        self.ds = DataServer()
        self._now = 0.0
        # the server is the lease-time authority: the endpoint stamps every
        # lease with THIS engine's virtual clock (identical values to the
        # client-supplied now under a single-threaded event loop, so runs
        # stay bit-identical — but the authority now has one owner)
        self.endpoint = ServerEndpoint(self.qs, self.ds,
                                       clock=VirtualClock(lambda: self._now))
        self.port = make_transport(transport, self.endpoint)
        if faults is not None:
            self.port = FaultyTransport(
                self.port, faults, seed=fault_seed,
                defer=lambda dt, fn: self._post(self._now + dt, fn))
        self.port.set_deliver(self._on_notify)
        self._measuring = self.port.measures_bytes
        # Push notifications are lossy only under injected faults; real
        # volunteer clients back a push wait with a coarse re-check timer
        # (paper §IV.F solution 2: "check if a datum has been modified").
        # Armed ONLY when faults are injected so fault-free event-mode runs
        # stay bit-identical (and event counts unpolluted).
        self._watchdog_dt = (
            visibility_timeout if math.isfinite(visibility_timeout)
            else 10.0 * self.cost.poll_interval)
        self._watchdog = (faults is not None if watchdog is None
                          else watchdog) and mode == "event"
        self.n_versions = (n_versions if n_versions is not None
                           else problem.n_versions)
        self.n_updates = self.policy.n_updates(problem, self.n_versions)
        enqueue_problem(problem, self.qs, self.ds, n_versions=self.n_versions,
                        policy=self.policy, store_real_model=False)
        self.specs = {s.vid: s for s in specs}
        # sorted join/leave arrays for O(log N) active-fleet counts — the
        # per-task churn scan is the 100k-1M volunteer bottleneck. Rebuilt
        # lazily; ChaosSimulator invalidates on every spec mutation.
        self._active_cache: Optional[Tuple[List[float], List[float]]] = None
        self.sessions: Dict[str, VolunteerSession] = {}
        self.grad_bytes = grad_bytes if grad_bytes is not None else problem.grad_bytes
        self.model_bytes = model_bytes if model_bytes is not None else problem.model_bytes
        self.map_flops = problem.flops_per_map()
        self.reduce_flops = problem.flops_per_reduce()
        # per-batch working set: model+opt state+minibatch activations per task
        self.server_apply = bool(server_apply)
        if self.server_apply:
            if self.policy.barrier:
                raise ValueError("server_apply needs a barrierless policy "
                                 "(staleness:<s> or local:<k>)")
            # the synthetic applier mirrors commit_update("blob", model_bytes)
            self.endpoint.applier = ServerApplier(
                self.policy, lambda blob, result, v: "blob",
                model_nbytes=self.model_bytes)
        # serial applier pipeline state for CostModel.dispatch_cost: end of
        # the last scheduled dispatch, start of the last scheduled dispatch
        # (arrivals before a dispatch starts pool into it), and counters
        self._applier_free_at = 0.0
        self._applier_batch_start = float("-inf")
        self.apply_dispatches = 0
        self.batched_dispatch_credits = 0
        self._heap: List[Tuple[float, int, Callable]] = []
        self._seq = itertools.count()
        self.timeline: List[TimelineEvent] = []
        self.tasks_by_worker: Dict[str, int] = {}
        self.busy: Dict[str, float] = {}
        self.bytes_sent = 0.0
        self.done_time = 0.0
        self.events = 0
        self.poll_events = 0
        self.expire_scans = 0
        self.expired = 0                 # messages requeued by expiry sweeps
        self.stale_discards = 0          # barrierless admission refusals

    # ------------------------------------------------------------------ engine
    def _post(self, t: float, fn: Callable):
        heapq.heappush(self._heap, (t, next(self._seq), fn))

    def _apply_slot(self, t: float) -> float:
        """Completion time of a server-side apply arriving at ``t`` under the
        serial dispatch pipeline: an idle applier dispatches immediately; an
        arrival after the last scheduled dispatch STARTED opens the next one;
        an arrival before it started pools into it (the batched-drain credit).
        ``dispatch_cost == 0`` returns ``t`` untouched — commits stay inline
        and event order is unchanged."""
        c = self.cost.dispatch_cost
        if c <= 0.0:
            return t
        if t >= self._applier_free_at:
            self._applier_batch_start = t
            self._applier_free_at = t + c
            self.apply_dispatches += 1
        elif t >= self._applier_batch_start:
            self._applier_batch_start = self._applier_free_at
            self._applier_free_at += c
            self.apply_dispatches += 1
        else:
            self.batched_dispatch_credits += 1
        return self._applier_free_at

    def _post_poll(self, t: float, fn: Callable):
        self.poll_events += 1
        self._post(t, fn)

    def _session(self, vid: str) -> VolunteerSession:
        sess = self.sessions.get(vid)
        if sess is None:
            sess = self.sessions[vid] = VolunteerSession(vid, self.port,
                                                         policy=self.policy)
        return sess

    def _wire_bytes(self) -> float:
        inner = getattr(self.port, "inner", self.port)
        return float(getattr(inner, "bytes_sent", 0)
                     + getattr(inner, "bytes_received", 0))

    def run(self) -> SimResult:
        for s in self.specs.values():
            self._post(s.join_time, lambda vid=s.vid: self._wake(vid))
        while self.ds.latest_version < self.n_updates:
            if not self._heap:
                # a lost notification (FaultyTransport) can strand every
                # volunteer at once: advance the clock to the next visibility
                # deadline so expiry requeues — and their wakes — restart the
                # run. This is the lease-expiry recovery path; without faults
                # it is unreachable (subscriptions keep the heap fed).
                dl = self.qs.next_deadline()
                if dl is None or not math.isfinite(dl):
                    break
                self.events += 1
                self._now = dl
                self.expire_scans += 1
                self.expired += self.qs.expire_all(dl)
                continue
            self.events += 1
            if self.events > self.max_events:
                raise RuntimeError("simulator runaway")
            t, _, fn = heapq.heappop(self._heap)
            self._now = t
            # O(expired), not O(queues x events): sweep only when the earliest
            # live visibility deadline has actually passed — each sweep is then
            # guaranteed to requeue at least one message.
            dl = self.qs.next_deadline()
            if dl is not None and dl <= t:
                self.expire_scans += 1
                self.expired += self.qs.expire_all(t)
            fn()
        return SimResult(self.done_time, self.timeline,
                         dict(self.tasks_by_worker), self.qs.total_requeued,
                         self.ds.latest_version, self.bytes_sent,
                         dict(self.busy), self.events, self.poll_events,
                         self.mode, self.expire_scans, self._wire_bytes(),
                         self.stale_discards, self.policy.spec)

    def _alive(self, vid: str) -> bool:
        s = self.specs[vid]
        return s.join_time <= self._now < s.leave_time

    def _active_count(self, now: float) -> int:
        """|{s : join_time <= now < leave_time}| in O(log N).

        Exactly ``sum(1 for s in specs if s.join_time <= now < s.leave_time)``
        — the count of joins at-or-before ``now`` minus the count of leaves
        at-or-before ``now`` (leaves clamped up to their join so a degenerate
        empty interval contributes 0, matching the linear scan). The old
        per-task linear scan made million-volunteer sweeps O(N x tasks)."""
        cache = self._active_cache
        if cache is None:
            specs = self.specs.values()
            joins = sorted(s.join_time for s in specs)
            leaves = sorted(max(s.leave_time, s.join_time) for s in specs)
            cache = self._active_cache = (joins, leaves)
        joins, leaves = cache
        return bisect_right(joins, now) - bisect_right(leaves, now)

    # wait primitives: poll reschedules, event notifications -------------------
    def _on_notify(self, vid: str, msg) -> None:
        """Wake/VersionReady notification -> simulator event at the current
        virtual time (the wake happens inside whatever event triggered it)."""
        self._post(self._now, lambda: self._continue(vid))

    def _continue(self, vid: str) -> None:
        """Resume a volunteer where its session left off: idle volunteers try
        to lease, task holders retry their blocked dependency."""
        if self._session(vid).task is None:
            self._wake(vid)
        else:
            self._dispatch(vid)

    def _advance(self, sess: VolunteerSession):
        """session.advance plus the measured-bytes tap around it (wire mode)."""
        if self._measuring:
            self.port.take_bytes()
        out = sess.advance(self._now)
        return out, (self.port.take_bytes() if self._measuring else 0.0)

    def _wake(self, vid: str):
        """Volunteer becomes idle at _now: try to lease the next task."""
        if self.ds.latest_version >= self.n_updates:
            return
        sess = self._session(vid)
        if not self._alive(vid):
            # a departed volunteer: requeue whatever it held (wakes the next
            # waiter via the requeue notification); if it consumed a wake while
            # holding nothing, pass that wake on so no event is lost
            sess.abort(kick_if_empty=True)
            return
        now = self._now
        out = sess.lease(now)
        if isinstance(out, NoTask):
            if not sess.queue_drained():
                if self.mode == "poll":
                    self._post_poll(now + self.cost.poll_interval,
                                    lambda: self._wake(vid))
                else:
                    sess.subscribe_idle()
                    if self._watchdog:
                        # idle waits have no lease to expire, so a dropped
                        # Wake needs the same client-side re-check fallback
                        self._post(now + self._watchdog_dt,
                                   lambda: self._continue(vid))
            return
        self._post(now + self.cost.latency, lambda: self._dispatch(vid))

    def _dispatch(self, vid: str):
        sess = self._session(vid)
        if not self._alive(vid):
            sess.abort()
            return
        out, adv_bytes = self._advance(sess)
        if isinstance(out, Busy):            # spurious (duplicate/late) wake
            return
        if isinstance(out, TaskDone):        # obsolete duplicate, acked
            self._post(self._now, lambda: self._wake(vid))
            return
        if isinstance(out, Blocked):
            if self.mode == "poll":
                self._post_poll(self._now + self.cost.poll_interval,
                                lambda: self._dispatch(vid))
            else:
                sess.subscribe(out)
                if self._watchdog:
                    # lost-push fallback: re-drive this volunteer later; a
                    # session that progressed meanwhile absorbs it (Busy /
                    # spurious lease attempt)
                    self._post(self._now + self._watchdog_dt,
                               lambda: self._continue(vid))
            return
        if isinstance(out, MapWork):
            if self.policy.barrier:
                self._run_map(vid, sess, out, adv_bytes)
            else:
                self._run_update(vid, sess, out, adv_bytes)
        elif isinstance(out, LocalWork):
            self._run_update(vid, sess, out, adv_bytes)
        else:
            self._run_reduce(vid, sess, out, adv_bytes)

    # ------------------------------------------------------------------ map
    def _run_map(self, vid: str, sess: VolunteerSession, work: MapWork,
                 adv_bytes: float):
        now = self._now
        t = work.task
        spec = self.specs[vid]
        # working set: a lone volunteer cycles model+opt+the whole 128-batch
        # through cache; k volunteers each hold ~1/k of the batch's tasks.
        active = self._active_count(now)
        share = (self.model_bytes
                 + self.grad_bytes
                 + self._batch_bytes() / max(active, 1))
        thr = self.cost.throughput(spec.speed, share)
        if self._measuring:
            # envelope bytes are real; the payloads are synthetic placeholders
            # (None gradients, string model blobs), so add the logical sizes
            # they stand in for — measured overhead + modeled payload
            fetch_b = adv_bytes + self.model_bytes
            push_b = wire_size(sess.result_message(None, self.grad_bytes,
                                                   0.0)) + self.grad_bytes
        else:
            fetch_b, push_b = self.model_bytes, self.grad_bytes
        fetch = self.cost.xfer(fetch_b)
        compute = self.map_flops / thr
        push = self.cost.xfer(push_b)
        start = now + fetch
        end = start + compute + push

        def finish():
            if not self._alive(vid):
                sess.abort()                # task requeues via its lease
                return
            done = sess.finish_map(None, self.grad_bytes, 0.0)
            # busy counts the attempt either way — a stale map burned the
            # same simulated compute before the admission ack (and matches
            # the barrierless _run_update convention)
            self.busy[vid] = self.busy.get(vid, 0.0) + (end - now)
            if not done.stale:
                self.timeline.append(TimelineEvent(vid, "Compute", now, end,
                                                   t.version))
                self.tasks_by_worker[vid] = self.tasks_by_worker.get(vid, 0) + 1
                self.bytes_sent += self.grad_bytes + self.model_bytes
            self._wake(vid)

        self._post(end, finish)

    def _batch_bytes(self) -> float:
        tp = self.problem.tp
        sample = tp.sample_len * max(self.problem.cfg.vocab, 96) * 4
        return tp.batch_size * sample

    # ------------------------------------------------------------- barrierless
    def _run_update(self, vid: str, sess: VolunteerSession, work, adv_bytes):
        """BoundedStaleness gradient ticket or LocalSteps k-step ticket: pull
        the latest model, compute, push the contribution. The network cost is
        the parameter-server shape of async SGD — gradient (or model-sized
        delta) up, model down; the session's volunteer-applied commit stands
        in for the applier node, so its extra model round-trip is not priced.
        A too-stale attempt still pays the push (the rejection is
        server-side) but commits nothing; its ticket requeues for a fresh
        recompute."""
        now = self._now
        t = work.task
        spec = self.specs[vid]
        local = isinstance(work, LocalWork)
        flops = self.map_flops * (t.k if local else 1)
        active = self._active_count(now)
        share = (self.model_bytes + self.grad_bytes
                 + self._batch_bytes() / max(active, 1))
        thr = self.cost.throughput(spec.speed, share)
        fetch_b = (adv_bytes if self._measuring else 0.0) + self.model_bytes
        push_b = self.model_bytes if local else self.grad_bytes
        end = (now + self.cost.xfer(fetch_b) + flops / thr
               + self.cost.xfer(push_b))
        kind = "Local" if local else "Compute"

        def finish():
            if not self._alive(vid):
                sess.abort()                # ticket requeues via its lease
                return
            result = (sess.delta_result(None, self.model_bytes, 0.0) if local
                      else sess.grad_result(None, self.grad_bytes, 0.0))
            if self.server_apply:
                # dispatch_cost > 0 queues this commit behind the applier's
                # serial dispatch pipeline (pooling concurrent arrivals into
                # one batched dispatch); the 0.0 default keeps the commit
                # inline on this event and every existing run bit-identical
                commit_at = self._apply_slot(end)
                if commit_at > end:
                    self._post(commit_at, lambda: commit(result, commit_at))
                    return
            commit(result, end)

        def commit(result, end):
            if not self._alive(vid):
                sess.abort()                # ticket requeues via its lease
                return
            if self.server_apply:
                # one SubmitUpdate round-trip: the server runs admission,
                # applies, publishes, acks — commit semantics identical to
                # the client-applied pair, wire traffic is not (that delta
                # is what benchmarks/staleness.py measures)
                done = sess.submit_update(result)
                # timeline stamps the admission-time version (what the
                # client-applied path records via ApplyWork.version), so a
                # server-applied run's SimResult matches the client-applied
                # one field-for-field — only measured wire bytes differ
                stale, version = done.stale, done.version - 1
            else:
                out = sess.finish_update(result)
                stale = isinstance(out, TaskDone)
                version = -1 if stale else out.version
            self.busy[vid] = self.busy.get(vid, 0.0) + (end - now)
            if stale:                       # refused, discarded
                self.stale_discards += 1
                # the wasted attempt still moved model-down + payload-up
                self.bytes_sent += self.model_bytes + push_b
                self.timeline.append(TimelineEvent(
                    vid, kind + "-stale", now, end, work.base_version))
                # re-wake through the heap: the nack above already woke an
                # idle volunteer (posted first), so a FASTER waiter gets the
                # requeued ticket before this one can re-lease it
                self._post(self._now, lambda: self._wake(vid))
                return
            if not self.server_apply:
                sess.commit_update("blob", self.model_bytes)
            self.timeline.append(TimelineEvent(vid, kind, now, end, version))
            self.tasks_by_worker[vid] = self.tasks_by_worker.get(vid, 0) + 1
            self.bytes_sent += self.model_bytes + push_b
            self.done_time = max(self.done_time, end)
            self._wake(vid)

        self._post(end, finish)

    # ------------------------------------------------------------------ reduce
    def _run_reduce(self, vid: str, sess: VolunteerSession, work: ReduceWork,
                    adv_bytes: float):
        now = self._now
        t = work.task
        spec = self.specs[vid]
        if self._measuring:
            # envelope bytes measured; logical payloads padded in: the leased
            # gradients, the model pull the real Coordinator performs here,
            # and the published model blob
            pull = self.cost.xfer(adv_bytes + self.grad_bytes * t.n_mb
                                  + self.model_bytes)
            push = self.cost.xfer(
                wire_size(sess.model_message("blob", self.model_bytes))
                + self.model_bytes)
        else:
            pull = self.cost.xfer(self.grad_bytes * t.n_mb) + self.cost.xfer(
                self.model_bytes)
            push = self.cost.xfer(self.model_bytes)
        compute = self.reduce_flops / (self.cost.flops_per_sec * spec.speed)
        end = now + pull + compute + push

        def finish():
            if not self._alive(vid):
                sess.abort()                # drop leases + nack drained results
                return
            sess.finish_reduce("blob", self.model_bytes)
            self.timeline.append(TimelineEvent(vid, "Accumulate", now, end,
                                               t.version))
            self.tasks_by_worker[vid] = self.tasks_by_worker.get(vid, 0) + 1
            self.busy[vid] = self.busy.get(vid, 0.0) + (end - now)
            self.bytes_sent += self.grad_bytes * t.n_mb + 2 * self.model_bytes
            self.done_time = max(self.done_time, end)
            self._wake(vid)

        self._post(end, finish)
