"""Elastic control plane: gateway ring ownership + the durable op log.

Two pieces the multi-gateway deployment (``repro.core.gateway --gid``) and
the chaos/model-checking tiers share:

- ``GatewayRing`` — consistent-hash ownership of queue names over K gateway
  processes, reusing the exact vnode hashing of ``ShardedQueueServer`` (PR 2)
  one level up: shards partition queues *inside* one process, the gateway
  ring partitions them *across* processes. A dead gateway's whole slice is
  adopted by ONE deterministic peer (``default_adopter`` = the smallest live
  gid), so failover never rehashes the survivors' slices.

- ``OpLog`` — incremental durability: ``snapshot()`` becomes a numbered BASE
  (full state, written atomically) plus append-only delta SEGMENTS of framed
  op records (``repro.checkpoint.serialize.pack_record``: length + crc32,
  fsync per append). ``load()`` picks the newest complete base and replays
  every intact record after it; a torn tail — the writer was kill -9'd
  mid-append — ends replay cleanly instead of failing it. Writing a new base
  starts a new epoch and truncates everything older, which bounds disk to
  one base + the ops since.

The log layer is byte-agnostic: callers (the gateway's endpoint op sink, the
chaos journal) decide what an op record contains. ``durable_fingerprint``
is the shared equality observable for "replay reconstructed the same server":
per-queue snapshots with the session-coupled wake state (banked signals)
masked out — subscriptions are connection-bound and never logged, so a
replayed queue legitimately over-banks signals a live subscriber consumed;
waiters are already excluded from snapshots for the same reason.
"""
from __future__ import annotations

import bisect
import os
import re
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.checkpoint.serialize import (append_record, iter_records,
                                        read_bytes)
from repro.checkpoint.serialize import atomic_write
from repro.core.queue import _stable_hash

#: ring key routing every DataServer-backed op (model fetch/publish/watch,
#: update submission) to one gateway — the model owner
MODEL_KEY = "__model__"


class GatewayRing:
    """Consistent-hash ownership of routing keys over gateway ids.

    Each gid owns the vnode set ``gw-{gid}#0..vnodes-1`` — stable under
    membership events, exactly like ``ShardedQueueServer``'s ring. Death and
    adoption do NOT remove the dead gid's vnodes (that would scatter its
    slice over every survivor); instead ``adopt(dead, adopter)`` records a
    redirect, so the dead gateway's entire slice moves to exactly one peer —
    the unit of failover the op log can actually replay.
    """

    def __init__(self, gids: Iterable[int], *, vnodes: int = 32):
        self.gids: Tuple[int, ...] = tuple(sorted(set(gids)))
        if not self.gids:
            raise ValueError("ring needs at least one gateway")
        self.vnodes = vnodes
        self._dead: set = set()
        self._adopted: Dict[int, int] = {}       # dead gid -> adopter gid
        ring: List[Tuple[int, int]] = []
        for gid in self.gids:
            for r in range(vnodes):
                bisect.insort(ring, (_stable_hash(f"gw-{gid}#{r}"), gid))
        self._keys = [h for h, _ in ring]
        self._vals = [g for _, g in ring]

    # -- membership ---------------------------------------------------------
    def live(self) -> Tuple[int, ...]:
        return tuple(g for g in self.gids if g not in self._dead)

    def kill(self, gid: int) -> None:
        if gid not in self.gids:
            raise ValueError(f"unknown gateway {gid}")
        self._dead.add(gid)
        if not self.live():
            raise ValueError("cannot kill the last live gateway")

    def default_adopter(self, dead: int) -> int:
        """The deterministic failover choice every survivor agrees on
        without coordination: the smallest live gid."""
        live = [g for g in self.live() if g != dead]
        if not live:
            raise ValueError("no live gateway left to adopt")
        return min(live)

    def adopt(self, dead: int, adopter: Optional[int] = None) -> int:
        """Record that ``adopter`` now owns the dead gateway's slice.
        Returns the adopter gid. Idempotent for the same pair."""
        if dead not in self._dead:
            raise ValueError(f"gateway {dead} is not dead")
        adopter = self.default_adopter(dead) if adopter is None else adopter
        if adopter in self._dead:
            raise ValueError(f"adopter {adopter} is dead")
        prev = self._adopted.get(dead)
        if prev is not None and prev != adopter:
            raise ValueError(
                f"slice of {dead} already adopted by {prev}, not {adopter}")
        self._adopted[dead] = adopter
        return adopter

    def adoptions(self) -> Dict[int, int]:
        """Recorded ``dead gid -> adopter gid`` redirects (a copy)."""
        return dict(self._adopted)

    # -- routing ------------------------------------------------------------
    def base_owner(self, key: str) -> int:
        """Ring successor of ``key`` ignoring liveness — the original owner."""
        h = _stable_hash(key)
        i = bisect.bisect_right(self._keys, h) % len(self._keys)
        return self._vals[i]

    def serving(self, gid: int) -> int:
        """The live gateway currently serving ``gid``'s slice: itself while
        alive, else its (transitive) adopter. Raises ``LookupError`` in the
        failover window — dead and not yet adopted — during which requests
        must be held or retried."""
        seen = set()
        while gid in self._adopted:
            if gid in seen:
                raise RuntimeError(f"adoption cycle at gateway {gid}")
            seen.add(gid)
            gid = self._adopted[gid]
        if gid in self._dead:
            raise LookupError(
                f"slice owner {gid} is dead and not yet adopted")
        return gid

    def owner_of(self, key: str) -> int:
        """Current owner: the base owner, redirected through any adoptions."""
        return self.serving(self.base_owner(key))

    def owners(self, keys: Iterable[str]) -> Dict[str, int]:
        return {k: self.owner_of(k) for k in keys}


# ---------------------------------------------------------------------------
# op log: numbered base + append-only delta segments
# ---------------------------------------------------------------------------

_BASE_RE = re.compile(r"\.base\.(\d+)$")
_SEG_RE = re.compile(r"\.log\.(\d+)\.(\d+)$")


class OpLog:
    """Base + numbered delta segments under a filename prefix.

    Files:
      ``<prefix>.base.<epoch>``       — full state, atomic write
      ``<prefix>.log.<epoch>.<seg>``  — framed op records, appended + fsynced

    ``write_base`` starts epoch N+1 and truncates every older epoch; appends
    land in the current epoch's segment, rolling to a new segment every
    ``segment_ops`` records (bounded per-file size, and the property tests'
    crash-at-byte-k can only ever tear the LAST record of the last segment).
    A brand-new log starts at epoch 0 with no base: ``load`` then replays
    from empty state, so an op-log-only boot is well-defined too.
    """

    def __init__(self, prefix: str, *, segment_ops: int = 256,
                 fsync: bool = True):
        self.prefix = str(prefix)
        self.segment_ops = max(1, int(segment_ops))
        self.fsync = fsync
        self.epoch = 0
        self.seg = 0
        self._ops_in_seg = 0
        self.appended = 0                       # ops appended by THIS object
        d = os.path.dirname(self.prefix) or "."
        if os.path.isdir(d):
            epochs = self._epochs()
            if epochs:
                self.epoch = max(epochs)
                segs = self._segments(self.epoch)
                if segs:
                    self.seg = max(segs)
                    self._ops_in_seg = sum(
                        1 for _ in iter_records(
                            read_bytes(self._seg_path(self.epoch, self.seg))))

    # -- paths --------------------------------------------------------------
    def _base_path(self, epoch: int) -> str:
        return f"{self.prefix}.base.{epoch}"

    def _seg_path(self, epoch: int, seg: int) -> str:
        return f"{self.prefix}.log.{epoch}.{seg}"

    def _family(self) -> List[str]:
        d = os.path.dirname(self.prefix) or "."
        stem = os.path.basename(self.prefix)
        try:
            names = os.listdir(d)
        except OSError:
            return []
        return [os.path.join(d, n) for n in names if n.startswith(stem)]

    def _epochs(self) -> List[int]:
        out = []
        for p in self._family():
            m = _BASE_RE.search(p)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def _segments(self, epoch: int) -> List[int]:
        out = []
        for p in self._family():
            m = _SEG_RE.search(p)
            if m and int(m.group(1)) == epoch:
                out.append(int(m.group(2)))
        return sorted(out)

    @staticmethod
    def exists(prefix: str) -> bool:
        """Any op-log family files under this prefix? Only actual
        ``.base.<epoch>`` / ``.log.<epoch>.<seg>`` files count — a plain
        file AT the prefix path (e.g. a legacy full snapshot) is not a
        family member, so restore dispatch cannot mistake one for a log."""
        probe = OpLog.__new__(OpLog)
        probe.prefix = str(prefix)
        return any(_BASE_RE.search(p) or _SEG_RE.search(p)
                   for p in probe._family())

    # -- writing ------------------------------------------------------------
    def write_base(self, data: bytes) -> str:
        """Start a new epoch: write the full-state base atomically, reset the
        segment counter, and truncate every older epoch's files (they are
        subsumed: the base was encoded AFTER their last op)."""
        self.epoch += 1
        path = self._base_path(self.epoch)
        atomic_write(path, data)
        self.seg = 0
        self._ops_in_seg = 0
        self.truncate()
        return path

    def append(self, data: bytes) -> str:
        """Append one op record to the current epoch, rolling segments every
        ``segment_ops`` records. Durable (fsync) before returning unless the
        log was opened with ``fsync=False``."""
        if self._ops_in_seg >= self.segment_ops:
            self.seg += 1
            self._ops_in_seg = 0
        path = self._seg_path(self.epoch, self.seg)
        append_record(path, data, fsync=self.fsync)
        self._ops_in_seg += 1
        self.appended += 1
        return path

    def truncate(self) -> List[str]:
        """Delete every file from epochs older than the current one.
        Returns the removed paths (newest-base durability is unaffected)."""
        removed = []
        for p in self._family():
            m = _BASE_RE.search(p) or _SEG_RE.search(p)
            if m and int(m.group(1)) < self.epoch:
                try:
                    os.remove(p)
                    removed.append(p)
                except OSError:
                    pass                       # already gone: racing truncate
        return removed

    # -- reading ------------------------------------------------------------
    def load(self) -> Tuple[Optional[bytes], List[bytes]]:
        """(base bytes or None, op records after it, in append order).

        Picks the newest epoch that has a complete base (atomic writes mean a
        base either exists whole or not at all), then replays its segments in
        order, stopping at the first torn/corrupt record — by construction
        only the final append can be torn, so everything acknowledged before
        the crash is returned.
        """
        epochs = self._epochs()
        epoch = max(epochs) if epochs else self.epoch
        base = None
        if epochs:
            base = read_bytes(self._base_path(epoch))
        ops: List[bytes] = []
        for seg in self._segments(epoch):
            data = read_bytes(self._seg_path(epoch, seg))
            recs = list(iter_records(data))
            ops.extend(recs)
            # a record boundary that doesn't consume the file is a torn
            # tail — nothing after it was acknowledged as durable
            consumed = sum(len(r) + 8 for r in recs)
            if consumed < len(data):
                break
        return base, ops

    def op_count(self) -> int:
        """Total intact op records in the current epoch (reads the files —
        an observable for tests, not a hot path)."""
        return len(self.load()[1])


# ---------------------------------------------------------------------------
# shared replay-equality observable
# ---------------------------------------------------------------------------

def durable_queue_state(q) -> Dict[str, Any]:
    """One queue's snapshot with session-coupled wake state masked (banked
    signals; waiters are excluded from snapshots already)."""
    s = q.snapshot()
    s.pop("signal", None)
    s.pop("pub_signal", None)
    return s


def durable_fingerprint(qs) -> Dict[str, Any]:
    """Name -> durable queue state over a QueueServer/ShardedQueueServer —
    what an op-log replay must reconstruct exactly."""
    return {name: durable_queue_state(q)
            for name, q in sorted(qs.queues.items())}
