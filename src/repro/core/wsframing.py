"""RFC 6455 WebSocket framing as a sans-IO layer.

The gateway speaks two dialects on one port: the native length-prefixed
byte framing (docs/protocol.md "Byte framing") and WebSocket, the only
framing a browser can produce.  This module owns everything RFC 6455
says about bytes and nothing about sockets: handshake parsing/response,
frame encode/decode, masking, fragmentation, ping/pong, and the close
handshake are all pure functions over buffers, so every rule is
unit-testable byte-for-byte without a network.

Layering (mirrors protocol.py's sans-IO split):

- ``ServerHandshake`` / client handshake helpers: HTTP upgrade in/out.
- ``Framer``: one side of an established connection.  ``feed(data)``
  returns decoded events (``Message``/``Ping``/``Pong``/``Closed``);
  ``send_message``/``ping``/``pong``/``close`` return wire bytes.
- The gateway maps **one protocol message to one binary WebSocket
  message** — the payload is ``encode_message(msg)`` WITHOUT the u32
  length prefix, because WS frames carry their own lengths.

Hard rules enforced here (violations raise ``WsProtocolError`` with an
RFC close code, and the I/O layer closes the connection):

- client frames MUST be masked; server frames MUST NOT be (RFC 5.1);
- RSV bits zero (no extensions negotiated);
- control frames are unfragmented and carry <= 125 payload bytes;
- a frame or reassembled message larger than ``max_frame`` is refused
  with close code 1009 *before* its payload is buffered — the cap is
  shared with the native dialect's ``MAX_FRAME`` so a hostile length
  field can't drive allocation in either framing.
"""
from __future__ import annotations

import base64
import hashlib
import os
import struct
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

# Shared frame cap for BOTH wire dialects (native u32-prefixed and WS).
# Large enough for any model blob the benchmarks ship (tens of MB),
# small enough that a corrupt/hostile length field cannot drive a
# multi-GB allocation loop.
MAX_FRAME = 32 * 1024 * 1024

# RFC 6455 section 1.3 — fixed GUID appended to the client key.
GUID = "258EAFA5-E914-47DA-95CA-C5AB0DC85B11"

WS_VERSION = "13"

# Opcodes (RFC 5.2).
OP_CONT = 0x0
OP_TEXT = 0x1
OP_BINARY = 0x2
OP_CLOSE = 0x8
OP_PING = 0x9
OP_PONG = 0xA

_DATA_OPCODES = (OP_TEXT, OP_BINARY)
_CONTROL_OPCODES = (OP_CLOSE, OP_PING, OP_PONG)

# Close codes (RFC 7.4.1).
CLOSE_NORMAL = 1000
CLOSE_GOING_AWAY = 1001
CLOSE_PROTOCOL_ERROR = 1002
CLOSE_TOO_BIG = 1009

_MAX_HANDSHAKE = 8 * 1024  # HTTP upgrade header cap

_U16 = struct.Struct(">H")
_U64 = struct.Struct(">Q")


class WsProtocolError(Exception):
    """Peer violated RFC 6455; carries the close code to send back."""

    def __init__(self, reason: str, code: int = CLOSE_PROTOCOL_ERROR):
        super().__init__(reason)
        self.code = code
        self.reason = reason


# ---------------------------------------------------------------------------
# events produced by Framer.feed
# ---------------------------------------------------------------------------

# sentinel returned by _parse_one when a non-final fragment was consumed
_CONSUMED = object()


@dataclass(frozen=True)
class Message:
    """A complete (possibly reassembled) data message."""
    data: bytes


@dataclass(frozen=True)
class Ping:
    data: bytes


@dataclass(frozen=True)
class Pong:
    data: bytes


@dataclass(frozen=True)
class Closed:
    """Peer sent a Close frame. ``code`` is None when it carried no code."""
    code: Optional[int]
    reason: bytes = b""


# ---------------------------------------------------------------------------
# handshake
# ---------------------------------------------------------------------------

def accept_key(key: str) -> str:
    """``Sec-WebSocket-Accept`` for a client ``Sec-WebSocket-Key``."""
    digest = hashlib.sha1((key + GUID).encode("ascii")).digest()
    return base64.b64encode(digest).decode("ascii")


def is_ws_preamble(data: bytes) -> bool:
    """Dialect sniff: does this connection open like an HTTP upgrade?

    One byte disambiguates.  A WS connection starts ``GET `` (0x47);
    the native dialect starts with a u32 BE length that is < MAX_FRAME
    (32 MiB = 0x02000000), so its first byte is always <= 0x01 and can
    never be ``G``.
    """
    return data[:1] == b"G"


def _parse_headers(block: bytes) -> Tuple[str, dict]:
    try:
        text = block.decode("latin-1")
    except UnicodeDecodeError as e:  # latin-1 never fails, but be explicit
        raise WsProtocolError(f"undecodable handshake: {e}") from e
    lines = text.split("\r\n")
    request_line = lines[0]
    headers: dict = {}
    for line in lines[1:]:
        if not line:
            continue
        name, sep, value = line.partition(":")
        if not sep:
            raise WsProtocolError(f"malformed header line: {line!r}")
        headers[name.strip().lower()] = value.strip()
    return request_line, headers


def handshake_response(key: str) -> bytes:
    return (
        "HTTP/1.1 101 Switching Protocols\r\n"
        "Upgrade: websocket\r\n"
        "Connection: Upgrade\r\n"
        f"Sec-WebSocket-Accept: {accept_key(key)}\r\n"
        "\r\n"
    ).encode("ascii")


def bad_handshake_response(reason: str = "bad websocket handshake") -> bytes:
    body = reason.encode("ascii", "replace")
    return (
        "HTTP/1.1 400 Bad Request\r\n"
        "Connection: close\r\n"
        f"Content-Length: {len(body)}\r\n"
        "\r\n"
    ).encode("ascii") + body


class ServerHandshake:
    """Incremental parser for the client's HTTP upgrade request.

    ``feed(data)`` returns the 101 response bytes once the full header
    block has arrived (None while incomplete); raises WsProtocolError on
    a request that is not a well-formed WS upgrade.  Bytes received past
    the header block are preserved in ``leftover`` — they are the first
    frame bytes and must be fed to the Framer.
    """

    def __init__(self) -> None:
        self._buf = b""
        self.leftover = b""
        self.path: Optional[str] = None

    def feed(self, data: bytes) -> Optional[bytes]:
        self._buf += data
        end = self._buf.find(b"\r\n\r\n")
        if end < 0:
            if len(self._buf) > _MAX_HANDSHAKE:
                raise WsProtocolError("handshake header block too large",
                                      CLOSE_TOO_BIG)
            return None
        block, self.leftover = self._buf[:end], self._buf[end + 4:]
        request_line, headers = _parse_headers(block)
        parts = request_line.split(" ")
        if len(parts) != 3 or parts[0] != "GET":
            raise WsProtocolError(f"not a GET request: {request_line!r}")
        self.path = parts[1]
        if "websocket" not in headers.get("upgrade", "").lower():
            raise WsProtocolError("missing Upgrade: websocket header")
        connection = headers.get("connection", "").lower()
        if "upgrade" not in (t.strip() for t in connection.split(",")):
            raise WsProtocolError("missing Connection: Upgrade header")
        key = headers.get("sec-websocket-key")
        if not key:
            raise WsProtocolError("missing Sec-WebSocket-Key header")
        version = headers.get("sec-websocket-version")
        if version != WS_VERSION:
            raise WsProtocolError(
                f"unsupported Sec-WebSocket-Version: {version!r}")
        return handshake_response(key)


def client_handshake_request(host: str, path: str = "/",
                             key: Optional[str] = None) -> Tuple[bytes, str]:
    """Upgrade request bytes + the key to verify the response against."""
    if key is None:
        key = base64.b64encode(os.urandom(16)).decode("ascii")
    request = (
        f"GET {path} HTTP/1.1\r\n"
        f"Host: {host}\r\n"
        "Upgrade: websocket\r\n"
        "Connection: Upgrade\r\n"
        f"Sec-WebSocket-Key: {key}\r\n"
        f"Sec-WebSocket-Version: {WS_VERSION}\r\n"
        "\r\n"
    ).encode("ascii")
    return request, key


class ClientHandshake:
    """Incremental parser for the server's 101 response.

    ``feed(data)`` returns True once the response is complete and valid;
    raises WsProtocolError otherwise.  ``leftover`` holds any frame
    bytes that arrived glued to the response.
    """

    def __init__(self, key: str) -> None:
        self._key = key
        self._buf = b""
        self.done = False
        self.leftover = b""

    def feed(self, data: bytes) -> bool:
        if self.done:
            self.leftover += data
            return True
        self._buf += data
        end = self._buf.find(b"\r\n\r\n")
        if end < 0:
            if len(self._buf) > _MAX_HANDSHAKE:
                raise WsProtocolError("handshake response too large",
                                      CLOSE_TOO_BIG)
            return False
        block, self.leftover = self._buf[:end], self._buf[end + 4:]
        status_line, headers = _parse_headers(block)
        parts = status_line.split(" ", 2)
        if len(parts) < 2 or parts[1] != "101":
            raise WsProtocolError(f"expected 101, got: {status_line!r}")
        want = accept_key(self._key)
        got = headers.get("sec-websocket-accept")
        if got != want:
            raise WsProtocolError(
                f"Sec-WebSocket-Accept mismatch: {got!r} != {want!r}")
        self.done = True
        return True


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------

def _mask_bytes(payload: bytes, mask: bytes) -> bytes:
    # XOR with a repeating 4-byte mask (RFC 5.3); int-XOR over the whole
    # buffer is far faster than a per-byte loop.
    if not payload:
        return payload
    reps = -(-len(payload) // 4)
    key = (mask * reps)[:len(payload)]
    return (int.from_bytes(payload, "big")
            ^ int.from_bytes(key, "big")).to_bytes(len(payload), "big")


class Framer:
    """Sans-IO frame codec for one side of an established connection.

    Servers send unmasked and require masked input; clients the inverse.
    Use the ``server_framer()`` / ``client_framer()`` factories.
    """

    def __init__(self, *, masking: bool, require_masked: bool,
                 max_frame: int = MAX_FRAME,
                 mask_source: Callable[[int], bytes] = os.urandom) -> None:
        self.masking = masking
        self.require_masked = require_masked
        self.max_frame = max_frame
        self.mask_source = mask_source
        self._buf = b""
        self._fragments: List[bytes] = []
        self._fragment_total = 0
        self.closed = False

    # -- receive side -------------------------------------------------------

    @property
    def mid_frame(self) -> bool:
        """True when bytes of an unfinished frame or message are pending.

        The I/O layer uses this for stall detection: a peer that goes
        silent mid-frame is dead (or hostile), while silence between
        frames is just an idle connection.
        """
        return bool(self._buf) or bool(self._fragments)

    def feed(self, data: bytes) -> List[object]:
        """Consume received bytes; return completed events in order."""
        if self.closed:
            return []
        self._buf += data
        events: List[object] = []
        while True:
            parsed = self._parse_one()
            if parsed is None:
                return events
            if parsed is _CONSUMED:  # a non-final fragment: no event yet
                continue
            events.append(parsed)
            if isinstance(parsed, Closed):
                self.closed = True
                return events

    def _parse_one(self):
        buf = self._buf
        if len(buf) < 2:
            return None
        b0, b1 = buf[0], buf[1]
        fin = bool(b0 & 0x80)
        if b0 & 0x70:
            raise WsProtocolError("nonzero RSV bits without an extension")
        opcode = b0 & 0x0F
        masked = bool(b1 & 0x80)
        length = b1 & 0x7F
        offset = 2
        if length == 126:
            if len(buf) < offset + 2:
                return None
            length = _U16.unpack_from(buf, offset)[0]
            offset += 2
        elif length == 127:
            if len(buf) < offset + 8:
                return None
            length = _U64.unpack_from(buf, offset)[0]
            offset += 8
        # refuse hostile lengths BEFORE buffering any payload
        if length > self.max_frame:
            raise WsProtocolError(
                f"{length}-byte frame exceeds max_frame={self.max_frame}",
                CLOSE_TOO_BIG)
        if masked != self.require_masked:
            side = "masked" if self.require_masked else "unmasked"
            raise WsProtocolError(f"peer frames must be {side}")
        mask = b""
        if masked:
            if len(buf) < offset + 4:
                return None
            mask = buf[offset:offset + 4]
            offset += 4
        if len(buf) < offset + length:
            return None
        payload = buf[offset:offset + length]
        self._buf = buf[offset + length:]
        if masked:
            payload = _mask_bytes(payload, mask)
        if opcode in _CONTROL_OPCODES:
            if not fin:
                raise WsProtocolError("fragmented control frame")
            if length > 125:
                raise WsProtocolError("control frame payload > 125 bytes")
            if opcode == OP_PING:
                return Ping(payload)
            if opcode == OP_PONG:
                return Pong(payload)
            code: Optional[int] = None
            reason = b""
            if len(payload) >= 2:
                code = _U16.unpack(payload[:2])[0]
                reason = payload[2:]
            elif len(payload) == 1:
                raise WsProtocolError("close frame with 1-byte payload")
            return Closed(code, reason)
        if opcode in _DATA_OPCODES:
            if self._fragments:
                raise WsProtocolError(
                    "new data frame while a fragmented message is pending")
            if fin:
                return Message(payload)
            self._fragments.append(payload)
            self._fragment_total = len(payload)
            return _CONSUMED
        if opcode == OP_CONT:
            if not self._fragments:
                raise WsProtocolError("continuation frame with no message")
            self._fragment_total += len(payload)
            if self._fragment_total > self.max_frame:
                raise WsProtocolError(
                    f"reassembled message exceeds max_frame={self.max_frame}",
                    CLOSE_TOO_BIG)
            self._fragments.append(payload)
            if not fin:
                return _CONSUMED
            data = b"".join(self._fragments)
            self._fragments = []
            self._fragment_total = 0
            return Message(data)
        raise WsProtocolError(f"unknown opcode {opcode:#x}")

    # -- send side ----------------------------------------------------------

    def _frame(self, opcode: int, payload: bytes, fin: bool = True) -> bytes:
        head = bytearray()
        head.append((0x80 if fin else 0x00) | opcode)
        mask_bit = 0x80 if self.masking else 0x00
        n = len(payload)
        if n <= 125:
            head.append(mask_bit | n)
        elif n <= 0xFFFF:
            head.append(mask_bit | 126)
            head += _U16.pack(n)
        else:
            head.append(mask_bit | 127)
            head += _U64.pack(n)
        if self.masking:
            mask = self.mask_source(4)
            head += mask
            payload = _mask_bytes(payload, mask)
        return bytes(head) + payload

    def send_message(self, payload: bytes,
                     fragment_size: Optional[int] = None) -> bytes:
        """Encode one binary message; optionally split into fragments."""
        if len(payload) > self.max_frame:
            raise WsProtocolError(
                f"refusing to send {len(payload)}-byte message "
                f"(max_frame={self.max_frame})", CLOSE_TOO_BIG)
        if fragment_size is None or fragment_size >= len(payload):
            return self._frame(OP_BINARY, payload)
        out = bytearray()
        chunks = [payload[i:i + fragment_size]
                  for i in range(0, len(payload), fragment_size)] or [b""]
        for i, chunk in enumerate(chunks):
            opcode = OP_BINARY if i == 0 else OP_CONT
            out += self._frame(opcode, chunk, fin=(i == len(chunks) - 1))
        return bytes(out)

    def ping(self, payload: bytes = b"") -> bytes:
        return self._frame(OP_PING, payload)

    def pong(self, payload: bytes = b"") -> bytes:
        return self._frame(OP_PONG, payload)

    def close(self, code: int = CLOSE_NORMAL, reason: bytes = b"") -> bytes:
        payload = _U16.pack(code) + reason if code is not None else b""
        return self._frame(OP_CLOSE, payload[:125])


def server_framer(max_frame: int = MAX_FRAME) -> Framer:
    return Framer(masking=False, require_masked=True, max_frame=max_frame)


def client_framer(max_frame: int = MAX_FRAME,
                  mask_source: Callable[[int], bytes] = os.urandom) -> Framer:
    return Framer(masking=True, require_masked=False, max_frame=max_frame,
                  mask_source=mask_source)
