"""Gateway — the volunteer protocol over a real loopback socket.

``python -m repro.core.gateway`` hosts a QueueServer + DataServer behind
``protocol.ServerEndpoint`` on a TCP socket (length-prefixed frames of
canonically encoded messages), so a genuinely **out-of-process** volunteer can
join a training run — the end-to-end proof that the sans-IO redesign works:
the same ``VolunteerSession`` that drives the Coordinator's JAX compute and
the Simulator's virtual time here drives a blocking socket client, with zero
protocol code of its own.

Pieces:

- ``GatewayServer`` — accept loop + per-connection reader threads; one global
  lock serializes endpoint dispatch (the in-process servers are
  single-threaded by design). A connection binds to a consumer id with
  ``Hello``; ``Wake``/``VersionReady`` notification frames are pushed down
  that consumer's connection.
- ``SocketTransport`` — the client half: ``call`` writes a request frame and
  reads until the reply frame arrives, stashing any notification frames that
  interleave; ``wait_notification`` blocks on the socket for the next push.
- ``run_volunteer`` — the engine-free driver: lease -> advance -> synthetic
  compute -> finish, blocking on notifications while ``Blocked``. Works over
  ANY transport (the ``--smoke`` mode runs it over ``InProcessTransport`` as
  the reference, then over a socket against a spawned server process, and
  asserts both reach the same final version with the same task count).

This is a liveness/serializability proof, not a production server: visibility
timeouts need a clock owner (the engines' virtual clocks, or a sweeper thread
in a real deployment), so the gateway runs with infinite leases.

Usage:
  python -m repro.core.gateway --serve --port 0 --port-file /tmp/gw.port
  python -m repro.core.gateway --volunteer --port 12345 --expect-final 4
  python -m repro.core.gateway --smoke
"""
from __future__ import annotations

import argparse
import os
import socket
import struct
import subprocess
import sys
import tempfile
import threading
import time
from collections import deque
from typing import Deque, Dict, Optional, Tuple

from repro.core.dataserver import DataServer
from repro.core.initiator import enqueue_problem
from repro.core.protocol import (Blocked, Hello, MapWork, NoTask,
                                 NOTIFICATION_TYPES, ReduceWork,
                                 ServerEndpoint, TaskDone, VolunteerSession,
                                 decode_message, encode_message)
from repro.core.queue import QueueServer
from repro.core.simulator import SyntheticProblem
from repro.core.transport import InProcessTransport, Transport

_LEN = struct.Struct(">I")


def _send_frame(sock: socket.socket, msg) -> int:
    data = encode_message(msg)
    sock.sendall(_LEN.pack(len(data)) + data)
    return _LEN.size + len(data)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return buf


def _recv_frame(sock: socket.socket):
    head = _recv_exact(sock, _LEN.size)
    if head is None:
        return None
    body = _recv_exact(sock, _LEN.unpack(head)[0])
    return None if body is None else decode_message(body)


# ---------------------------------------------------------------------------
# server
# ---------------------------------------------------------------------------

class GatewayServer:
    def __init__(self, problem, *, host: str = "127.0.0.1", port: int = 0,
                 n_versions: Optional[int] = None):
        self.qs = QueueServer()                  # infinite visibility timeout
        self.ds = DataServer()
        self.n_versions = (n_versions if n_versions is not None
                           else problem.n_versions)
        enqueue_problem(problem, self.qs, self.ds,
                        n_versions=self.n_versions, store_real_model=False)
        self.endpoint = ServerEndpoint(self.qs, self.ds, self._notify)
        self._lock = threading.Lock()            # serializes ALL dispatch + writes
        self._conns: Dict[str, socket.socket] = {}
        self.done = threading.Event()
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(16)
        self.port = self._sock.getsockname()[1]

    def _notify(self, consumer: str, msg) -> None:
        # called inside endpoint.handle, under self._lock. The send is
        # bounded: a client that stops draining its socket would otherwise
        # block here with the global lock held and stall the whole server —
        # treat a wedged buffer like a disconnect and drop the registration.
        conn = self._conns.get(consumer)
        if conn is not None:
            try:
                conn.settimeout(10.0)
                _send_frame(conn, msg)
            except OSError:
                self._conns.pop(consumer, None)
            finally:
                try:
                    conn.settimeout(None)
                except OSError:
                    pass

    def _serve_conn(self, conn: socket.socket) -> None:
        consumer = None
        try:
            while True:
                msg = _recv_frame(conn)
                if msg is None:
                    break
                with self._lock:
                    if isinstance(msg, Hello):
                        consumer = msg.consumer
                        self._conns[consumer] = conn
                    reply = self.endpoint.handle(msg)
                    _send_frame(conn, reply)
                    if self.ds.latest_version >= self.n_versions:
                        self.done.set()
        finally:
            with self._lock:
                if consumer is not None and self._conns.get(consumer) is conn:
                    del self._conns[consumer]
            conn.close()

    def serve_forever(self) -> None:
        while True:
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()

    def start(self) -> threading.Thread:
        t = threading.Thread(target=self.serve_forever, daemon=True)
        t.start()
        return t

    def close(self) -> None:
        self._sock.close()


# ---------------------------------------------------------------------------
# client transport
# ---------------------------------------------------------------------------

class SocketTransport(Transport):
    """Blocking request/reply over the gateway socket; pushed notification
    frames are stashed (or blocked for) rather than delivered by callback."""

    def __init__(self, host: str, port: int, consumer: str,
                 connect_timeout: float = 10.0):
        deadline = time.monotonic() + connect_timeout
        last_err = None
        while True:                      # the server may still be binding
            try:
                self.sock = socket.create_connection((host, port), timeout=30)
                # the connect timeout must not linger: a volunteer may sit in
                # wait_notification far longer than any connect should take
                self.sock.settimeout(None)
                break
            except OSError as e:
                last_err = e
                if time.monotonic() >= deadline:
                    raise ConnectionError(
                        f"gateway at {host}:{port} unreachable: {last_err}")
                time.sleep(0.05)
        self.inbox: Deque = deque()
        self.consumer = consumer
        self.bytes_moved = 0
        self.call(Hello(consumer))

    def set_deliver(self, deliver) -> None:
        """SocketTransport is a BLOCKING client port: notifications are
        consumed via ``wait_notification``/``inbox``, never pushed through a
        callback — so the virtual-clock engines (which need synchronous
        delivery) cannot run over it. Fail loudly instead of deadlocking."""
        raise RuntimeError(
            "SocketTransport has no callback delivery; drive it with a "
            "blocking client loop (gateway.run_volunteer), not an engine")

    def call(self, msg):
        self.bytes_moved += _send_frame(self.sock, msg)
        while True:
            reply = _recv_frame(self.sock)
            if reply is None:
                raise ConnectionError("gateway closed the connection")
            if isinstance(reply, NOTIFICATION_TYPES):
                self.inbox.append(reply)
                continue
            return reply

    def wait_notification(self):
        """Block until the server pushes a Wake/VersionReady frame."""
        if self.inbox:
            return self.inbox.popleft()
        msg = _recv_frame(self.sock)
        if msg is None:
            raise ConnectionError("gateway closed while waiting")
        if not isinstance(msg, NOTIFICATION_TYPES):
            raise RuntimeError(f"unexpected frame while idle: {msg}")
        return msg

    def close(self) -> None:
        self.sock.close()


# ---------------------------------------------------------------------------
# the engine-free volunteer
# ---------------------------------------------------------------------------

def _wait(transport: Transport, inbox: Deque) -> None:
    if inbox:
        inbox.popleft()
        return
    waiter = getattr(transport, "wait_notification", None)
    if waiter is None:
        raise RuntimeError(
            "volunteer blocked on a transport that cannot wait — with no "
            "other actors this is a protocol deadlock")
    waiter()


def run_volunteer(transport: Transport, vid: str, n_versions: int,
                  ) -> Tuple[int, int]:
    """Drive one volunteer to run completion over any transport. Compute is
    synthetic (gradient payloads None, model blobs version strings). Returns
    (final_version, tasks_done)."""
    sess = VolunteerSession(vid, transport)
    inbox: Deque = getattr(transport, "inbox", None)
    if inbox is None:
        inbox = deque()
        transport.set_deliver(lambda c, m: inbox.append(m))
    # end-of-run nudge: a volunteer idling on the task queue when ANOTHER
    # volunteer publishes the final version would otherwise wait forever —
    # the VersionReady push for the final version breaks that wait
    sess.subscribe(Blocked(version=n_versions))
    tasks_done = 0
    while True:
        if sess.task is None:
            # termination is only checked while idle — while a task is held,
            # advance()'s own LatestReq covers staleness, so the socket path
            # pays one version poll per task, not one per protocol move
            if sess.latest() >= n_versions:
                break
            if isinstance(sess.lease(0.0), NoTask):
                sess.subscribe_idle()
                _wait(transport, inbox)
                continue
        out = sess.advance(0.0)
        if isinstance(out, Blocked):
            sess.subscribe(out)
            _wait(transport, inbox)
            continue
        if isinstance(out, TaskDone):
            continue
        if isinstance(out, MapWork):
            if not sess.finish_map(None, 0, 0.0).stale:
                tasks_done += 1
        elif isinstance(out, ReduceWork):
            sess.finish_reduce(f"v{out.task.version + 1}")
            tasks_done += 1
    final = sess.latest()
    sess.bye()
    return final, tasks_done


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _problem(args) -> SyntheticProblem:
    return SyntheticProblem(n_versions=args.n_versions, n_mb=args.n_mb)


def _serve(args) -> int:
    server = GatewayServer(_problem(args), port=args.port,
                           n_versions=args.n_versions)
    if args.port_file:
        tmp = args.port_file + ".tmp"
        with open(tmp, "w") as f:
            f.write(str(server.port))
        os.replace(tmp, args.port_file)         # atomic: readers never see ""
    print(f"gateway: serving {args.n_versions} versions x "
          f"{args.n_mb}+1 tasks on 127.0.0.1:{server.port}", flush=True)
    server.start()
    server.done.wait(timeout=args.timeout)
    # linger until connected volunteers finish their goodbyes (Bye + close)
    deadline = time.monotonic() + 5.0
    while server._conns and time.monotonic() < deadline:
        time.sleep(0.02)
    ok = server.ds.latest_version >= args.n_versions
    print(f"gateway: final_version={server.ds.latest_version} "
          f"({'done' if ok else 'TIMEOUT'})", flush=True)
    server.close()
    return 0 if ok else 1


def _volunteer(args) -> int:
    transport = SocketTransport("127.0.0.1", args.port, args.vid)
    final, tasks = run_volunteer(transport, args.vid, args.n_versions)
    transport.close()
    print(f"volunteer {args.vid}: final_version={final} tasks={tasks} "
          f"bytes_sent={transport.bytes_moved}", flush=True)
    if args.expect_final is not None and final != args.expect_final:
        print(f"FAIL: expected final_version={args.expect_final}")
        return 1
    return 0


def _smoke(args) -> int:
    """End-to-end proof: the identical volunteer loop over (a) direct calls
    and (b) a real socket to a separate gateway PROCESS must agree."""
    # (a) in-process reference
    server = GatewayServer(_problem(args), n_versions=args.n_versions)
    ref_final, ref_tasks = run_volunteer(
        InProcessTransport(server.endpoint), "ref", args.n_versions)
    server.close()
    # (b) out-of-process over the wire
    with tempfile.TemporaryDirectory() as td:
        port_file = os.path.join(td, "gw.port")
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.core.gateway", "--serve",
             "--port", "0", "--port-file", port_file,
             "--n-versions", str(args.n_versions), "--n-mb", str(args.n_mb)],
            env=os.environ.copy())
        try:
            deadline = time.monotonic() + 20
            while not os.path.exists(port_file):
                if time.monotonic() > deadline or proc.poll() is not None:
                    raise RuntimeError("gateway server did not come up")
                time.sleep(0.05)
            with open(port_file) as f:
                port = int(f.read())
            transport = SocketTransport("127.0.0.1", port, "gw0")
            final, tasks = run_volunteer(transport, "gw0", args.n_versions)
            transport.close()
            rc = proc.wait(timeout=10)
        finally:
            if proc.poll() is None:
                proc.kill()
    n_tasks = args.n_versions * (args.n_mb + 1)
    assert final == ref_final == args.n_versions, (final, ref_final)
    assert tasks == ref_tasks == n_tasks, (tasks, ref_tasks, n_tasks)
    assert rc == 0, f"gateway server exited {rc}"
    print(f"# OK gateway smoke: out-of-process volunteer over the socket "
          f"matched in-process — final_version={final}, tasks={tasks}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--serve", action="store_true")
    mode.add_argument("--volunteer", action="store_true")
    mode.add_argument("--smoke", action="store_true")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--port-file", default=None)
    ap.add_argument("--vid", default="gw0")
    ap.add_argument("--n-versions", type=int, default=4)
    ap.add_argument("--n-mb", type=int, default=6)
    ap.add_argument("--expect-final", type=int, default=None)
    ap.add_argument("--timeout", type=float, default=60.0)
    args = ap.parse_args(argv)
    if args.serve:
        return _serve(args)
    if args.volunteer:
        return _volunteer(args)
    return _smoke(args)


if __name__ == "__main__":
    sys.exit(main())
