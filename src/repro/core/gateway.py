"""Gateway — the volunteer protocol over a real loopback socket, durably.

``python -m repro.core.gateway`` hosts a QueueServer + DataServer behind
``protocol.ServerEndpoint`` on a TCP socket (length-prefixed frames of
canonically encoded messages), so a genuinely **out-of-process** volunteer can
join a training run — the end-to-end proof that the sans-IO redesign works:
the same ``VolunteerSession`` that drives the Coordinator's JAX compute and
the Simulator's virtual time here drives a blocking socket client, with zero
protocol code of its own.

Beyond the liveness proof, the gateway is a durable volunteer SERVICE:

- **Wall-clock leases** — the endpoint carries a ``WallClock`` (the
  ``LeaseClock`` implementation for real time), so the SERVER stamps every
  lease deadline, and a sweeper thread drives ``QueueServer.expire_all()``
  whenever a real deadline passes: a socket volunteer that is kill -9'd
  mid-task has its ticket requeued after ``--visibility-timeout`` seconds and
  the run finishes without it (MLitB's "failure is the common case" stance).
- **Snapshot/restore** — ``--snapshot-every K`` serializes the full
  QueueServer + DataServer live state (pending FIFOs, in-flight deadlines,
  banked signals, counters, model blobs) through the ``checkpoint.serialize``
  codecs to ``--snapshot-path`` after every K state-changing requests,
  atomically; ``--restore-from`` boots a fresh process from the latest
  snapshot. kill -9 the server, restart, and the run resumes: unacked work
  replays (at-least-once) and dead clients' leases expire via the sweeper.
  Deadlines are ``time.monotonic()`` values — boot-relative on Linux/macOS,
  so they stay meaningful across a server process restart.
- **Server-side applier** — for barrierless policies (``staleness:<s>``,
  ``local:<k>``) the endpoint hosts a ``ServerApplier``: volunteers push one
  ``SubmitUpdate`` (gradient/delta up) and the SERVER runs admission ->
  apply -> publish -> ack, so a thin client never fetches the admission-time
  model or pushes the updated blob (the DistML.js parameter-server shape;
  bytes-per-update measured in ``benchmarks/staleness.py``).

Pieces:

- ``GatewayServer`` — accept loop + per-connection reader threads; one global
  lock serializes endpoint dispatch (the in-process servers are
  single-threaded by design). A connection binds to a consumer id with
  ``Hello``; ``Wake``/``VersionReady`` notification frames are pushed down
  that consumer's connection.
- ``SocketTransport`` — the client half: ``call`` writes a request frame and
  reads until the reply frame arrives, stashing any notification frames that
  interleave; ``wait_notification`` blocks on the socket for the next push.
- ``run_volunteer`` — the engine-free driver: lease -> advance -> synthetic
  compute -> finish, blocking on notifications while ``Blocked``. Works over
  ANY transport; ``run_volunteer_resilient`` adds reconnect-on-crash so a
  volunteer survives a gateway restart.

Usage:
  python -m repro.core.gateway --serve --port 0 --port-file /tmp/gw.port
  python -m repro.core.gateway --serve --visibility-timeout 2 \\
      --snapshot-every 1 --snapshot-path /tmp/gw.snap
  python -m repro.core.gateway --volunteer --port 12345 --expect-final 4
  python -m repro.core.gateway --smoke
"""
from __future__ import annotations

import argparse
import os
import signal
import socket
import struct
import subprocess
import sys
import tempfile
import threading
import time
from collections import deque
from typing import Deque, Dict, Optional, Tuple

from repro.checkpoint import serialize
from repro.core.aggregation import PolicyLike, make_policy
from repro.core.dataserver import DataServer
from repro.core.initiator import enqueue_problem
from repro.core.protocol import (Blocked, Hello, KickQueue, LocalWork, MapWork,
                                 NoTask, NOTIFICATION_TYPES, ReduceWork,
                                 ServerApplier, ServerEndpoint, TaskDone,
                                 VolunteerSession, Wake, decode_message,
                                 encode_message)
from repro.core.queue import QueueServer, ShardedQueueServer, WallClock
from repro.core.simulator import SyntheticProblem
from repro.core.transport import InProcessTransport, Transport

_LEN = struct.Struct(">I")

# requests that cannot change durable state — skipped by the snapshot trigger
_READONLY = ("LatestReq", "DepthReq", "DrainedReq", "FetchModel", "Hello")

# the module's single wall-time authority: connect deadlines, smoke-leg
# timers, and compute pacing all read the same LeaseClock the server stamps
# leases with (REPRO-TIME)
_CLOCK = WallClock()


def _monitor():
    """The runtime lock/invariant monitor, iff ``ANALYSIS_INSTRUMENT=1``
    (see ``repro.analysis.runtime``); None — zero overhead — otherwise.
    The env var rides ``os.environ.copy()`` into every spawned server and
    volunteer subprocess, so one instrumented ``--smoke`` covers the whole
    topology."""
    if not os.environ.get("ANALYSIS_INSTRUMENT"):
        return None
    from repro.analysis.runtime import Analysis
    return Analysis.instrument()


def _make_lock(name: str, *, guard: bool = False):
    """Lock seam: a plain ``threading.Lock`` normally, a ``MonitoredLock``
    under instrumentation. ``guard=True`` marks a dispatch lock no blocking
    call may run under (LOCK-BLOCK)."""
    mon = _monitor()
    if mon is not None:
        return mon.make_lock(name, guard=guard)
    return threading.Lock()


def _send_frame(sock: socket.socket, msg) -> int:
    data = encode_message(msg)
    sock.sendall(_LEN.pack(len(data)) + data)
    return _LEN.size + len(data)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    mon = _monitor()
    if mon is not None:
        mon.note_blocking("socket-recv")
    buf = b""
    while len(buf) < n:
        try:
            chunk = sock.recv(n - len(buf))
        except socket.timeout:
            if not buf:
                raise               # idle timeout: caller decides (heartbeat)
            continue                # mid-frame: the rest is in flight
        except OSError:
            return None
        if not chunk:
            return None
        buf += chunk
    return buf


def _recv_frame(sock: socket.socket):
    head = _recv_exact(sock, _LEN.size)
    if head is None:
        return None
    body = _recv_exact(sock, _LEN.unpack(head)[0])
    return None if body is None else decode_message(body)


def _synthetic_apply(blob, result, version: int):
    """The gateway's synthetic applier: model blobs are version strings, so
    applying any admitted contribution to version v just names v+1 (the real
    engines hand ``ApplyWork`` to JAX; the gateway proves the protocol)."""
    return f"v{version + 1}"


# ---------------------------------------------------------------------------
# server
# ---------------------------------------------------------------------------

class GatewayServer:
    """Loopback volunteer service: wall-clock leases + sweeper, optional
    periodic snapshots, optional server-side applier (barrierless policies).
    """

    def __init__(self, problem=None, *, host: str = "127.0.0.1", port: int = 0,
                 n_versions: Optional[int] = None, policy: PolicyLike = None,
                 n_shards: int = 1,
                 visibility_timeout: float = float("inf"),
                 sweep_interval: float = 0.05,
                 snapshot_path: Optional[str] = None, snapshot_every: int = 0,
                 restore_from: Optional[str] = None):
        self.policy = make_policy(policy)
        self.clock = WallClock()
        if problem is None:
            # even a restore needs the problem spec: the commit target is
            # policy arithmetic over (n_versions, n_mb), which the snapshot
            # records only as a cross-check, not as a reconstructible schedule
            raise ValueError("GatewayServer needs the problem spec (pass the "
                             "same --n-versions/--n-mb as the original serve "
                             "when restoring)")
        self.qs = (QueueServer(default_timeout=visibility_timeout)
                   if n_shards <= 1
                   else ShardedQueueServer(n_shards,
                                           default_timeout=visibility_timeout))
        self.ds = DataServer()
        nv = n_versions if n_versions is not None else problem.n_versions
        self.n_versions = nv
        # the run's commit target: the policy decides how many model versions
        # `nv` BSP-equivalent rounds must publish (sync: nv; async: nv * n_mb)
        self.n_updates = self.policy.n_updates(problem, nv)
        if restore_from is not None:
            self.restore(restore_from)
        else:
            enqueue_problem(problem, self.qs, self.ds, n_versions=nv,
                            policy=self.policy, store_real_model=False)
        applier = None
        if not self.policy.barrier:
            applier = ServerApplier(self.policy, _synthetic_apply)
        self.applier = applier
        self.endpoint = ServerEndpoint(self.qs, self.ds, self._notify,
                                       clock=self.clock, applier=applier)
        self.sweep_interval = sweep_interval
        self.snapshot_path = snapshot_path
        self.snapshot_every = snapshot_every
        self.snapshots_written = 0
        self._ops_since_snap = 0
        # dispatch lock (guard: no blocking call may run under it) + a
        # separate writer lock so snapshot fsyncs serialize among themselves
        # without ever stalling dispatch
        self._lock = _make_lock("gateway._lock", guard=True)
        self._snap_lock = _make_lock("gateway._snap_lock")
        self._snap_seq = 0                       # encode order (under _lock)
        self._snap_written = 0                   # last seq on disk (_snap_lock)
        self._conns: Dict[str, socket.socket] = {}
        self.done = threading.Event()
        self._closed = threading.Event()
        if self.ds.latest_version >= self.n_updates:
            self.done.set()                      # restored a finished run
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(16)
        self.port = self._sock.getsockname()[1]

    # -- durability ------------------------------------------------------------
    def _encode_snapshot(self) -> Tuple[int, bytes]:
        """Serialize the full queue+data state (CPU only — caller holds the
        dispatch lock). The blob rides the PROTOCOL wire codec
        (``encode_message``), not raw ``serialize.dumps``, because queue
        bodies are wire dataclasses (``MapTask`` et al.) that serialize by
        registered name. Returns (seq, bytes): ``seq`` orders this state
        against other encodes so a slow writer can never clobber a newer
        snapshot with an older one."""
        assert self.snapshot_path is not None
        state = {"gateway": {"qs": self.qs.snapshot(),
                             "ds": self.ds.snapshot(),
                             "n_updates": self.n_updates,
                             "policy": self.policy.spec}}
        self._snap_seq += 1
        return self._snap_seq, encode_message(state,
                                              codec=serialize.DEFAULT_CODEC)

    def _write_snapshot(self, seq: int, data: bytes) -> int:
        """Atomic-write an encoded snapshot (tmp + fsync + rename) — called
        with the dispatch lock RELEASED: the fsync is the blocking call that
        must never stall dispatch (LOCK-BLOCK invariant). Returns bytes
        written, 0 if a newer snapshot already reached disk."""
        with self._snap_lock:
            if seq <= self._snap_written:
                return 0
            mon = _monitor()
            if mon is not None:
                mon.note_blocking("snapshot-fsync")
            n = serialize.atomic_write(self.snapshot_path, data)
            self._snap_written = seq
            self.snapshots_written += 1
            return n

    def snapshot(self) -> int:
        """Write the full queue+data state atomically; returns bytes
        written. Takes the dispatch lock itself — call it unlocked."""
        with self._lock:
            seq, data = self._encode_snapshot()
        return self._write_snapshot(seq, data)

    def restore(self, path: str) -> None:
        state = decode_message(serialize.read_bytes(path))["gateway"]
        # the snapshot records the run's semantics as a cross-check: booting
        # it under different CLI flags must fail HERE, not as a confusing
        # protocol cascade once volunteers reconnect
        if state["policy"] != self.policy.spec:
            raise ValueError(f"snapshot was served under policy="
                             f"{state['policy']!r}, this server is "
                             f"{self.policy.spec!r} — pass the original "
                             f"--policy")
        if state["n_updates"] != self.n_updates:
            raise ValueError(f"snapshot's commit target is "
                             f"{state['n_updates']}, this server computes "
                             f"{self.n_updates} — pass the original "
                             f"--n-versions/--n-mb")
        if state["qs"].get("kind") == "ShardedQueueServer" and \
                not isinstance(self.qs, ShardedQueueServer):
            self.qs = ShardedQueueServer(1, default_timeout=float("inf"))
        elif state["qs"].get("kind") == "QueueServer" and \
                isinstance(self.qs, ShardedQueueServer):
            self.qs = QueueServer()
        self.qs.restore(state["qs"])
        self.ds.restore(state["ds"])

    def _maybe_snapshot(self, msg) -> Optional[Tuple[int, bytes]]:
        """Called under the dispatch lock. When a snapshot is due, ENCODES
        the state (pure CPU) and returns the pending ``(seq, bytes)`` for
        the caller to write after releasing the lock; None otherwise."""
        if self.snapshot_every <= 0 or self.snapshot_path is None:
            return None
        if type(msg).__name__ in _READONLY:
            return None
        self._ops_since_snap += 1
        if self._ops_since_snap < self.snapshot_every:
            return None
        self._ops_since_snap = 0
        return self._encode_snapshot()

    # -- lease sweeper ---------------------------------------------------------
    def _sweep_loop(self) -> None:
        """Visibility-timeout enforcement on REAL deadlines: wake when the
        earliest lease deadline passes and requeue everything expired (the
        requeue notifications push Wake frames to waiting volunteers). This
        is the clock owner the in-process engines emulate with virtual time."""
        while not self._closed.is_set():
            pending = None
            with self._lock:
                now = self.clock.now()
                expired = self.qs.expire_all(now)
                if expired and self.snapshot_every > 0 \
                        and self.snapshot_path is not None:
                    # expiry is a durable state change; encode under the
                    # lock, fsync after releasing it
                    pending = self._encode_snapshot()
                dl = self.qs.next_deadline()
            if pending is not None:
                self._write_snapshot(*pending)
            wait = self.sweep_interval if dl is None else \
                max(0.0, min(dl - self.clock.now(), self.sweep_interval))
            self._closed.wait(wait if wait > 0 else 0.001)

    # -- wire ------------------------------------------------------------------
    def _notify(self, consumer: str, msg) -> None:
        # called inside endpoint.handle, under self._lock. The send is
        # bounded: a client that stops draining its socket would otherwise
        # block here with the global lock held and stall the whole server —
        # treat a wedged buffer like a disconnect and drop the registration.
        conn = self._conns.get(consumer)
        delivered = False
        if conn is not None:
            try:
                conn.settimeout(10.0)
                _send_frame(conn, msg)
                delivered = True
            except OSError:
                self._conns.pop(consumer, None)
            finally:
                try:
                    conn.settimeout(None)
                except OSError:
                    pass
        if not delivered and isinstance(msg, Wake):
            # a queue wake is one-shot: consumed by an unreachable consumer,
            # the event would be lost to everyone. Hand it to the next waiter
            # (or bank it), like the engines' dead-volunteer kick path —
            # through the endpoint, the same move a live volunteer's
            # KickQueue request makes (REPRO-LAYER).
            self.endpoint.handle(KickQueue(msg.queue))

    def _serve_conn(self, conn: socket.socket) -> None:
        consumer = None
        try:
            while True:
                msg = _recv_frame(conn)
                if msg is None:
                    break
                with self._lock:
                    if isinstance(msg, Hello):
                        consumer = msg.consumer
                        self._conns[consumer] = conn
                    reply = self.endpoint.handle(msg)
                    _send_frame(conn, reply)
                    pending = self._maybe_snapshot(msg)
                    if self.ds.latest_version >= self.n_updates:
                        self.done.set()
                if pending is not None:
                    self._write_snapshot(*pending)
        finally:
            with self._lock:
                if consumer is not None and self._conns.get(consumer) is conn:
                    del self._conns[consumer]
                    # a disconnected consumer can never serve a wake: drop
                    # its queue waiters so they stop consuming one-shot
                    # events other volunteers need. Its LEASES stay — that
                    # recovery is deliberately the sweeper's (it may
                    # reconnect and heartbeat; only real death expires them).
                    self.endpoint.disconnect(consumer)
            conn.close()

    def serve_forever(self) -> None:
        while True:
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()

    def start(self) -> threading.Thread:
        threading.Thread(target=self._sweep_loop, daemon=True).start()
        t = threading.Thread(target=self.serve_forever, daemon=True)
        t.start()
        return t

    def close(self) -> None:
        self._closed.set()
        self._sock.close()


# ---------------------------------------------------------------------------
# client transport
# ---------------------------------------------------------------------------

class SocketTransport(Transport):
    """Blocking request/reply over the gateway socket; pushed notification
    frames are stashed (or blocked for) rather than delivered by callback."""

    timed_waits = True               # wait_notification accepts a timeout

    def __init__(self, host: str, port: int, consumer: str,
                 connect_timeout: float = 10.0):
        deadline = _CLOCK.now() + connect_timeout
        last_err = None
        while True:                      # the server may still be binding
            try:
                self.sock = socket.create_connection((host, port), timeout=30)
                # the connect timeout must not linger: a volunteer may sit in
                # wait_notification far longer than any connect should take
                self.sock.settimeout(None)
                break
            except OSError as e:
                last_err = e
                if _CLOCK.now() >= deadline:
                    raise ConnectionError(
                        f"gateway at {host}:{port} unreachable: {last_err}")
                time.sleep(0.05)
        self.inbox: Deque = deque()
        self.consumer = consumer
        self.bytes_moved = 0
        self.sent: Dict[str, int] = {}   # request-type histogram (observable:
        #                                  the applier path sends no PublishModel)
        self.call(Hello(consumer))

    def set_deliver(self, deliver) -> None:
        """SocketTransport is a BLOCKING client port: notifications are
        consumed via ``wait_notification``/``inbox``, never pushed through a
        callback — so the virtual-clock engines (which need synchronous
        delivery) cannot run over it. Fail loudly instead of deadlocking."""
        raise RuntimeError(
            "SocketTransport has no callback delivery; drive it with a "
            "blocking client loop (gateway.run_volunteer), not an engine")

    def call(self, msg):
        name = type(msg).__name__
        self.sent[name] = self.sent.get(name, 0) + 1
        self.bytes_moved += _send_frame(self.sock, msg)
        while True:
            reply = _recv_frame(self.sock)
            if reply is None:
                raise ConnectionError("gateway closed the connection")
            if isinstance(reply, NOTIFICATION_TYPES):
                self.inbox.append(reply)
                continue
            return reply

    def wait_notification(self, timeout: Optional[float] = None):
        """Block until the server pushes a Wake/VersionReady frame. With a
        ``timeout``, return None when nothing arrives in time — the caller's
        cue to heartbeat its lease and re-check state."""
        if self.inbox:
            return self.inbox.popleft()
        if timeout is not None:
            self.sock.settimeout(timeout)
        try:
            msg = _recv_frame(self.sock)
        except socket.timeout:
            return None
        finally:
            if timeout is not None:
                try:
                    self.sock.settimeout(None)
                except OSError:
                    pass
        if msg is None:
            raise ConnectionError("gateway closed while waiting")
        if not isinstance(msg, NOTIFICATION_TYPES):
            raise RuntimeError(f"unexpected frame while idle: {msg}")
        return msg

    def close(self) -> None:
        self.sock.close()


# ---------------------------------------------------------------------------
# the engine-free volunteer
# ---------------------------------------------------------------------------

def _wait(transport: Transport, inbox: Deque,
          timeout: Optional[float] = None, *, holding: bool = False) -> bool:
    """Wait for the next notification. Returns False on a timed-out wait
    (the caller should heartbeat its lease and re-check state). ``holding``
    says whether the caller still holds a leased ticket — an UNTIMED wait
    while holding is the PARKED-HOLDER invariant the runtime monitor checks
    (PR 5's step-aside deadlock: if that ticket is the last progressable
    task, nothing can ever wake the parked holder)."""
    if inbox:
        inbox.popleft()
        return True
    waiter = getattr(transport, "wait_notification", None)
    if waiter is None:
        raise RuntimeError(
            "volunteer blocked on a transport that cannot wait — with no "
            "other actors this is a protocol deadlock")
    timed = timeout is not None and getattr(transport, "timed_waits", False)
    mon = _monitor()
    if mon is not None:
        mon.note_park("volunteer-wait", holding=holding, timed=timed)
    if timed:
        return waiter(timeout) is not None
    waiter()
    return True


def run_volunteer(transport: Transport, vid: str, n_updates: int, *,
                  policy: PolicyLike = None, task_delay: float = 0.0,
                  heartbeat_every: float = 0.5,
                  tally: Optional[list] = None) -> Tuple[int, int]:
    """Drive one volunteer to run completion over any transport. Compute is
    synthetic (gradient payloads None, model blobs version strings);
    ``task_delay`` sleeps that long per compute — the window the chaos legs
    use to kill a process mid-task. Barrierless policies commit through the
    server-side applier (one ``SubmitUpdate``, no model push). On transports
    with timed waits, every wait wakes at least each ``heartbeat_every``
    seconds to renew the held lease (``ExtendLease``) and re-check state —
    so a LIVE volunteer parked on the reduce barrier never loses its ticket
    to the wall-clock sweeper, while a dead one's expires on schedule.
    ``tally`` (a one-element list) is incremented per completed task IN
    PLACE, so a caller surviving this function's ConnectionError still sees
    the partial count. Returns (final_version, tasks_done)."""
    pol = make_policy(policy)
    sess = VolunteerSession(vid, transport, policy=pol)
    inbox: Deque = getattr(transport, "inbox", None)
    if inbox is None:
        inbox = deque()
        transport.set_deliver(lambda c, m: inbox.append(m))
    # end-of-run nudge: a volunteer idling on the task queue when ANOTHER
    # volunteer publishes the final version would otherwise wait forever —
    # the VersionReady push for the final version breaks that wait
    sess.subscribe(Blocked(version=n_updates))
    tasks_done = 0

    def bump():
        nonlocal tasks_done
        tasks_done += 1
        if tally is not None:
            tally[0] += 1

    def compute_delay():
        # simulate slow compute in heartbeat-sized slices, renewing the held
        # lease between them — a LIVE volunteer must keep its ticket through
        # a compute longer than the visibility timeout (only kill -9 stops
        # the renewals, which is exactly when the sweeper SHOULD requeue)
        end = _CLOCK.now() + task_delay
        while True:
            rem = end - _CLOCK.now()
            if rem <= 0:
                return
            time.sleep(min(rem, heartbeat_every))
            sess.heartbeat()

    while True:
        if sess.task is None:
            # termination is only checked while idle — while a task is held,
            # advance()'s own LatestReq covers staleness, so the socket path
            # pays one version poll per task, not one per protocol move
            if sess.latest() >= n_updates:
                break
            if isinstance(sess.lease(0.0), NoTask):
                sess.subscribe_idle()
                _wait(transport, inbox, heartbeat_every)
                continue
        out = sess.advance(0.0)
        if isinstance(out, Blocked):
            sess.subscribe(out)
            woke = _wait(transport, inbox, heartbeat_every,
                         holding=sess.task is not None)
            # renew on EVERY wakeup, not just timeouts: a dense stream of
            # (spurious) wakes must not starve the renewal of a held lease
            sess.heartbeat()
            if not woke:
                if sess.latest() >= n_updates:
                    break            # run finished while we were parked; the
                    #                  held ticket requeues via bye() below
                # deadlock breaker: a holder still blocked after a full wait
                # window steps aside while OTHER tasks are leasable —
                # requeue to the BACK (order-safe: a version-blocked map
                # cannot run before its version commits, and a reduce's
                # barrier state lives in the results queue, not the ticket)
                # and take the front task instead. The queue becomes a slow
                # rotation that always finds the one progressable task —
                # e.g. the expiry-recovered map an open barrier is missing —
                # where a fleet of parked holders would deadlock.
                if sess.task is not None and sess.queue_depth() > 0:
                    sess.release(front=False)
            continue
        if isinstance(out, TaskDone):
            continue
        if task_delay > 0:
            compute_delay()
        if isinstance(out, MapWork):
            if pol.barrier:
                if not sess.finish_map(None, 0, 0.0).stale:
                    bump()
            else:
                if not sess.submit_update(sess.grad_result(None, 0, 0.0)).stale:
                    bump()
        elif isinstance(out, LocalWork):
            if not sess.submit_update(sess.delta_result(None, 0, 0.0)).stale:
                bump()
        elif isinstance(out, ReduceWork):
            sess.finish_reduce(f"v{out.task.version + 1}")
            bump()
    final = sess.latest()
    sess.bye()
    return final, tasks_done


def run_volunteer_resilient(host: str, port: int, vid: str, n_updates: int, *,
                            policy: PolicyLike = None, task_delay: float = 0.0,
                            max_reconnects: int = 20,
                            ) -> Tuple[int, int, int]:
    """``run_volunteer`` that survives gateway crashes: on a connection error
    it reconnects (fresh transport + session, same consumer id) and resumes.
    A lease the dead attempt held is recovered by the server's wall-clock
    sweeper, so no work is lost — only possibly repeated (at-least-once).
    Returns (final_version, tasks_done_total, reconnects)."""
    tally = [0]
    reconnects = -1
    while True:
        reconnects += 1
        if reconnects > max_reconnects:
            raise ConnectionError(
                f"{vid}: gave up after {max_reconnects} reconnects")
        try:
            transport = SocketTransport(host, port, vid, connect_timeout=15.0)
        except ConnectionError:
            continue
        try:
            final, _ = run_volunteer(transport, vid, n_updates,
                                     policy=policy, task_delay=task_delay,
                                     tally=tally)
            return final, tally[0], reconnects
        except ConnectionError:
            # server died mid-run; partial progress is already durable
            # server-side (acked tasks) or recoverable (leases expire)
            continue
        finally:
            try:
                transport.close()
            except OSError:
                pass


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _problem(args) -> SyntheticProblem:
    return SyntheticProblem(n_versions=args.n_versions, n_mb=args.n_mb)


def _target(args) -> int:
    return make_policy(args.policy).n_updates(_problem(args), args.n_versions)


def _serve(args) -> int:
    server = GatewayServer(
        _problem(args), port=args.port, n_versions=args.n_versions,
        policy=args.policy, n_shards=args.shards,
        visibility_timeout=args.visibility_timeout,
        snapshot_path=args.snapshot_path, snapshot_every=args.snapshot_every,
        restore_from=args.restore_from)
    if args.port_file:
        tmp = args.port_file + ".tmp"
        with open(tmp, "w") as f:
            f.write(str(server.port))
        os.replace(tmp, args.port_file)         # atomic: readers never see ""
    print(f"gateway: serving {args.n_versions} versions x "
          f"{args.n_mb}+1 tasks (policy={server.policy.spec}, "
          f"target={server.n_updates}, "
          f"vt={args.visibility_timeout}) on 127.0.0.1:{server.port}"
          + (f" [restored from {args.restore_from}]" if args.restore_from
             else ""), flush=True)
    server.start()
    server.done.wait(timeout=args.timeout)
    # linger until connected volunteers finish their goodbyes (Bye + close);
    # generous, because a volunteer parked in a timed wait notices the end
    # of the run on its next wakeup, not instantly
    deadline = _CLOCK.now() + 20.0
    while server._conns and _CLOCK.now() < deadline:
        time.sleep(0.02)
    ok = server.ds.latest_version >= server.n_updates
    print(f"gateway: final_version={server.ds.latest_version} "
          f"snapshots={server.snapshots_written} "
          f"({'done' if ok else 'TIMEOUT'})", flush=True)
    server.close()
    return 0 if ok else 1


def _volunteer(args) -> int:
    n_updates = _target(args)
    final, tasks, reconnects = run_volunteer_resilient(
        "127.0.0.1", args.port, args.vid, n_updates, policy=args.policy,
        task_delay=args.task_delay)
    print(f"volunteer {args.vid}: final_version={final} tasks={tasks} "
          f"reconnects={reconnects}", flush=True)
    if args.expect_final is not None and final != args.expect_final:
        print(f"FAIL: expected final_version={args.expect_final}")
        return 1
    return 0


def _spawn_server(args, port_file: str, *, port: int = 0,
                  extra: Tuple[str, ...] = ()) -> subprocess.Popen:
    return subprocess.Popen(
        [sys.executable, "-m", "repro.core.gateway", "--serve",
         "--port", str(port), "--port-file", port_file,
         "--n-versions", str(args.n_versions), "--n-mb", str(args.n_mb),
         *extra],
        env=os.environ.copy())


def _wait_port(port_file: str, proc: subprocess.Popen,
               timeout: float = 20.0) -> int:
    deadline = _CLOCK.now() + timeout
    while not os.path.exists(port_file):
        if _CLOCK.now() > deadline or proc.poll() is not None:
            raise RuntimeError("gateway server did not come up")
        time.sleep(0.05)
    with open(port_file) as f:
        return int(f.read())


def _smoke_transport_equivalence(args) -> None:
    """Leg 1 — the identical volunteer loop over (a) direct calls and (b) a
    real socket to a separate gateway PROCESS must agree."""
    server = GatewayServer(_problem(args), n_versions=args.n_versions)
    ref_final, ref_tasks = run_volunteer(
        InProcessTransport(server.endpoint), "ref", args.n_versions)
    server.close()
    with tempfile.TemporaryDirectory() as td:
        port_file = os.path.join(td, "gw.port")
        proc = _spawn_server(args, port_file)
        try:
            port = _wait_port(port_file, proc)
            transport = SocketTransport("127.0.0.1", port, "gw0")
            final, tasks = run_volunteer(transport, "gw0", args.n_versions)
            transport.close()
            rc = proc.wait(timeout=10)
        finally:
            if proc.poll() is None:
                proc.kill()
    n_tasks = args.n_versions * (args.n_mb + 1)
    assert final == ref_final == args.n_versions, (final, ref_final)
    assert tasks == ref_tasks == n_tasks, (tasks, ref_tasks, n_tasks)
    assert rc == 0, f"gateway server exited {rc}"
    print(f"# OK gateway smoke [transport]: out-of-process volunteer over "
          f"the socket matched in-process — final_version={final}, "
          f"tasks={tasks}")


def _smoke_lease_sweeper(args) -> None:
    """Leg 2 — kill -9 a real volunteer PROCESS mid-task: its lease must
    expire on the wall clock (sweeper thread), the ticket requeue, and the
    surviving volunteers finish the whole run. Two survivors, because the
    recovered map task needs an IDLE taker if the other survivor is already
    holding the reduce barrier."""
    vt = 1.0
    n_tasks = args.n_versions * (args.n_mb + 1)
    with tempfile.TemporaryDirectory() as td:
        port_file = os.path.join(td, "gw.port")
        proc = _spawn_server(args, port_file,
                             extra=("--visibility-timeout", str(vt)))
        victim = None
        try:
            port = _wait_port(port_file, proc)
            # the victim sleeps 30 s inside every compute, so once it LEASES
            # it is holding that lease when killed (and can never finish)
            victim = subprocess.Popen(
                [sys.executable, "-m", "repro.core.gateway", "--volunteer",
                 "--port", str(port), "--vid", "victim",
                 "--n-versions", str(args.n_versions),
                 "--n-mb", str(args.n_mb), "--task-delay", "30"],
                env=os.environ.copy())
            # wait until the victim has genuinely leased: the task queue's
            # depth drops below the full schedule (DepthReq is read-only)
            from repro.core.protocol import DepthReq
            from repro.core.tasks import INITIAL_QUEUE
            monitor = SocketTransport("127.0.0.1", port, "monitor")
            deadline = _CLOCK.now() + 30.0
            while monitor.call(DepthReq(INITIAL_QUEUE)).value >= n_tasks:
                assert _CLOCK.now() < deadline, "victim never leased"
                time.sleep(0.05)
            monitor.close()
            victim.send_signal(signal.SIGKILL)
            victim.wait(timeout=10)
            t0 = _CLOCK.now()
            results: Dict[str, Tuple[int, int]] = {}

            def survive(vid: str) -> None:
                tr = SocketTransport("127.0.0.1", port, vid)
                results[vid] = run_volunteer(tr, vid, args.n_versions)
                tr.close()

            threads = [threading.Thread(target=survive, args=(f"s{i}",),
                                        daemon=True) for i in range(2)]
            for th in threads:
                th.start()
            for th in threads:
                th.join(timeout=60)
                assert not th.is_alive(), "survivor deadlocked"
            wall = _CLOCK.now() - t0
            rc = proc.wait(timeout=15)
        finally:
            for p in (victim, proc):
                if p is not None and p.poll() is None:
                    p.kill()
    finals = [results[v][0] for v in sorted(results)]
    tasks = sum(results[v][1] for v in sorted(results))
    assert finals == [args.n_versions] * 2, f"run did not finish: {finals}"
    assert tasks >= n_tasks, f"tasks lost: {tasks} < {n_tasks}"
    assert rc == 0, f"gateway server exited {rc}"
    print(f"# OK gateway smoke [lease-sweeper]: victim volunteer kill -9'd "
          f"mid-task; wall-clock sweeper requeued its lease (vt={vt}s) and "
          f"2 survivors finished the run ({tasks} tasks) in {wall:.1f}s")


def _smoke_crash_recovery(args) -> None:
    """Leg 3 — kill -9 the SERVER mid-run, restart from the latest snapshot:
    the volunteer reconnects and the run completes with the same final
    version as the uninterrupted single-process reference (tasks may repeat:
    at-least-once)."""
    # uninterrupted reference (in process, same problem)
    server = GatewayServer(_problem(args), n_versions=args.n_versions)
    ref_final, ref_tasks = run_volunteer(
        InProcessTransport(server.endpoint), "ref", args.n_versions)
    server.close()
    with tempfile.TemporaryDirectory() as td:
        port_file = os.path.join(td, "gw.port")
        snap = os.path.join(td, "gw.snap")
        durable = ("--visibility-timeout", "1.0",
                   "--snapshot-every", "1", "--snapshot-path", snap)
        proc = _spawn_server(args, port_file, extra=durable)
        out: Dict[str, Tuple[int, int, int]] = {}
        try:
            port = _wait_port(port_file, proc)

            def drive():
                out["v"] = run_volunteer_resilient(
                    "127.0.0.1", port, "gw0", args.n_versions,
                    task_delay=0.06)

            vt = threading.Thread(target=drive, daemon=True)
            vt.start()
            time.sleep(0.8)                      # mid-run (15 tasks x ~60ms+)
            proc.send_signal(signal.SIGKILL)     # no goodbye, no final flush
            proc.wait(timeout=10)
            assert os.path.exists(snap), "server died before any snapshot"
            # restart on the SAME port from the latest snapshot
            proc = _spawn_server(args, port_file, port=port,
                                 extra=durable + ("--restore-from", snap))
            vt.join(timeout=60)
            assert not vt.is_alive(), "volunteer never finished after restart"
            rc = proc.wait(timeout=15)
        finally:
            if proc.poll() is None:
                proc.kill()
    final, tasks, reconnects = out["v"]
    assert final == ref_final == args.n_versions, (final, ref_final)
    assert tasks >= ref_tasks, f"lost work: {tasks} < {ref_tasks}"
    assert reconnects >= 1, "volunteer never observed the crash"
    assert rc == 0, f"restarted gateway exited {rc}"
    print(f"# OK gateway smoke [crash-recovery]: server kill -9'd mid-run, "
          f"restarted from snapshot, run resumed and matched the "
          f"uninterrupted final version v{final} "
          f"(tasks {tasks} >= {ref_tasks} ref; {reconnects} reconnect)")


def _smoke_server_applier(args) -> None:
    """Leg 4 — barrierless policy over the socket: the server-side applier
    commits every admitted gradient, so the volunteer's wire histogram shows
    ZERO model pushes and zero admission fetches — the bytes-per-update win
    ``benchmarks/staleness.py`` quantifies."""
    policy = "staleness:2"
    n_updates = make_policy(policy).n_updates(_problem(args), args.n_versions)
    with tempfile.TemporaryDirectory() as td:
        port_file = os.path.join(td, "gw.port")
        proc = _spawn_server(args, port_file, extra=("--policy", policy))
        try:
            port = _wait_port(port_file, proc)
            transport = SocketTransport("127.0.0.1", port, "thin0")
            final, tasks = run_volunteer(transport, "thin0", n_updates,
                                         policy=policy)
            sent = dict(transport.sent)
            transport.close()
            rc = proc.wait(timeout=10)
        finally:
            if proc.poll() is None:
                proc.kill()
    assert final == n_updates, (final, n_updates)
    assert sent.get("SubmitUpdate", 0) == tasks > 0, sent
    assert "PublishModel" not in sent, f"thin client pushed a model: {sent}"
    assert rc == 0, f"gateway server exited {rc}"
    print(f"# OK gateway smoke [server-applier]: {policy} over the socket — "
          f"{tasks} updates committed via SubmitUpdate, volunteer sent "
          f"0 PublishModel frames (server applied every gradient)")


def _smoke(args) -> int:
    _smoke_transport_equivalence(args)
    _smoke_lease_sweeper(args)
    _smoke_crash_recovery(args)
    _smoke_server_applier(args)
    print("# OK gateway smoke: all 4 legs green (transport equivalence, "
          "wall-clock lease sweeper, kill -9 crash recovery, server-side "
          "applier)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--serve", action="store_true")
    mode.add_argument("--volunteer", action="store_true")
    mode.add_argument("--smoke", action="store_true")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--port-file", default=None)
    ap.add_argument("--vid", default="gw0")
    ap.add_argument("--n-versions", type=int, default=4)
    ap.add_argument("--n-mb", type=int, default=6)
    ap.add_argument("--policy", default="sync",
                    help="sync | staleness:<s> | local:<k> (barrierless "
                         "policies enable the server-side applier)")
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--visibility-timeout", type=float, default=float("inf"),
                    help="wall-clock lease seconds before the sweeper "
                         "requeues an unacked task (default: infinite)")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="snapshot after every K state-changing requests "
                         "(0 = never)")
    ap.add_argument("--snapshot-path", default=None)
    ap.add_argument("--restore-from", default=None,
                    help="boot from a snapshot instead of a fresh enqueue")
    ap.add_argument("--task-delay", type=float, default=0.0,
                    help="volunteer: sleep per compute (chaos kill window)")
    ap.add_argument("--expect-final", type=int, default=None)
    ap.add_argument("--timeout", type=float, default=60.0)
    args = ap.parse_args(argv)
    if args.serve:
        rc = _serve(args)
    elif args.volunteer:
        rc = _volunteer(args)
    else:
        rc = _smoke(args)
    mon = _monitor()
    if mon is not None:
        # instrumented runs fail on any recorded lock/invariant violation,
        # even if the protocol run itself succeeded
        rc = max(rc, mon.report())
    return rc


if __name__ == "__main__":
    sys.exit(main())
