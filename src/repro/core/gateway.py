"""Gateway — the volunteer protocol over a real loopback socket, durably.

``python -m repro.core.gateway`` hosts a QueueServer + DataServer behind
``protocol.ServerEndpoint`` on a TCP socket, so a genuinely
**out-of-process** volunteer can join a training run — the end-to-end proof
that the sans-IO redesign works: the same ``VolunteerSession`` that drives
the Coordinator's JAX compute and the Simulator's virtual time here drives a
blocking socket client, with zero protocol code of its own.

One port serves TWO framing dialects, selected per connection by sniffing
the first byte (``GatewayServer._open_channel``):

- **native** — length-prefixed frames (u32 BE + canonically encoded
  message), the repo's original loopback framing;
- **WebSocket** — RFC 6455 (``core/wsframing``), each protocol message as
  one masked binary WS message: the framing a real browser volunteer — the
  paper's whole design point — can actually produce. ``WsClientTransport``
  is the client half; ``repro.core.browser`` is the thin browser-shaped
  volunteer on top of it.

Beyond the liveness proof, the gateway is a durable volunteer SERVICE:

- **Wall-clock leases** — the endpoint carries a ``WallClock`` (the
  ``LeaseClock`` implementation for real time), so the SERVER stamps every
  lease deadline, and a sweeper thread drives ``QueueServer.expire_all()``
  whenever a real deadline passes: a socket volunteer that is kill -9'd
  mid-task has its ticket requeued after ``--visibility-timeout`` seconds and
  the run finishes without it (MLitB's "failure is the common case" stance).
- **Snapshot/restore** — ``--snapshot-every K`` serializes the full
  QueueServer + DataServer live state (pending FIFOs, in-flight deadlines,
  banked signals, counters, model blobs) through the ``checkpoint.serialize``
  codecs to ``--snapshot-path`` after every K state-changing requests,
  atomically; ``--restore-from`` boots a fresh process from the latest
  snapshot. kill -9 the server, restart, and the run resumes: unacked work
  replays (at-least-once) and dead clients' leases expire via the sweeper.
  Deadlines are ``time.monotonic()`` values — boot-relative on Linux/macOS,
  so they stay meaningful across a server process restart.
- **Server-side applier** — for barrierless policies (``staleness:<s>``,
  ``local:<k>``) the endpoint hosts a ``ServerApplier``: volunteers push one
  ``SubmitUpdate`` (gradient/delta up) and the SERVER runs admission ->
  apply -> publish -> ack, so a thin client never fetches the admission-time
  model or pushes the updated blob (the DistML.js parameter-server shape;
  bytes-per-update measured in ``benchmarks/staleness.py``).

Pieces:

- ``GatewayServer`` — accept loop + per-connection reader threads; one global
  lock serializes endpoint dispatch (the in-process servers are
  single-threaded by design). A connection binds to a consumer id with
  ``Hello``; ``Wake``/``VersionReady`` notification frames are pushed down
  that consumer's connection.
- ``SocketTransport`` — the client half: ``call`` writes a request frame and
  reads until the reply frame arrives, stashing any notification frames that
  interleave; ``wait_notification`` blocks on the socket for the next push.
- ``run_volunteer`` — the engine-free driver: lease -> advance -> synthetic
  compute -> finish, blocking on notifications while ``Blocked``. Works over
  ANY transport; ``run_volunteer_resilient`` adds reconnect-on-crash so a
  volunteer survives a gateway restart.

Usage:
  python -m repro.core.gateway --serve --port 0 --port-file /tmp/gw.port
  python -m repro.core.gateway --serve --visibility-timeout 2 \\
      --snapshot-every 1 --snapshot-path /tmp/gw.snap
  python -m repro.core.gateway --volunteer --port 12345 --expect-final 4
  python -m repro.core.gateway --smoke
"""
from __future__ import annotations

import argparse
import contextlib
import logging
import os
import signal
import socket
import struct
import subprocess
import sys
import tempfile
import threading
import time
from collections import deque
from typing import Deque, Dict, Optional, Tuple

from repro.checkpoint import serialize
from repro.core import wsframing
from repro.core.aggregation import PolicyLike, make_policy
from repro.core.dataserver import DataServer
from repro.core.elastic import MODEL_KEY, GatewayRing, OpLog
from repro.core.initiator import enqueue_problem
from repro.core.applier import make_real_applier
from repro.core.mapreduce import TrainingProblem
from repro.core.protocol import (Ack, Blocked, Bye, DropConsumer, ExpireAll,
                                 FetchModel, Forward, ForwardNotify,
                                 ForwardReply, GcModels, Hello, KickQueue,
                                 LatestReq, LatestVersion, LeaseGrant,
                                 LocalWork, MapWork, ModelBlob, Nack, NoTask,
                                 NOTIFICATION_TYPES, Ok, PublishModel,
                                 ReduceWork, ServerApplier, ServerEndpoint,
                                 SubmitUpdate, TaskDone, UpdateCommitted,
                                 VersionReady, VolunteerSession, Wake,
                                 WatchVersion, decode_message, encode_message)
from repro.core.queue import (QueueServer, ShardedQueueServer, WallClock,
                              colocate_results)
from repro.core.simulator import SyntheticProblem
from repro.core.transport import InProcessTransport, Transport

_LEN = struct.Struct(">I")

log = logging.getLogger("repro.gateway")

# Frame cap shared with the WebSocket framer: a corrupt/hostile length
# prefix must close the connection with a protocol error, never drive a
# multi-GB allocation loop (same bound, both dialects).
MAX_FRAME = wsframing.MAX_FRAME

# A peer that goes silent MID-frame (header sent, body never arrives) is
# dead or hostile: after this many seconds with zero bytes of progress the
# connection is torn down — through ``endpoint.disconnect`` on the server,
# so the half-open client's waiters/subscriptions don't leak into the
# sweeper's lease bookkeeping. Silence BETWEEN frames is just idle.
FRAME_STALL_TIMEOUT = 10.0

# Bound on the dialect sniff + WS upgrade exchange for a fresh connection.
HANDSHAKE_TIMEOUT = 10.0

_RECV_CHUNK = 1 << 20                # never recv() more than 1 MiB at a time

# requests that cannot change durable state — skipped by the snapshot trigger
_READONLY = ("LatestReq", "DepthReq", "DrainedReq", "FetchModel", "Hello")

# the module's single wall-time authority: connect deadlines, smoke-leg
# timers, and compute pacing all read the same LeaseClock the server stamps
# leases with (REPRO-TIME)
_CLOCK = WallClock()


def _monitor():
    """The runtime lock/invariant monitor, iff ``ANALYSIS_INSTRUMENT=1``
    (see ``repro.analysis.runtime``); None — zero overhead — otherwise.
    The env var rides ``os.environ.copy()`` into every spawned server and
    volunteer subprocess, so one instrumented ``--smoke`` covers the whole
    topology."""
    if not os.environ.get("ANALYSIS_INSTRUMENT"):
        return None
    from repro.analysis.runtime import Analysis
    return Analysis.instrument()


def _make_lock(name: str, *, guard: bool = False):
    """Lock seam: a plain ``threading.Lock`` normally, a ``MonitoredLock``
    under instrumentation. ``guard=True`` marks a dispatch lock no blocking
    call may run under (LOCK-BLOCK)."""
    mon = _monitor()
    if mon is not None:
        return mon.make_lock(name, guard=guard)
    return threading.Lock()


@contextlib.contextmanager
def _sock_timeout(sock: socket.socket, timeout: Optional[float]):
    """Scoped ``settimeout`` that ALWAYS restores the previous value.

    Every timed section of the framing layer goes through this: restoring
    on the happy path only (the old ``settimeout``/``settimeout(None)``
    dance) leaks a stale timeout into the next frame read when an
    exception escapes mid-section, and a surprise ``socket.timeout`` on a
    later read desyncs the whole stream."""
    try:
        prev = sock.gettimeout()
    except OSError:
        prev = None
    try:
        sock.settimeout(timeout)
    except OSError:
        pass                    # socket already closed under us (die()/close):
        #                         the next recv/send raises and the caller
        #                         treats the connection as over
    try:
        yield sock
    finally:
        try:
            sock.settimeout(prev)
        except OSError:
            pass


def _send_frame(sock: socket.socket, msg) -> int:
    data = encode_message(msg)
    sock.sendall(_LEN.pack(len(data)) + data)
    return _LEN.size + len(data)


def _recv_exact(sock: socket.socket, n: int, *,
                mid_frame: bool = False) -> Optional[bytes]:
    """Read exactly ``n`` bytes. None = connection over (closed/reset, or a
    mid-frame stall). A ``socket.timeout`` with NOTHING consumed and
    ``mid_frame=False`` propagates — that is a clean idle timeout the
    caller asked for (heartbeat cue) and the stream is still aligned.

    Once any byte of a frame has been consumed a timeout may NOT surface:
    the caller would treat the consumed bytes as never read and desync on
    the next frame. Instead keep reading while bytes make progress, and
    give up (dead peer -> None) only after ``FRAME_STALL_TIMEOUT`` of
    total silence."""
    mon = _monitor()
    if mon is not None:
        mon.note_blocking("socket-recv")
    buf = b""
    stall_deadline = None
    while len(buf) < n:
        try:
            chunk = sock.recv(min(n - len(buf), _RECV_CHUNK))
        except socket.timeout:
            if not buf and not mid_frame:
                raise               # idle timeout: caller decides (heartbeat)
            if mid_frame:
                # the caller scoped FRAME_STALL_TIMEOUT onto the socket:
                # this timeout IS the stall window elapsing with no bytes
                return None
            if stall_deadline is None:
                stall_deadline = _CLOCK.now() + FRAME_STALL_TIMEOUT
            elif _CLOCK.now() >= stall_deadline:
                return None         # mid-frame stall: peer is dead
            continue                # mid-frame: the rest is in flight
        except OSError:
            return None
        if not chunk:
            return None
        buf += chunk
        stall_deadline = None       # progress resets the stall window
    return buf


def _recv_frame(sock: socket.socket):
    head = _recv_exact(sock, _LEN.size)
    if head is None:
        return None
    n = _LEN.unpack(head)[0]
    if n > MAX_FRAME:
        # corrupt or hostile length prefix — never allocate for it; the
        # caller sees None and closes the connection (server side through
        # endpoint.disconnect, client side as a ConnectionError)
        log.error("protocol error: %d-byte frame exceeds MAX_FRAME=%d "
                  "-- closing connection", n, MAX_FRAME)
        return None
    # the header is consumed: from here a timeout must not surface (the
    # stream would desync), so the body read runs under the stall window
    with _sock_timeout(sock, FRAME_STALL_TIMEOUT):
        body = _recv_exact(sock, n, mid_frame=True)
    return None if body is None else decode_message(body)


def _synthetic_apply(blob, result, version: int):
    """The gateway's synthetic applier: model blobs are version strings, so
    applying any admitted contribution to version v just names v+1 (the real
    engines hand ``ApplyWork`` to JAX; the gateway proves the protocol)."""
    return f"v{version + 1}"


# ---------------------------------------------------------------------------
# multi-gateway control plane: ownership facade + op-log replay
# ---------------------------------------------------------------------------

class _ClusterQueueView:
    """The endpoint's queue-server facade on a cluster gateway: local queues
    dispatch straight through; ticket acks/nacks/kicks for a queue owned by a
    PEER gateway are handed to ``relay`` instead (the model owner committing
    a SubmitUpdate acks a ticket whose queue lives elsewhere).

    The presence check matters: ``QueueServer.ack`` auto-declares unknown
    queues (``declare(qname).ack(tag)``), so blind delegation would grow
    phantom queues on the model owner — and again during op-log replay, where
    ``relay=None`` simply DROPS remote-queue ops (the owning gateway's own
    log carries them; at-least-once absorbs a relay lost to a crash)."""

    def __init__(self, local, relay=None):
        self._local = local
        self._relay = relay

    def __getattr__(self, name):
        return getattr(self._local, name)

    def ack(self, qname: str, tag: int) -> bool:
        if qname in self._local.queues:
            return self._local.ack(qname, tag)
        if self._relay is not None:
            self._relay(Ack(qname, tag))
        return True

    def nack(self, qname: str, tag: int, *, front: bool = True) -> bool:
        if qname in self._local.queues:
            return self._local.nack(qname, tag, front=front)
        if self._relay is not None:
            self._relay(Nack(qname, tag, front))
        return True

    def kick(self, qname: str) -> bool:
        if qname in self._local.queues:
            return self._local.kick(qname)
        if self._relay is not None:
            self._relay(KickQueue(qname))
        return False


class _ReplayClock:
    """LeaseClock for op-log replay: ``now`` is the recorded stamp of the op
    being replayed, so the reconstructed server re-lives its own history —
    lease deadlines land exactly where the live server put them."""

    def __init__(self):
        self.t = 0.0

    def now(self) -> float:
        return self.t


def replay_oplog(prefix: str, *, policy: PolicyLike = None,
                 visibility_timeout: float = float("inf")):
    """Reconstruct a gateway's durable state from its op log: restore the
    newest base, then re-dispatch every intact op record through a scratch
    endpoint whose clock replays each op's recorded timestamp. Returns
    ``(queue_server, data_server, meta)`` — ``meta`` carries the base's
    policy/n_updates cross-check fields (None when the log has no base yet).

    Ops that touch a queue owned by a DIFFERENT gateway (the model owner's
    relayed ticket acks) are dropped by the same ownership facade the live
    server dispatches through — the owning gateway's log carries them."""
    pol = make_policy(policy)
    base, ops = OpLog(prefix).load()
    rq = QueueServer(default_timeout=visibility_timeout)
    rd = DataServer()
    meta = None
    if base is not None:
        state = decode_message(base)
        # a fresh process replays the log: no live connections, so waiters
        # are dropped rather than carried (the snapshot-restore convention)
        rq.restore(state["qs"], waiters_from={})
        rd.restore(state["ds"])
        meta = {"policy": state.get("policy"),
                "n_updates": state.get("n_updates")}
    clk = _ReplayClock()
    applier = None if pol.barrier else ServerApplier(pol, _synthetic_apply)
    ep = ServerEndpoint(_ClusterQueueView(rq), rd, clock=clk, applier=applier)
    for rec in ops:
        r = decode_message(rec)
        clk.t = r["t"]
        ep.handle(r["m"])
    return rq, rd, meta


# ---------------------------------------------------------------------------
# per-connection channels: one port, two framing dialects
# ---------------------------------------------------------------------------

class _TcpChannel:
    """Native length-prefixed dialect (docs/protocol.md "Byte framing")."""

    dialect = "tcp"

    def __init__(self, conn: socket.socket):
        self.conn = conn

    def handshake(self) -> bool:
        return True                  # the native dialect has no preamble

    def send(self, msg) -> int:
        return _send_frame(self.conn, msg)

    def recv(self):
        """Next protocol message; None = connection over."""
        return _recv_frame(self.conn)

    def close(self) -> None:
        try:
            self.conn.close()
        except OSError:
            pass


class _WsChannel:
    """RFC 6455 dialect: the same protocol messages, each carried as one
    binary WebSocket message (``wsframing``). The server never masks; the
    client (a browser) must."""

    dialect = "ws"

    def __init__(self, conn: socket.socket):
        self.conn = conn
        self.framer = wsframing.server_framer()
        self._events: Deque = deque()

    def handshake(self) -> bool:
        """Run the HTTP upgrade under the handshake timeout; True on 101."""
        hs = wsframing.ServerHandshake()
        try:
            with _sock_timeout(self.conn, HANDSHAKE_TIMEOUT):
                while True:
                    data = self.conn.recv(4096)
                    if not data:
                        return False
                    response = hs.feed(data)
                    if response is not None:
                        break
                self.conn.sendall(response)
        except socket.timeout:
            log.error("ws handshake stalled after %.0fs -- closing",
                      HANDSHAKE_TIMEOUT)
            return False
        except wsframing.WsProtocolError as e:
            log.error("ws handshake rejected: %s", e)
            try:
                self.conn.sendall(wsframing.bad_handshake_response(str(e)))
            except OSError:
                pass
            return False
        except OSError:
            return False
        if hs.leftover:              # first frame bytes glued to the upgrade
            try:
                self._events.extend(self.framer.feed(hs.leftover))
            except wsframing.WsProtocolError as e:
                log.error("ws protocol error in handshake leftover: %s", e)
                return False
        return True

    def send(self, msg) -> int:
        frame = self.framer.send_message(encode_message(msg))
        self.conn.sendall(frame)
        return len(frame)

    def _read_chunk(self) -> Optional[bytes]:
        try:
            if self.framer.mid_frame:
                # same rule as the native dialect: a timeout may not
                # surface mid-frame — it IS the stall window elapsing
                with _sock_timeout(self.conn, FRAME_STALL_TIMEOUT):
                    try:
                        data = self.conn.recv(_RECV_CHUNK)
                    except socket.timeout:
                        return None
            else:
                data = self.conn.recv(_RECV_CHUNK)
        except OSError:
            return None
        return data or None

    def recv(self):
        """Next protocol message; answers pings and the close handshake
        transparently. None = connection over."""
        while True:
            while self._events:
                ev = self._events.popleft()
                if isinstance(ev, wsframing.Message):
                    return decode_message(ev.data)
                if isinstance(ev, wsframing.Ping):
                    try:
                        self.conn.sendall(self.framer.pong(ev.data))
                    except OSError:
                        return None
                elif isinstance(ev, wsframing.Closed):
                    # complete the close handshake (best effort), then the
                    # caller tears the connection down
                    code = ev.code if ev.code is not None \
                        else wsframing.CLOSE_NORMAL
                    try:
                        self.conn.sendall(self.framer.close(code))
                    except OSError:
                        pass
                    return None
                # Pong: keepalive reply, nothing to do
            data = self._read_chunk()
            if data is None:
                return None
            try:
                self._events.extend(self.framer.feed(data))
            except wsframing.WsProtocolError as e:
                log.error("ws protocol error from peer: %s -- closing", e)
                try:
                    self.conn.sendall(self.framer.close(e.code))
                except OSError:
                    pass
                return None

    def close(self) -> None:
        try:
            self.conn.close()
        except OSError:
            pass


# ---------------------------------------------------------------------------
# inter-gateway link
# ---------------------------------------------------------------------------

class _PeerLink:
    """Client half of one inter-gateway connection (origin side).

    One native-dialect socket serves three flows concurrently: ``forward``
    request/reply (correlated by ``Forward.seq`` — many may be in flight),
    ``forward_async`` fire-and-forget ticket relays, and owner->origin
    ``ForwardNotify`` pushes, which the reader thread hands back to the
    server for local delivery. The link registers on the peer as consumer
    ``gw:<origin gid>`` via Hello — which is exactly how the peer's endpoint
    addresses ForwardNotify frames at us."""

    _DEAD = object()                 # reply slot sentinel: link died waiting

    def __init__(self, server: "GatewayServer", gid: int, host: str,
                 port: int):
        self.server = server
        self.gid = gid
        self.closed = False
        self.sock = _connect_with_retry(host, port, 2.0)
        self._send_lock = _make_lock(f"gateway.peer{gid}._send_lock")
        self._pending_lock = _make_lock(f"gateway.peer{gid}._pending_lock")
        self._pending: Dict[int, list] = {}      # seq -> [Event, reply slot]
        self._seq = 0
        try:
            with self._send_lock:
                _send_frame(self.sock, Hello(f"gw:{server.gid}"))
        except OSError as e:
            self.close()
            raise ConnectionError(f"gateway {gid} hung up: {e}") from e
        threading.Thread(target=self._read_loop, daemon=True).start()

    def _read_loop(self) -> None:
        while True:
            msg = _recv_frame(self.sock)
            if msg is None:
                break
            if isinstance(msg, ForwardReply):
                with self._pending_lock:
                    ent = self._pending.pop(msg.seq, None)
                if ent is not None:
                    ent[1] = msg.inner
                    ent[0].set()
                # unknown seq: a forward_async reply or a timed-out waiter's
                # late answer — both dropped by design
            elif isinstance(msg, ForwardNotify):
                self.server._deliver_forwarded(msg)
            # anything else (the Hello's Ok) needs no action
        self.closed = True
        with self._pending_lock:
            pend, self._pending = self._pending, {}
        for ent in pend.values():
            ent[0].set()             # slot stays _DEAD -> ConnectionError

    def forward(self, inner, timeout: float = 30.0):
        """Send ``Forward(inner)`` and block for the correlated reply."""
        if self.closed:
            raise ConnectionError(f"gateway {self.gid} link is down")
        ent = [threading.Event(), _PeerLink._DEAD]
        with self._pending_lock:
            self._seq += 1
            seq = self._seq
            self._pending[seq] = ent
        try:
            with self._send_lock:
                _send_frame(self.sock,
                            Forward(seq, str(self.server.gid), inner))
        except OSError as e:
            with self._pending_lock:
                self._pending.pop(seq, None)
            raise ConnectionError(f"gateway {self.gid} hung up: {e}") from e
        if not ent[0].wait(timeout):
            with self._pending_lock:
                self._pending.pop(seq, None)
            raise ConnectionError(f"gateway {self.gid} forward timed out")
        if ent[1] is _PeerLink._DEAD:
            raise ConnectionError(f"gateway {self.gid} died mid-forward")
        return ent[1]

    def forward_async(self, inner) -> None:
        """Fire-and-forget Forward (ticket relays): the reply frame is
        dropped by the reader (unregistered seq). At-least-once semantics
        absorb a relay the peer never received — the lease re-expires."""
        if self.closed:
            raise ConnectionError(f"gateway {self.gid} link is down")
        with self._pending_lock:
            self._seq += 1
            seq = self._seq
        try:
            with self._send_lock:
                _send_frame(self.sock,
                            Forward(seq, str(self.server.gid), inner))
        except OSError as e:
            raise ConnectionError(f"gateway {self.gid} hung up: {e}") from e

    def close(self) -> None:
        self.closed = True
        try:
            self.sock.close()
        except OSError:
            pass


# ---------------------------------------------------------------------------
# server
# ---------------------------------------------------------------------------

class GatewayServer:
    """Loopback volunteer service: wall-clock leases + sweeper, optional
    periodic snapshots, optional server-side applier (barrierless policies).

    With ``gateways > 1`` the server is ONE member of a K-gateway control
    plane: a ``GatewayRing`` (consistent hashing over ``colocate_results``
    placement keys, ``MODEL_KEY`` for all DataServer state) decides which
    gateway owns each request; non-owned requests are forwarded over
    inter-gateway ``Forward`` frames. Durability is the per-gateway op log
    under ``cluster_dir`` (``--cluster-dir`` alone, with ``gateways == 1``,
    turns the op log on without the ring): every state-changing op is
    fsynced BEFORE its reply goes out, so a kill -9'd gateway's slice can be
    replayed by the deterministic adopter (smallest live gid) and the run
    completes at the reference final version.
    """

    def __init__(self, problem=None, *, host: str = "127.0.0.1", port: int = 0,
                 n_versions: Optional[int] = None, policy: PolicyLike = None,
                 n_shards: int = 1,
                 visibility_timeout: float = float("inf"),
                 sweep_interval: float = 0.05,
                 snapshot_path: Optional[str] = None, snapshot_every: int = 0,
                 restore_from: Optional[str] = None,
                 real_apply: bool = False,
                 gid: int = 0, gateways: int = 1,
                 cluster_dir: Optional[str] = None,
                 oplog_segment_ops: int = 256):
        self.policy = make_policy(policy)
        self.clock = WallClock()
        if problem is None:
            # even a restore needs the problem spec: the commit target is
            # policy arithmetic over (n_versions, n_mb), which the snapshot
            # records only as a cross-check, not as a reconstructible schedule
            raise ValueError("GatewayServer needs the problem spec (pass the "
                             "same --n-versions/--n-mb as the original serve "
                             "when restoring)")
        self.gid = int(gid)
        self.gateways = int(gateways)
        self.cluster_dir = cluster_dir
        self.ring = (GatewayRing(range(self.gateways))
                     if self.gateways > 1 else None)
        #: placement rule shared with ShardedQueueServer: map-results:vN
        #: colocates with the task queue, so ONE gateway owns a version's
        #: whole barrier (publish + drain never straddle processes)
        self._place = colocate_results
        if self.ring is not None:
            if cluster_dir is None:
                raise ValueError("gateways > 1 needs cluster_dir (op logs "
                                 "and peer port files live there)")
            if not 0 <= self.gid < self.gateways:
                raise ValueError(f"gid {gid} outside ring of {gateways}")
            if real_apply:
                raise ValueError("multi-gateway mode hosts the synthetic "
                                 "applier only (the real JAX applier is "
                                 "single-gateway)")
            if n_shards > 1:
                raise ValueError("multi-gateway mode subsumes --shards: the "
                                 "ring partitions queues across processes")
            if snapshot_path is not None:
                raise ValueError("multi-gateway durability is the op log "
                                 "(cluster_dir); snapshot_path is the "
                                 "single-gateway snapshot file")
        self.qs = (QueueServer(default_timeout=visibility_timeout)
                   if n_shards <= 1
                   else ShardedQueueServer(n_shards,
                                           default_timeout=visibility_timeout))
        self.ds = DataServer()
        nv = n_versions if n_versions is not None else problem.n_versions
        self.n_versions = nv
        # the run's commit target: the policy decides how many model versions
        # `nv` BSP-equivalent rounds must publish (sync: nv; async: nv * n_mb)
        self.n_updates = self.policy.n_updates(problem, nv)
        if real_apply and self.policy.barrier:
            raise ValueError("real_apply needs a barrierless policy "
                             "(staleness:<s> or local:<k>)")
        if restore_from is not None:
            self.restore(restore_from)
        else:
            # real applies need the real (params, opt_state) blob as v0;
            # the synthetic applier runs on version-string tokens
            enqueue_problem(problem, self.qs, self.ds, n_versions=nv,
                            policy=self.policy, store_real_model=real_apply)
        applier = None
        if not self.policy.barrier:
            if real_apply:
                applier = make_real_applier(problem, self.policy)
                if restore_from is not None:
                    # the snapshot's latest blob is the applier's new truth
                    latest = self.ds.latest_version
                    applier.backend.reseed(self.ds.get_model(latest), latest)
            else:
                applier = ServerApplier(self.policy, _synthetic_apply)
        self.applier = applier
        # on a cluster member the endpoint dispatches through the ownership
        # facade: remote-queue ticket ops relay to their owner instead of
        # auto-declaring phantom queues locally
        eqs = self.qs if self.ring is None \
            else _ClusterQueueView(self.qs, self._relay_ticket)
        self.endpoint = ServerEndpoint(eqs, self.ds, self._notify,
                                       clock=self.clock, applier=applier)
        self.sweep_interval = sweep_interval
        self.snapshot_path = snapshot_path
        self.snapshot_every = snapshot_every
        self.snapshots_written = 0
        self._ops_since_snap = 0
        # dispatch lock (guard: no blocking call may run under it) + a
        # separate writer lock so snapshot fsyncs serialize among themselves
        # without ever stalling dispatch
        self._lock = _make_lock("gateway._lock", guard=True)
        self._snap_lock = _make_lock("gateway._snap_lock")
        # submit combining queue (leaf lock; order: _lock -> _submit_lock).
        # SubmitUpdates enqueue here; whichever connection thread wins the
        # dispatch lock drains them ALL as one endpoint.submit_batch — one
        # jitted dispatch on a real applier instead of one per update.
        self._submit_lock = _make_lock("gateway._submit_lock")
        self._submit_pending: list = []
        self._snap_seq = 0                       # encode order (under _lock)
        self._snap_written = 0                   # last seq on disk (_snap_lock)
        self._conns: Dict[str, object] = {}      # consumer -> channel
        self.done = threading.Event()
        self._closed = threading.Event()
        # -- cluster state --------------------------------------------------
        self._oplog: Optional[OpLog] = None
        self._op_buffer: list = []               # ("op"|"base", bytes) FIFO
        self._ops_since_base = 0
        self._fwd_outbox: list = []              # ticket relays awaiting send
        self._peers: Dict[int, _PeerLink] = {}
        self._peers_lock = _make_lock("gateway._peers_lock")
        # failover is serialized and may block (replay reads the dead
        # gateway's log from disk); order: _failover_lock -> _lock
        self._failover_lock = _make_lock("gateway._failover_lock")
        self._seen_version = 0                   # cluster-wide version echo
        if self.ring is not None:
            # this gateway serves only its ring slice: every queue the
            # shared enqueue created for a peer's slice is dropped here
            for name in list(self.qs.queues):
                if self.ring.owner_of(self._place(name)) != self.gid:
                    self.qs.detach(name)
        if cluster_dir is not None:
            os.makedirs(cluster_dir, exist_ok=True)
            self._oplog = OpLog(
                os.path.join(cluster_dir, f"gw{self.gid}.oplog"),
                segment_ops=oplog_segment_ops)
            self.endpoint.op_sink = self._log_op
            # boot base: the new epoch captures the (pruned, possibly
            # restored) starting state, so replaying a freshly-booted
            # gateway is well-defined and older epochs are subsumed
            self._oplog.write_base(self._encode_cluster_base())
        if self.ds.latest_version >= self.n_updates:
            self.done.set()                      # restored a finished run
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(16)
        self.port = self._sock.getsockname()[1]
        if cluster_dir is not None:
            # peers (and in-process clusters) discover us via the port file
            pf = os.path.join(cluster_dir, f"gw{self.gid}.port")
            tmp = pf + ".tmp"
            with open(tmp, "w") as f:
                f.write(str(self.port))
            os.replace(tmp, pf)                  # atomic: readers never see ""

    # -- durability ------------------------------------------------------------
    def _encode_snapshot(self) -> Tuple[int, bytes]:
        """Serialize the full queue+data state (CPU only — caller holds the
        dispatch lock). The blob rides the PROTOCOL wire codec
        (``encode_message``), not raw ``serialize.dumps``, because queue
        bodies are wire dataclasses (``MapTask`` et al.) that serialize by
        registered name. Returns (seq, bytes): ``seq`` orders this state
        against other encodes so a slow writer can never clobber a newer
        snapshot with an older one."""
        assert self.snapshot_path is not None
        state = {"gateway": {"qs": self.qs.snapshot(),
                             "ds": self.ds.snapshot(),
                             "n_updates": self.n_updates,
                             "policy": self.policy.spec}}
        self._snap_seq += 1
        return self._snap_seq, encode_message(state,
                                              codec=serialize.DEFAULT_CODEC)

    def _write_snapshot(self, seq: int, data: bytes) -> int:
        """Atomic-write an encoded snapshot (tmp + fsync + rename) — called
        with the dispatch lock RELEASED: the fsync is the blocking call that
        must never stall dispatch (LOCK-BLOCK invariant). Returns bytes
        written, 0 if a newer snapshot already reached disk."""
        with self._snap_lock:
            if seq <= self._snap_written:
                return 0
            mon = _monitor()
            if mon is not None:
                mon.note_blocking("snapshot-fsync")
            n = serialize.atomic_write(self.snapshot_path, data)
            self._snap_written = seq
            self.snapshots_written += 1
            return n

    def snapshot(self) -> int:
        """Write the full queue+data state atomically; returns bytes
        written. Takes the dispatch lock itself — call it unlocked."""
        with self._lock:
            seq, data = self._encode_snapshot()
        return self._write_snapshot(seq, data)

    def restore(self, path: str) -> None:
        """Boot from durable state: an op-log prefix (base + replayed ops)
        when ``path`` names one, else a legacy full-snapshot file."""
        if OpLog.exists(path):
            rq, rd, meta = replay_oplog(
                path, policy=self.policy,
                visibility_timeout=self.qs.default_timeout)
            if meta is not None:
                if meta["policy"] != self.policy.spec:
                    raise ValueError(
                        f"op log was served under policy={meta['policy']!r}, "
                        f"this server is {self.policy.spec!r} — pass the "
                        f"original --policy")
                if meta["n_updates"] != self.n_updates:
                    raise ValueError(
                        f"op log's commit target is {meta['n_updates']}, "
                        f"this server computes {self.n_updates} — pass the "
                        f"original --n-versions/--n-mb")
            if isinstance(self.qs, ShardedQueueServer):
                # op logs are written by unsharded cluster members; restore
                # to the matching kind (the legacy branch's coercion move)
                self.qs = QueueServer(default_timeout=self.qs.default_timeout)
            self.qs.restore(rq.snapshot(), waiters_from={})
            self.ds.restore(rd.snapshot())
            return
        state = decode_message(serialize.read_bytes(path))["gateway"]
        # the snapshot records the run's semantics as a cross-check: booting
        # it under different CLI flags must fail HERE, not as a confusing
        # protocol cascade once volunteers reconnect
        if state["policy"] != self.policy.spec:
            raise ValueError(f"snapshot was served under policy="
                             f"{state['policy']!r}, this server is "
                             f"{self.policy.spec!r} — pass the original "
                             f"--policy")
        if state["n_updates"] != self.n_updates:
            raise ValueError(f"snapshot's commit target is "
                             f"{state['n_updates']}, this server computes "
                             f"{self.n_updates} — pass the original "
                             f"--n-versions/--n-mb")
        if state["qs"].get("kind") == "ShardedQueueServer" and \
                not isinstance(self.qs, ShardedQueueServer):
            self.qs = ShardedQueueServer(1, default_timeout=float("inf"))
        elif state["qs"].get("kind") == "QueueServer" and \
                isinstance(self.qs, ShardedQueueServer):
            self.qs = QueueServer()
        self.qs.restore(state["qs"])
        self.ds.restore(state["ds"])

    def _maybe_snapshot(self, msg) -> Optional[Tuple[int, bytes]]:
        """Called under the dispatch lock. When a snapshot is due, ENCODES
        the state (pure CPU) and returns the pending ``(seq, bytes)`` for
        the caller to write after releasing the lock; None otherwise."""
        if self.snapshot_every <= 0 or self.snapshot_path is None:
            return None
        if type(msg).__name__ in _READONLY:
            return None
        self._ops_since_snap += 1
        if self._ops_since_snap < self.snapshot_every:
            return None
        self._ops_since_snap = 0
        return self._encode_snapshot()

    # -- op log (cluster durability) -------------------------------------------
    def _encode_cluster_base(self) -> bytes:
        """Full durable state as an op-log base record (the protocol wire
        codec, because queue bodies are wire dataclasses)."""
        return encode_message({"qs": self.qs.snapshot(),
                               "ds": self.ds.snapshot(),
                               "policy": self.policy.spec,
                               "n_updates": self.n_updates},
                              codec=serialize.DEFAULT_CODEC)

    def _log_op(self, m) -> None:
        """Endpoint op sink — runs under the dispatch lock (pure CPU): the
        op is encoded with its authority timestamp and buffered; the
        dispatching thread flushes the buffer to disk BEFORE sending the
        reply, so every acknowledged op is recoverable by replay. Every
        ``snapshot_every`` ops a fresh base is queued behind the ops that
        precede it, rolling the log's epoch at the flush."""
        self._op_buffer.append(
            ("op", encode_message({"t": self.clock.now(), "m": m})))
        if self.snapshot_every > 0:
            self._ops_since_base += 1
            if self._ops_since_base >= self.snapshot_every:
                self._ops_since_base = 0
                self._op_buffer.append(("base", self._encode_cluster_base()))

    def _flush_oplog(self) -> None:
        """Drain the op buffer to disk in order — called with the dispatch
        lock RELEASED (fsync is blocking; LOCK-BLOCK). ``_snap_lock``
        serializes writers so two drains can never interleave their
        batches; the dispatch lock is retaken only for the buffer swap."""
        if self._oplog is None or self._closed.is_set():
            return
        with self._snap_lock:
            with self._lock:
                batch, self._op_buffer = self._op_buffer, []
            if not batch:
                return
            mon = _monitor()
            if mon is not None:
                mon.note_blocking("oplog-fsync")
            for kind, data in batch:
                if kind == "base":
                    self._oplog.write_base(data)
                else:
                    self._oplog.append(data)

    @property
    def observed_version(self) -> int:
        """Latest model version this gateway can vouch for: its own
        DataServer (when it is the model owner) or versions echoed in
        forwarded replies and notifications (when a peer is)."""
        return max(self.ds.latest_version, self._seen_version)

    def _observe_version(self, msg) -> None:
        """Track the cluster-wide latest version flowing through this
        gateway — the model owner may be a peer, so the local DataServer
        can be arbitrarily stale. Reaching the commit target sets ``done``
        exactly like a local commit would."""
        v = -1
        if isinstance(msg, (LatestVersion, UpdateCommitted, VersionReady)):
            v = msg.version
        elif isinstance(msg, ModelBlob) and msg.present:
            v = msg.version
        elif isinstance(msg, LeaseGrant):
            v = msg.latest
        if v > self._seen_version:
            self._seen_version = v
        if self.observed_version >= self.n_updates:
            self.done.set()

    # -- lease sweeper ---------------------------------------------------------
    def _sweep_loop(self) -> None:
        """Visibility-timeout enforcement on REAL deadlines: wake when the
        earliest lease deadline passes and requeue everything expired (the
        requeue notifications push Wake frames to waiting volunteers). This
        is the clock owner the in-process engines emulate with virtual time."""
        while not self._closed.is_set():
            pending = None
            with self._lock:
                now = self.clock.now()
                if self._oplog is not None:
                    # expiry through the endpoint so the op log records it:
                    # replay must expire exactly what the live server did
                    # (ExpireAll.now is applied verbatim). Dispatch only
                    # when a real deadline has passed, so the log never
                    # fills with no-op sweeps at the polling cadence.
                    dl0 = self.qs.next_deadline()
                    if dl0 is not None and dl0 <= now:
                        self.endpoint.handle(ExpireAll(now))
                else:
                    expired = self.qs.expire_all(now)
                    if expired and self.snapshot_every > 0 \
                            and self.snapshot_path is not None:
                        # expiry is a durable state change; encode under the
                        # lock, fsync after releasing it
                        pending = self._encode_snapshot()
                dl = self.qs.next_deadline()
            if pending is not None:
                self._write_snapshot(*pending)
            self._flush_oplog()
            self._drain_outbox()
            wait = self.sweep_interval if dl is None else \
                max(0.0, min(dl - self.clock.now(), self.sweep_interval))
            self._closed.wait(wait if wait > 0 else 0.001)

    # -- wire ------------------------------------------------------------------
    def _notify(self, consumer: str, msg) -> None:
        # called inside endpoint.handle, under self._lock. The send is
        # bounded: a client that stops draining its socket would otherwise
        # block here with the global lock held and stall the whole server —
        # treat a wedged buffer like a disconnect and drop the registration.
        channel = self._conns.get(consumer)
        delivered = False
        if channel is not None:
            try:
                with _sock_timeout(channel.conn, 10.0):
                    channel.send(msg)
                delivered = True
            except OSError:
                self._conns.pop(consumer, None)
        if not delivered and isinstance(msg, Wake):
            # a queue wake is one-shot: consumed by an unreachable consumer,
            # the event would be lost to everyone. Hand it to the next waiter
            # (or bank it), like the engines' dead-volunteer kick path —
            # through the endpoint, the same move a live volunteer's
            # KickQueue request makes (REPRO-LAYER).
            self.endpoint.handle(KickQueue(msg.queue))

    def _open_channel(self, conn: socket.socket):
        """Sniff the dialect from the first byte and run any handshake.

        A WebSocket connection opens with an HTTP ``GET `` (0x47); a
        native-dialect connection opens with a u32 BE length < MAX_FRAME,
        whose first byte is <= 0x01 — one peeked byte disambiguates.
        Returns a ready channel, or None (connection already closed)."""
        try:
            with _sock_timeout(conn, HANDSHAKE_TIMEOUT):
                first = conn.recv(1, socket.MSG_PEEK)
        except (socket.timeout, OSError):
            first = b""
        if not first:
            try:
                conn.close()
            except OSError:
                pass
            return None
        channel = _WsChannel(conn) if wsframing.is_ws_preamble(first) \
            else _TcpChannel(conn)
        if not channel.handshake():
            channel.close()
            return None
        return channel

    # -- cluster routing + failover --------------------------------------------
    def _route_key(self, msg) -> Optional[str]:
        """Ring routing key for one request; None = dispatch locally (Hello
        binds the connection; Bye/DropConsumer broadcast; ExpireAll is
        server-internal)."""
        if isinstance(msg, (FetchModel, PublishModel, GcModels, WatchVersion,
                            LatestReq, SubmitUpdate)):
            return MODEL_KEY
        q = getattr(msg, "queue", None)
        if q is not None:
            return self._place(q)
        return None

    def _owner_for(self, key: str, timeout: float = 30.0) -> int:
        """Resolve the current owner of ``key``, waiting out a failover
        window (owner dead, adoption not yet recorded)."""
        deadline = _CLOCK.now() + timeout
        while True:
            try:
                return self.ring.owner_of(key)
            except LookupError:
                if _CLOCK.now() >= deadline:
                    raise
                time.sleep(0.02)

    def _await_ownership(self, key: Optional[str],
                         timeout: float = 30.0) -> None:
        """Hold a forwarded request until this gateway owns ``key``'s slice.
        The window where this actually waits is failover: peers route to
        the deterministic adopter BEFORE it finishes replaying the dead
        gateway's op log; the request proceeds the moment the merge
        commits the adoption."""
        if key is None or self.ring is None:
            return
        deadline = _CLOCK.now() + timeout
        while not self._closed.is_set():
            try:
                if self.ring.owner_of(key) == self.gid:
                    return
            except LookupError:
                pass                 # failover window: nobody owns it yet
            if _CLOCK.now() >= deadline:
                raise RuntimeError(
                    f"gateway {self.gid}: forwarded request for slice "
                    f"{key!r} but ownership never arrived")
            time.sleep(0.02)

    def _peer_port(self, g: int, wait: float = 20.0) -> Optional[int]:
        pf = os.path.join(self.cluster_dir, f"gw{g}.port")
        deadline = _CLOCK.now() + wait
        while True:
            try:
                with open(pf) as f:
                    return int(f.read())
            except (OSError, ValueError):
                # missing at boot = not up YET (no liveness verdict); the
                # caller decides how long a missing file is tolerable
                if _CLOCK.now() >= deadline:
                    return None
                time.sleep(0.05)

    def _peer(self, g: int) -> _PeerLink:
        """The (cached) link to gateway ``g``; reconnects a dead link once —
        a closed socket may just be a restarted peer."""
        with self._peers_lock:
            link = self._peers.get(g)
        if link is not None and not link.closed:
            return link
        port = self._peer_port(g)
        if port is None:
            raise ConnectionError(f"gateway {g} never published a port file")
        fresh = _PeerLink(self, g, "127.0.0.1", port)
        with self._peers_lock:
            cur = self._peers.get(g)
            if cur is not None and not cur.closed and cur is not link:
                fresh.close()        # lost the reconnect race; use theirs
                return cur
            self._peers[g] = fresh
        return fresh

    def _peer_died(self, g: int) -> None:
        """A send/connect to ``g`` failed: drop its link and run failover."""
        with self._peers_lock:
            link = self._peers.get(g)
            if link is not None and link.closed:
                self._peers.pop(g, None)
        self._on_peer_death(g)

    def _on_peer_death(self, dead: int) -> None:
        """Failover: mark ``dead`` dead on the ring; the deterministic
        adopter (smallest live gid) replays the dead gateway's op log and
        merges its slice, every other survivor just records the redirect.
        Serialized and idempotent — reentry for an already-dead gid is a
        no-op, so racing detectors (pinger, forward errors) are safe."""
        with self._failover_lock:
            if self.ring is None or dead == self.gid or \
                    dead not in self.ring.live():
                return
            try:
                dead_owned_model = self.ring.owner_of(MODEL_KEY) == dead
            except LookupError:
                dead_owned_model = False
            self.ring.kill(dead)
            adopter = self.ring.default_adopter(dead)
            if adopter != self.gid:
                # optimistic redirect: the adopter gates forwarded requests
                # on its own merge, so routing ahead of it is safe
                self.ring.adopt(dead, adopter)
                log.warning("gateway %d: peer %d died; slice redirects to "
                            "adopter %d", self.gid, dead, adopter)
                return
            prefix = os.path.join(self.cluster_dir, f"gw{dead}.oplog")
            rq, rd, _ = replay_oplog(
                prefix, policy=self.policy,
                visibility_timeout=self.qs.default_timeout)
            n_queues = len(rq.queues)
            with self._lock:
                for name in list(rq.queues):
                    moved = rq.detach(name)
                    if name in self.qs.queues:
                        # both sides only transiently (a relay declared it
                        # here): keep OUR live waiters, their durable body
                        local = self.qs.detach(name)
                        moved.adopt_waiters(local)
                    self.qs.attach(moved)
                if dead_owned_model:
                    # in-place restore: the endpoint aliases self.ds
                    self.ds.restore(rd.snapshot())
                self.ring.adopt(dead, self.gid)
                # the merged state becomes a fresh base: OUR log now carries
                # the adopted slice, so a SECOND failover replays from here
                self._op_buffer.append(
                    ("base", self._encode_cluster_base()))
                if self.observed_version >= self.n_updates:
                    self.done.set()
            self._flush_oplog()
            log.warning("gateway %d: adopted slice of dead gateway %d "
                        "(%d queues, model_owner=%s)", self.gid, dead,
                        n_queues, dead_owned_model)

    def _forward_retry(self, key: str, msg, timeout: float = 30.0):
        """Dispatch ``msg`` at the current owner of ``key``, retrying across
        a failover (the owner may die mid-forward, or become US). Retried
        ops may double-apply — at-least-once, absorbed the same way
        re-leased tickets are."""
        deadline = _CLOCK.now() + timeout
        while True:
            owner = self._owner_for(key)
            if owner == self.gid:
                with self._lock:
                    reply = self.endpoint.handle(msg)
                    if self.ds.latest_version >= self.n_updates:
                        self.done.set()
                self._flush_oplog()
                self._drain_outbox()
                return reply
            try:
                return self._peer(owner).forward(msg)
            except ConnectionError:
                self._peer_died(owner)
                if _CLOCK.now() >= deadline:
                    raise
                time.sleep(0.02)

    def _route_cluster(self, msg, channel) -> bool:
        """Cluster routing for one client request. True = fully handled
        (forwarded or broadcast, reply sent); False = this gateway owns the
        slice, fall through to local dispatch."""
        if isinstance(msg, (Bye, DropConsumer)):
            # consumer-scoped cleanup must reach EVERY gateway: the
            # consumer's leases and waiters may span several owners' slices
            with self._lock:
                reply = self.endpoint.handle(msg)
            total = reply.value if isinstance(reply.value, int) else 0
            for g in self.ring.live():
                if g == self.gid:
                    continue
                try:
                    r = self._peer(g).forward(msg)
                    if isinstance(r, Ok) and isinstance(r.value, int):
                        total += r.value
                except ConnectionError:
                    self._peer_died(g)
            self._flush_oplog()
            with self._lock:
                channel.send(Ok(total))
            return True
        key = self._route_key(msg)
        if key is None or self._owner_for(key) == self.gid:
            return False
        reply = self._forward_retry(key, msg)
        self._observe_version(reply)
        with self._lock:
            channel.send(reply)
        return True

    def _relay_ticket(self, msg) -> None:
        """Ownership-facade hook: an ack/nack/kick for a PEER's queue raised
        mid-dispatch (the model owner committing a SubmitUpdate acks a
        ticket whose queue lives elsewhere). Runs UNDER the dispatch lock,
        so it only enqueues; the dispatching thread relays after release
        (at-least-once absorbs a relay lost to a crash)."""
        self._fwd_outbox.append(msg)

    def _drain_outbox(self) -> None:
        """Send buffered ticket relays to their owners — called with the
        dispatch lock released. Undeliverable relays requeue for the next
        drain (sweeper cadence bounds the delay)."""
        if self.ring is None or not self._fwd_outbox:
            return
        with self._lock:
            batch, self._fwd_outbox = self._fwd_outbox, []
        requeue = []
        for m in batch:
            try:
                owner = self.ring.owner_of(self._place(m.queue))
            except LookupError:
                requeue.append(m)    # failover window: retry next drain
                continue
            if owner == self.gid:    # adopted mid-flight: now local
                with self._lock:
                    self.endpoint.handle(m)
                continue
            try:
                self._peer(owner).forward_async(m)
            except ConnectionError:
                self._peer_died(owner)
                requeue.append(m)
            except RuntimeError:
                requeue.append(m)    # shutting down; next drain decides
        if requeue:
            with self._lock:
                self._fwd_outbox.extend(requeue)

    def _deliver_forwarded(self, fn: ForwardNotify) -> None:
        """A peer pushed a notification owed to one of OUR consumers
        (their endpoint fired a watch/wake registered via Forward)."""
        self._observe_version(fn.inner)
        with self._lock:
            self._notify(fn.consumer, fn.inner)

    def _failover_loop(self) -> None:
        """Peer liveness + end-of-run observation, at sweeper-ish cadence.
        Each round pings every live peer over its link (a forwarded Hello
        is the cheapest request that proves the peer's dispatch loop is
        alive); a failure on a peer that HAS published its port file means
        the process died -> failover. The model owner's latest version is
        probed too, so a gateway serving only forwarded traffic still
        observes the run finishing."""
        while not self._closed.is_set():
            for g in self.ring.live():
                if g == self.gid or self._closed.is_set():
                    continue
                if self._peer_port(g, wait=0.0) is None:
                    continue         # not up yet: no link, no verdict
                try:
                    self._peer(g).forward(Hello(f"gw:{self.gid}"),
                                          timeout=5.0)
                except ConnectionError:
                    self._peer_died(g)
            try:
                owner = self.ring.owner_of(MODEL_KEY)
                if owner == self.gid:
                    self._observe_version(
                        LatestVersion(self.ds.latest_version))
                else:
                    self._observe_version(
                        self._peer(owner).forward(LatestReq(), timeout=5.0))
            except (LookupError, ConnectionError):
                pass                 # failover window / dead link: next round
            self._drain_outbox()
            self._closed.wait(0.3)

    def die(self) -> None:
        """In-process stand-in for kill -9 (benchmarks/tests): stop serving
        and DROP the buffered-but-unflushed ops — exactly the state the
        real signal loses. The on-disk op log is left as the crash left
        it."""
        self._closed.set()
        with self._lock:
            self._op_buffer = []
            conns, self._conns = dict(self._conns), {}
        try:
            self._sock.close()
        except OSError:
            pass
        with self._peers_lock:
            links, self._peers = dict(self._peers), {}
        for link in links.values():
            link.close()
        for ch in conns.values():
            ch.close()

    def _send_submit_reply(self, entry, reply) -> None:
        """Send one drained submit reply (under the dispatch lock),
        wrapping it as ``ForwardReply`` when the submit arrived forwarded
        from a peer gateway."""
        _, channel, _, wrap = entry
        out = reply if wrap is None else ForwardReply(wrap, reply)
        try:
            channel.send(out)
        except OSError:
            # peer died mid-drain: its update is already committed/nacked
            # server-side; drop the dead conn registration (the _notify
            # convention) and let ITS thread's recv observe the close
            for c, ch in list(self._conns.items()):
                if ch is channel:
                    self._conns.pop(c, None)

    def _submit_drain(self, msg, channel,
                      wrap: Optional[int] = None) -> None:
        """Combining-lock commit: enqueue this ``SubmitUpdate``, then whoever
        wins the dispatch lock drains EVERY pending submit through one
        ``endpoint.submit_batch`` call (one jitted dispatch on a real
        applier) and sends every drained reply — under the lock, like
        ordinary dispatch, so reply frames never interleave with pushed
        notifications. A thread whose entry was drained by another finds its
        event already set and just returns to ``recv``. With the op log on,
        replies go out only AFTER the drained ops are fsynced (durability
        before acknowledgement); ``wrap`` carries the ``Forward.seq`` of a
        submit that arrived forwarded from a peer gateway."""
        entry = (msg, channel, threading.Event(), wrap)
        with self._submit_lock:
            self._submit_pending.append(entry)
        pendings: list = []
        batch: list = []
        sends: list = []
        try:
            with self._lock:
                with self._submit_lock:
                    batch, self._submit_pending = self._submit_pending, []
                if batch:
                    replies = self.endpoint.submit_batch(
                        [e[0] for e in batch])
                    if self._oplog is not None:
                        sends = list(zip(batch, replies))
                    else:
                        for e, reply in zip(batch, replies):
                            self._send_submit_reply(e, reply)
                    for e in batch:
                        p = self._maybe_snapshot(e[0])
                        if p is not None:
                            pendings.append(p)
                    if self.ds.latest_version >= self.n_updates:
                        self.done.set()
            if sends:
                self._flush_oplog()
                with self._lock:
                    for e, reply in sends:
                        self._send_submit_reply(e, reply)
        finally:
            for e in batch:
                e[2].set()
        for p in pendings:
            self._write_snapshot(*p)
        self._drain_outbox()
        entry[2].wait()

    def _serve_conn(self, conn: socket.socket) -> None:
        channel = self._open_channel(conn)
        if channel is None:
            return
        consumer = None
        try:
            while True:
                msg = channel.recv()
                if msg is None:
                    break
                if isinstance(msg, Forward) and \
                        isinstance(msg.inner, SubmitUpdate) and \
                        self.applier is not None:
                    # a peer forwarded a submit to us (the model owner):
                    # same combining drain, reply wrapped by its seq
                    self._await_ownership(MODEL_KEY)
                    self._submit_drain(msg.inner, channel, wrap=msg.seq)
                    continue
                if isinstance(msg, SubmitUpdate) and \
                        self.applier is not None:
                    if self.ring is not None and \
                            self._owner_for(MODEL_KEY) != self.gid:
                        reply = self._forward_retry(MODEL_KEY, msg)
                        self._observe_version(reply)
                        with self._lock:
                            channel.send(reply)
                        continue
                    self._submit_drain(msg, channel)
                    continue
                if self.ring is not None:
                    if isinstance(msg, Forward):
                        # dispatch the envelope locally: endpoint.handle
                        # unwraps, records remote consumers, wraps the reply
                        self._await_ownership(self._route_key(msg.inner))
                    elif self._route_cluster(msg, channel):
                        continue
                with self._lock:
                    if isinstance(msg, Hello):
                        consumer = msg.consumer
                        self._conns[consumer] = channel
                    reply = self.endpoint.handle(msg)
                    if self._oplog is None:
                        channel.send(reply)
                    pending = self._maybe_snapshot(msg)
                    if self.ds.latest_version >= self.n_updates:
                        self.done.set()
                if self._oplog is not None:
                    # durability before acknowledgement: the op reaches
                    # disk before the client ever sees its reply
                    self._flush_oplog()
                    with self._lock:
                        channel.send(reply)
                if pending is not None:
                    self._write_snapshot(*pending)
                self._drain_outbox()
        finally:
            with self._lock:
                if consumer is not None \
                        and self._conns.get(consumer) is channel:
                    del self._conns[consumer]
                    # EVERY teardown path lands here — clean Bye, kill -9,
                    # a corrupt length prefix, or a mid-frame stall — and a
                    # disconnected consumer can never serve a wake: drop
                    # its queue waiters so they stop consuming one-shot
                    # events other volunteers need. Its LEASES stay — that
                    # recovery is deliberately the sweeper's (it may
                    # reconnect and heartbeat; only real death expires them).
                    self.endpoint.disconnect(consumer)
            channel.close()

    def serve_forever(self) -> None:
        while True:
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()

    def start(self) -> threading.Thread:
        threading.Thread(target=self._sweep_loop, daemon=True).start()
        if self.ring is not None:
            threading.Thread(target=self._failover_loop, daemon=True).start()
        t = threading.Thread(target=self.serve_forever, daemon=True)
        t.start()
        return t

    def close(self) -> None:
        self._closed.set()
        self._sock.close()
        with self._peers_lock:
            links, self._peers = dict(self._peers), {}
        for link in links.values():
            link.close()


# ---------------------------------------------------------------------------
# client transport
# ---------------------------------------------------------------------------

def _connect_with_retry(host: str, port: int,
                        connect_timeout: float) -> socket.socket:
    deadline = _CLOCK.now() + connect_timeout
    last_err = None
    while True:                      # the server may still be binding
        try:
            sock = socket.create_connection((host, port), timeout=30)
            # the connect timeout must not linger: a volunteer may sit in
            # wait_notification far longer than any connect should take
            sock.settimeout(None)
            return sock
        except OSError as e:
            last_err = e
            if _CLOCK.now() >= deadline:
                raise ConnectionError(
                    f"gateway at {host}:{port} unreachable: {last_err}")
            time.sleep(0.05)


class _FramedClientTransport(Transport):
    """Blocking request/reply over a gateway socket; pushed notification
    frames are stashed (or blocked for) rather than delivered by callback.
    Subclasses supply the framing dialect via ``_setup``/``_send_msg``/
    ``_recv_msg``; everything above the frame boundary — the reply loop,
    the notification inbox, the request histogram — is dialect-blind.

    ``_recv_msg`` contract: return the next protocol message; return None
    when the connection is over (close, reset, torn frame, protocol
    error); raise ``socket.timeout`` ONLY for a clean idle timeout with
    the stream still aligned on a frame boundary."""

    timed_waits = True               # wait_notification accepts a timeout
    dialect = "?"

    def __init__(self, host: str, port: int, consumer: str,
                 connect_timeout: float = 10.0):
        self.sock = _connect_with_retry(host, port, connect_timeout)
        self.inbox: Deque = deque()
        self.consumer = consumer
        self.bytes_moved = 0
        self.sent: Dict[str, int] = {}   # request-type histogram (observable:
        #                                  the applier path sends no PublishModel)
        try:
            self._setup()
            self.call(Hello(consumer))
        except (OSError, ConnectionError):
            self.sock.close()
            raise

    def _setup(self) -> None:
        """Dialect handshake, run once before the Hello."""

    def _send_msg(self, msg) -> int:
        raise NotImplementedError

    def _recv_msg(self):
        raise NotImplementedError

    def set_deliver(self, deliver) -> None:
        """A socket transport is a BLOCKING client port: notifications are
        consumed via ``wait_notification``/``inbox``, never pushed through a
        callback — so the virtual-clock engines (which need synchronous
        delivery) cannot run over it. Fail loudly instead of deadlocking."""
        raise RuntimeError(
            f"{type(self).__name__} has no callback delivery; drive it "
            "with a blocking client loop (gateway.run_volunteer), not an "
            "engine")

    def call(self, msg):
        name = type(msg).__name__
        self.sent[name] = self.sent.get(name, 0) + 1
        self.bytes_moved += self._send_msg(msg)
        while True:
            reply = self._recv_msg()
            if reply is None:
                raise ConnectionError("gateway closed the connection")
            if isinstance(reply, NOTIFICATION_TYPES):
                self.inbox.append(reply)
                continue
            return reply

    def wait_notification(self, timeout: Optional[float] = None):
        """Block until the server pushes a Wake/VersionReady frame. With a
        ``timeout``, return None when nothing arrives in time — the caller's
        cue to heartbeat its lease and re-check state."""
        if self.inbox:
            return self.inbox.popleft()
        try:
            if timeout is not None:
                with _sock_timeout(self.sock, timeout):
                    msg = self._recv_msg()
            else:
                msg = self._recv_msg()
        except socket.timeout:
            return None
        if msg is None:
            raise ConnectionError("gateway closed while waiting")
        if not isinstance(msg, NOTIFICATION_TYPES):
            raise RuntimeError(f"unexpected frame while idle: {msg}")
        return msg

    def close(self) -> None:
        self.sock.close()


class SocketTransport(_FramedClientTransport):
    """The native length-prefixed dialect (docs/protocol.md)."""

    dialect = "tcp"

    def _send_msg(self, msg) -> int:
        return _send_frame(self.sock, msg)

    def _recv_msg(self):
        return _recv_frame(self.sock)


class WsClientTransport(_FramedClientTransport):
    """The RFC 6455 dialect — what a browser's WebSocket object speaks.

    Each protocol message rides as one masked binary WS message; pings
    from the server are answered transparently; a Close frame or any
    framing violation ends the connection cleanly (None from
    ``_recv_msg`` -> ConnectionError upstream, same as the TCP dialect).
    """

    dialect = "ws"

    def _setup(self) -> None:
        self.framer = wsframing.client_framer()
        self._events: Deque = deque()
        request, key = wsframing.client_handshake_request(
            f"{self.sock.getpeername()[0]}:{self.sock.getpeername()[1]}")
        handshake = wsframing.ClientHandshake(key)
        try:
            with _sock_timeout(self.sock, HANDSHAKE_TIMEOUT):
                self.sock.sendall(request)
                while not handshake.done:
                    data = self.sock.recv(4096)
                    if not data:
                        raise ConnectionError(
                            "gateway closed during ws handshake")
                    handshake.feed(data)
        except socket.timeout:
            raise ConnectionError("ws handshake timed out") from None
        except wsframing.WsProtocolError as e:
            raise ConnectionError(f"ws handshake failed: {e}") from e
        if handshake.leftover:
            self._events.extend(self.framer.feed(handshake.leftover))

    def _send_msg(self, msg) -> int:
        frame = self.framer.send_message(encode_message(msg))
        self.sock.sendall(frame)
        return len(frame)

    def _recv_msg(self):
        while True:
            while self._events:
                ev = self._events.popleft()
                if isinstance(ev, wsframing.Message):
                    return decode_message(ev.data)
                if isinstance(ev, wsframing.Ping):
                    self.sock.sendall(self.framer.pong(ev.data))
                elif isinstance(ev, wsframing.Closed):
                    return None
                # Pong: ignore
            try:
                if self.framer.mid_frame:
                    # a timeout may not surface mid-frame (stream desync);
                    # scope the stall window exactly like the TCP dialect
                    with _sock_timeout(self.sock, FRAME_STALL_TIMEOUT):
                        try:
                            data = self.sock.recv(_RECV_CHUNK)
                        except socket.timeout:
                            return None     # stalled mid-frame: peer is dead
                else:
                    data = self.sock.recv(_RECV_CHUNK)  # may raise (idle)
            except socket.timeout:
                raise
            except OSError:
                return None
            if not data:
                return None
            try:
                self._events.extend(self.framer.feed(data))
            except wsframing.WsProtocolError as e:
                log.error("ws protocol error from gateway: %s -- closing", e)
                return None

    def close(self) -> None:
        try:
            self.sock.sendall(self.framer.close())
        except OSError:
            pass
        self.sock.close()


_DIALECTS = {"tcp": SocketTransport, "ws": WsClientTransport}


# ---------------------------------------------------------------------------
# the engine-free volunteer
# ---------------------------------------------------------------------------

def _wait(transport: Transport, inbox: Deque,
          timeout: Optional[float] = None, *, holding: bool = False) -> bool:
    """Wait for the next notification. Returns False on a timed-out wait
    (the caller should heartbeat its lease and re-check state). ``holding``
    says whether the caller still holds a leased ticket — an UNTIMED wait
    while holding is the PARKED-HOLDER invariant the runtime monitor checks
    (PR 5's step-aside deadlock: if that ticket is the last progressable
    task, nothing can ever wake the parked holder)."""
    if inbox:
        inbox.popleft()
        return True
    waiter = getattr(transport, "wait_notification", None)
    if waiter is None:
        raise RuntimeError(
            "volunteer blocked on a transport that cannot wait — with no "
            "other actors this is a protocol deadlock")
    timed = timeout is not None and getattr(transport, "timed_waits", False)
    mon = _monitor()
    if mon is not None:
        mon.note_park("volunteer-wait", holding=holding, timed=timed)
    if timed:
        return waiter(timeout) is not None
    waiter()
    return True


def run_volunteer(transport: Transport, vid: str, n_updates: int, *,
                  policy: PolicyLike = None, task_delay: float = 0.0,
                  heartbeat_every: float = 0.5,
                  tally: Optional[list] = None,
                  problem: Optional[TrainingProblem] = None
                  ) -> Tuple[int, int]:
    """Drive one volunteer to run completion over any transport. Compute is
    synthetic (gradient payloads None, model blobs version strings);
    ``task_delay`` sleeps that long per compute — the window the chaos legs
    use to kill a process mid-task. Barrierless policies commit through the
    server-side applier (one ``SubmitUpdate``, no model push). On transports
    with timed waits, every wait wakes at least each ``heartbeat_every``
    seconds to renew the held lease (``ExtendLease``) and re-check state —
    so a LIVE volunteer parked on the reduce barrier never loses its ticket
    to the wall-clock sweeper, while a dead one's expires on schedule.
    ``tally`` (a one-element list) is incremented per completed task IN
    PLACE, so a caller surviving this function's ConnectionError still sees
    the partial count. Returns (final_version, tasks_done)."""
    pol = make_policy(policy)
    sess = VolunteerSession(vid, transport, policy=pol)
    inbox: Deque = getattr(transport, "inbox", None)
    if inbox is None:
        inbox = deque()
        transport.set_deliver(lambda c, m: inbox.append(m))
    # end-of-run nudge: a volunteer idling on the task queue when ANOTHER
    # volunteer publishes the final version would otherwise wait forever —
    # the VersionReady push for the final version breaks that wait
    sess.subscribe(Blocked(version=n_updates))
    tasks_done = 0

    def bump():
        nonlocal tasks_done
        tasks_done += 1
        if tally is not None:
            tally[0] += 1

    def compute_delay():
        # simulate slow compute in heartbeat-sized slices, renewing the held
        # lease between them — a LIVE volunteer must keep its ticket through
        # a compute longer than the visibility timeout (only kill -9 stops
        # the renewals, which is exactly when the sweeper SHOULD requeue)
        end = _CLOCK.now() + task_delay
        while True:
            rem = end - _CLOCK.now()
            if rem <= 0:
                return
            time.sleep(min(rem, heartbeat_every))
            sess.heartbeat()

    while True:
        if sess.task is None:
            # termination is only checked while idle — while a task is held,
            # advance()'s own LatestReq covers staleness, so the socket path
            # pays one version poll per task, not one per protocol move
            if sess.latest() >= n_updates:
                break
            if isinstance(sess.lease(0.0), NoTask):
                sess.subscribe_idle()
                _wait(transport, inbox, heartbeat_every)
                continue
        out = sess.advance(0.0)
        if isinstance(out, Blocked):
            sess.subscribe(out)
            woke = _wait(transport, inbox, heartbeat_every,
                         holding=sess.task is not None)
            # renew on EVERY wakeup, not just timeouts: a dense stream of
            # (spurious) wakes must not starve the renewal of a held lease
            sess.heartbeat()
            if not woke:
                if sess.latest() >= n_updates:
                    break            # run finished while we were parked; the
                    #                  held ticket requeues via bye() below
                # deadlock breaker: a holder still blocked after a full wait
                # window steps aside while OTHER tasks are leasable —
                # requeue to the BACK (order-safe: a version-blocked map
                # cannot run before its version commits, and a reduce's
                # barrier state lives in the results queue, not the ticket)
                # and take the front task instead. The queue becomes a slow
                # rotation that always finds the one progressable task —
                # e.g. the expiry-recovered map an open barrier is missing —
                # where a fleet of parked holders would deadlock.
                if sess.task is not None and sess.queue_depth() > 0:
                    sess.release(front=False)
            continue
        if isinstance(out, TaskDone):
            continue
        if task_delay > 0:
            compute_delay()
        if isinstance(out, MapWork):
            if pol.barrier:
                if not sess.finish_map(None, 0, 0.0).stale:
                    bump()
            else:
                if problem is not None:
                    # real compute: gradient of this stream slot at the
                    # fetched latest model — pushed to the server's real
                    # applier through the same SubmitUpdate
                    t = out.task
                    g, loss = problem.map_compute(out.model[0], t.version,
                                                  t.mb_index)
                    res = sess.grad_result(g, problem.grad_bytes, loss)
                else:
                    res = sess.grad_result(None, 0, 0.0)
                if not sess.submit_update(res).stale:
                    bump()
        elif isinstance(out, LocalWork):
            if problem is not None:
                t = out.task
                p0, s0 = out.model
                delta, loss = problem.local_compute(p0, s0, t.start, t.k)
                res = sess.delta_result(delta, problem.model_bytes, loss)
            else:
                res = sess.delta_result(None, 0, 0.0)
            if not sess.submit_update(res).stale:
                bump()
        elif isinstance(out, ReduceWork):
            sess.finish_reduce(f"v{out.task.version + 1}")
            bump()
    final = sess.latest()
    sess.bye()
    return final, tasks_done


def run_volunteer_resilient(host: str, port: int, vid: str, n_updates: int, *,
                            policy: PolicyLike = None, task_delay: float = 0.0,
                            max_reconnects: int = 20, dialect: str = "tcp",
                            problem: Optional[TrainingProblem] = None,
                            fallback_ports: Tuple[int, ...] = (),
                            ) -> Tuple[int, int, int]:
    """``run_volunteer`` that survives gateway crashes: on a connection error
    it reconnects (fresh transport + session, same consumer id) and resumes.
    A lease the dead attempt held is recovered by the server's wall-clock
    sweeper, so no work is lost — only possibly repeated (at-least-once).
    ``dialect`` picks the framing ("tcp" native, "ws" RFC 6455).
    ``fallback_ports`` are alternative gateways (a multi-gateway cluster)
    tried round-robin on each reconnect, so a volunteer whose HOME gateway
    is kill -9'd rejoins the run through a surviving peer.
    Returns (final_version, tasks_done_total, reconnects)."""
    transport_cls = _DIALECTS[dialect]
    ports = [port, *fallback_ports]
    # a lone gateway may restart on its port (wait generously); a cluster
    # volunteer should fail fast and rotate to the next surviving gateway
    connect_timeout = 15.0 if len(ports) == 1 else 3.0
    tally = [0]
    reconnects = -1
    while True:
        reconnects += 1
        if reconnects > max_reconnects:
            raise ConnectionError(
                f"{vid}: gave up after {max_reconnects} reconnects")
        try:
            transport = transport_cls(host, ports[reconnects % len(ports)],
                                      vid, connect_timeout=connect_timeout)
        except ConnectionError:
            continue
        try:
            final, _ = run_volunteer(transport, vid, n_updates,
                                     policy=policy, task_delay=task_delay,
                                     tally=tally, problem=problem)
            return final, tally[0], reconnects
        except ConnectionError:
            # server died mid-run; partial progress is already durable
            # server-side (acked tasks) or recoverable (leases expire)
            continue
        finally:
            try:
                transport.close()
            except OSError:
                pass


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _real_problem(seed: int = 0) -> TrainingProblem:
    """Seed-deterministic shrunk REAL problem for ``--real-apply`` runs: the
    paper model family at d_model=8 on the hermetic synthetic corpus. Every
    term is seeded (corpus, schedule hashes, init PRNGKey), so a volunteer
    process building this independently computes gradients the server's
    applier chains bit-exactly."""
    from repro.configs.paper_lstm import TrainParams
    from repro.data.text import synthetic_corpus
    tp = TrainParams(batch_size=32, examples_per_epoch=256, num_epochs=1,
                     sample_len=40, mini_batch_size=8,
                     mini_batches_to_accumulate=4)
    return TrainingProblem.paper_problem(corpus=synthetic_corpus(20_000),
                                         tp=tp, seed=seed, d_model=8)


def _problem(args):
    if getattr(args, "real_apply", False):
        return _real_problem()
    return SyntheticProblem(n_versions=args.n_versions, n_mb=args.n_mb)


def _target(args) -> int:
    return make_policy(args.policy).n_updates(_problem(args), args.n_versions)


def _serve(args) -> int:
    server = GatewayServer(
        _problem(args), port=args.port, n_versions=args.n_versions,
        policy=args.policy, n_shards=args.shards,
        visibility_timeout=args.visibility_timeout,
        snapshot_path=args.snapshot_path, snapshot_every=args.snapshot_every,
        restore_from=args.restore_from, real_apply=args.real_apply,
        gid=args.gid, gateways=args.gateways, cluster_dir=args.cluster_dir)
    if args.port_file:
        tmp = args.port_file + ".tmp"
        with open(tmp, "w") as f:
            f.write(str(server.port))
        os.replace(tmp, args.port_file)         # atomic: readers never see ""
    who = f"gateway gw{args.gid}/{args.gateways}" if args.gateways > 1 \
        else "gateway"
    print(f"{who}: serving {args.n_versions} versions x "
          f"{args.n_mb}+1 tasks (policy={server.policy.spec}, "
          f"target={server.n_updates}, "
          f"vt={args.visibility_timeout}) on 127.0.0.1:{server.port}"
          + (f" [restored from {args.restore_from}]" if args.restore_from
             else ""), flush=True)
    server.start()
    server.done.wait(timeout=args.timeout)
    # linger until connected volunteers finish their goodbyes (Bye + close);
    # generous, because a volunteer parked in a timed wait notices the end
    # of the run on its next wakeup, not instantly. Inter-gateway links
    # ("gw:" consumers) are not volunteers — peers exit on their own clock.
    deadline = _CLOCK.now() + 20.0
    while any(not c.startswith("gw:") for c in server._conns) \
            and _CLOCK.now() < deadline:
        time.sleep(0.02)
    ok = server.observed_version >= server.n_updates
    applier_stats = ""
    if args.real_apply and server.applier is not None:
        ap = server.applier
        applier_stats = (f" applied={ap.applied} rejected={ap.rejected} "
                         f"batches={ap.batches} "
                         f"batched_updates={ap.batched_updates}")
    print(f"{who}: final_version={server.observed_version} "
          f"snapshots={server.snapshots_written} "
          f"({'done' if ok else 'TIMEOUT'})" + applier_stats, flush=True)
    server.close()
    return 0 if ok else 1


def _volunteer(args) -> int:
    n_updates = _target(args)
    fallback = tuple(int(p) for p in args.ports.split(",") if p) \
        if args.ports else ()
    final, tasks, reconnects = run_volunteer_resilient(
        "127.0.0.1", args.port, args.vid, n_updates, policy=args.policy,
        task_delay=args.task_delay, dialect=args.dialect,
        problem=_real_problem() if args.real_apply else None,
        fallback_ports=fallback)
    print(f"volunteer {args.vid} [{args.dialect}]: final_version={final} "
          f"tasks={tasks} reconnects={reconnects}", flush=True)
    if args.expect_final is not None and final != args.expect_final:
        print(f"FAIL: expected final_version={args.expect_final}")
        return 1
    return 0


def _spawn_server(args, port_file: str, *, port: int = 0,
                  extra: Tuple[str, ...] = ()) -> subprocess.Popen:
    return subprocess.Popen(
        [sys.executable, "-m", "repro.core.gateway", "--serve",
         "--port", str(port), "--port-file", port_file,
         "--n-versions", str(args.n_versions), "--n-mb", str(args.n_mb),
         *extra],
        env=os.environ.copy())


def _wait_port(port_file: str, proc: subprocess.Popen,
               timeout: float = 20.0) -> int:
    deadline = _CLOCK.now() + timeout
    while not os.path.exists(port_file):
        if _CLOCK.now() > deadline or proc.poll() is not None:
            raise RuntimeError("gateway server did not come up")
        time.sleep(0.05)
    with open(port_file) as f:
        return int(f.read())


def _smoke_transport_equivalence(args) -> None:
    """Leg 1 — the identical volunteer loop over (a) direct calls and (b) a
    real socket to a separate gateway PROCESS must agree."""
    server = GatewayServer(_problem(args), n_versions=args.n_versions)
    ref_final, ref_tasks = run_volunteer(
        InProcessTransport(server.endpoint), "ref", args.n_versions)
    server.close()
    with tempfile.TemporaryDirectory() as td:
        port_file = os.path.join(td, "gw.port")
        proc = _spawn_server(args, port_file)
        try:
            port = _wait_port(port_file, proc)
            transport = SocketTransport("127.0.0.1", port, "gw0")
            final, tasks = run_volunteer(transport, "gw0", args.n_versions)
            transport.close()
            rc = proc.wait(timeout=10)
        finally:
            if proc.poll() is None:
                proc.kill()
    n_tasks = args.n_versions * (args.n_mb + 1)
    assert final == ref_final == args.n_versions, (final, ref_final)
    assert tasks == ref_tasks == n_tasks, (tasks, ref_tasks, n_tasks)
    assert rc == 0, f"gateway server exited {rc}"
    print(f"# OK gateway smoke [transport]: out-of-process volunteer over "
          f"the socket matched in-process — final_version={final}, "
          f"tasks={tasks}")


def _smoke_lease_sweeper(args) -> None:
    """Leg 2 — kill -9 a real volunteer PROCESS mid-task: its lease must
    expire on the wall clock (sweeper thread), the ticket requeue, and the
    surviving volunteers finish the whole run. Two survivors, because the
    recovered map task needs an IDLE taker if the other survivor is already
    holding the reduce barrier."""
    vt = 1.0
    n_tasks = args.n_versions * (args.n_mb + 1)
    with tempfile.TemporaryDirectory() as td:
        port_file = os.path.join(td, "gw.port")
        proc = _spawn_server(args, port_file,
                             extra=("--visibility-timeout", str(vt)))
        victim = None
        try:
            port = _wait_port(port_file, proc)
            # the victim sleeps 30 s inside every compute, so once it LEASES
            # it is holding that lease when killed (and can never finish)
            victim = subprocess.Popen(
                [sys.executable, "-m", "repro.core.gateway", "--volunteer",
                 "--port", str(port), "--vid", "victim",
                 "--n-versions", str(args.n_versions),
                 "--n-mb", str(args.n_mb), "--task-delay", "30"],
                env=os.environ.copy())
            # wait until the victim has genuinely leased: the task queue's
            # depth drops below the full schedule (DepthReq is read-only)
            from repro.core.protocol import DepthReq
            from repro.core.tasks import INITIAL_QUEUE
            monitor = SocketTransport("127.0.0.1", port, "monitor")
            deadline = _CLOCK.now() + 30.0
            while monitor.call(DepthReq(INITIAL_QUEUE)).value >= n_tasks:
                assert _CLOCK.now() < deadline, "victim never leased"
                time.sleep(0.05)
            monitor.close()
            victim.send_signal(signal.SIGKILL)
            victim.wait(timeout=10)
            t0 = _CLOCK.now()
            results: Dict[str, Tuple[int, int]] = {}

            def survive(vid: str) -> None:
                tr = SocketTransport("127.0.0.1", port, vid)
                results[vid] = run_volunteer(tr, vid, args.n_versions)
                tr.close()

            threads = [threading.Thread(target=survive, args=(f"s{i}",),
                                        daemon=True) for i in range(2)]
            for th in threads:
                th.start()
            for th in threads:
                th.join(timeout=60)
                assert not th.is_alive(), "survivor deadlocked"
            wall = _CLOCK.now() - t0
            rc = proc.wait(timeout=15)
        finally:
            for p in (victim, proc):
                if p is not None and p.poll() is None:
                    p.kill()
    finals = [results[v][0] for v in sorted(results)]
    tasks = sum(results[v][1] for v in sorted(results))
    assert finals == [args.n_versions] * 2, f"run did not finish: {finals}"
    assert tasks >= n_tasks, f"tasks lost: {tasks} < {n_tasks}"
    assert rc == 0, f"gateway server exited {rc}"
    print(f"# OK gateway smoke [lease-sweeper]: victim volunteer kill -9'd "
          f"mid-task; wall-clock sweeper requeued its lease (vt={vt}s) and "
          f"2 survivors finished the run ({tasks} tasks) in {wall:.1f}s")


def _smoke_crash_recovery(args) -> None:
    """Leg 3 — kill -9 the SERVER mid-run, restart from the latest snapshot:
    the volunteer reconnects and the run completes with the same final
    version as the uninterrupted single-process reference (tasks may repeat:
    at-least-once)."""
    # uninterrupted reference (in process, same problem)
    server = GatewayServer(_problem(args), n_versions=args.n_versions)
    ref_final, ref_tasks = run_volunteer(
        InProcessTransport(server.endpoint), "ref", args.n_versions)
    server.close()
    with tempfile.TemporaryDirectory() as td:
        port_file = os.path.join(td, "gw.port")
        snap = os.path.join(td, "gw.snap")
        durable = ("--visibility-timeout", "1.0",
                   "--snapshot-every", "1", "--snapshot-path", snap)
        proc = _spawn_server(args, port_file, extra=durable)
        out: Dict[str, Tuple[int, int, int]] = {}
        try:
            port = _wait_port(port_file, proc)

            def drive():
                out["v"] = run_volunteer_resilient(
                    "127.0.0.1", port, "gw0", args.n_versions,
                    task_delay=0.06)

            vt = threading.Thread(target=drive, daemon=True)
            vt.start()
            time.sleep(0.8)                      # mid-run (15 tasks x ~60ms+)
            proc.send_signal(signal.SIGKILL)     # no goodbye, no final flush
            proc.wait(timeout=10)
            assert os.path.exists(snap), "server died before any snapshot"
            # restart on the SAME port from the latest snapshot
            proc = _spawn_server(args, port_file, port=port,
                                 extra=durable + ("--restore-from", snap))
            vt.join(timeout=60)
            assert not vt.is_alive(), "volunteer never finished after restart"
            rc = proc.wait(timeout=15)
        finally:
            if proc.poll() is None:
                proc.kill()
    final, tasks, reconnects = out["v"]
    assert final == ref_final == args.n_versions, (final, ref_final)
    assert tasks >= ref_tasks, f"lost work: {tasks} < {ref_tasks}"
    assert reconnects >= 1, "volunteer never observed the crash"
    assert rc == 0, f"restarted gateway exited {rc}"
    print(f"# OK gateway smoke [crash-recovery]: server kill -9'd mid-run, "
          f"restarted from snapshot, run resumed and matched the "
          f"uninterrupted final version v{final} "
          f"(tasks {tasks} >= {ref_tasks} ref; {reconnects} reconnect)")


def _smoke_server_applier(args) -> None:
    """Leg 4 — barrierless policy over the socket: the server-side applier
    commits every admitted gradient, so the volunteer's wire histogram shows
    ZERO model pushes and zero admission fetches — the bytes-per-update win
    ``benchmarks/staleness.py`` quantifies."""
    policy = "staleness:2"
    n_updates = make_policy(policy).n_updates(_problem(args), args.n_versions)
    with tempfile.TemporaryDirectory() as td:
        port_file = os.path.join(td, "gw.port")
        proc = _spawn_server(args, port_file, extra=("--policy", policy))
        try:
            port = _wait_port(port_file, proc)
            transport = SocketTransport("127.0.0.1", port, "thin0")
            final, tasks = run_volunteer(transport, "thin0", n_updates,
                                         policy=policy)
            sent = dict(transport.sent)
            transport.close()
            rc = proc.wait(timeout=10)
        finally:
            if proc.poll() is None:
                proc.kill()
    assert final == n_updates, (final, n_updates)
    assert sent.get("SubmitUpdate", 0) == tasks > 0, sent
    assert "PublishModel" not in sent, f"thin client pushed a model: {sent}"
    assert rc == 0, f"gateway server exited {rc}"
    print(f"# OK gateway smoke [server-applier]: {policy} over the socket — "
          f"{tasks} updates committed via SubmitUpdate, volunteer sent "
          f"0 PublishModel frames (server applied every gradient)")


def _smoke_ws_dialect(args) -> None:
    """Leg 5 — one port, two framing dialects: a WebSocket-framed volunteer
    PROCESS and a native-TCP volunteer PROCESS join the SAME gateway run and
    must both observe the identical (bit-identical) final model version."""
    n_tasks = args.n_versions * (args.n_mb + 1)
    with tempfile.TemporaryDirectory() as td:
        port_file = os.path.join(td, "gw.port")
        proc = _spawn_server(args, port_file)
        volunteers = []
        try:
            port = _wait_port(port_file, proc)
            for vid, dialect in (("ws0", "ws"), ("tcp0", "tcp")):
                volunteers.append(subprocess.Popen(
                    [sys.executable, "-m", "repro.core.gateway",
                     "--volunteer", "--port", str(port), "--vid", vid,
                     "--dialect", dialect,
                     "--n-versions", str(args.n_versions),
                     "--n-mb", str(args.n_mb),
                     "--expect-final", str(args.n_versions)],
                    env=os.environ.copy()))
            rcs = [v.wait(timeout=90) for v in volunteers]
            rc = proc.wait(timeout=15)
        finally:
            for p in (*volunteers, proc):
                if p.poll() is None:
                    p.kill()
    assert rcs == [0, 0], f"volunteer processes exited {rcs}"
    assert rc == 0, f"gateway server exited {rc}"
    print(f"# OK gateway smoke [ws-dialect]: a WebSocket volunteer and a "
          f"TCP volunteer shared one gateway port and finished the same "
          f"{n_tasks}-task run at the identical final version "
          f"v{args.n_versions}")


def _smoke_browser_thin(args) -> None:
    """Leg 6 — the browser tier end to end: a ``repro.core.browser`` thin
    client PROCESS (WebSocket framing, lease/fetch-latest/SubmitUpdate only)
    and a TCP volunteer finish a barrierless run; the browser client asserts
    ZERO PublishModel frames itself (MLitB's thin-client contract)."""
    policy = "staleness:2"
    n_updates = make_policy(policy).n_updates(_problem(args), args.n_versions)
    with tempfile.TemporaryDirectory() as td:
        port_file = os.path.join(td, "gw.port")
        proc = _spawn_server(args, port_file, extra=("--policy", policy))
        browser = tcp = None
        try:
            port = _wait_port(port_file, proc)
            browser = subprocess.Popen(
                [sys.executable, "-m", "repro.core.browser",
                 "--port", str(port), "--vid", "browser0",
                 "--policy", policy,
                 "--n-versions", str(args.n_versions),
                 "--n-mb", str(args.n_mb),
                 "--expect-final", str(n_updates)],
                env=os.environ.copy())
            tcp = subprocess.Popen(
                [sys.executable, "-m", "repro.core.gateway", "--volunteer",
                 "--port", str(port), "--vid", "tcp1", "--policy", policy,
                 "--n-versions", str(args.n_versions),
                 "--n-mb", str(args.n_mb),
                 "--expect-final", str(n_updates)],
                env=os.environ.copy())
            rcs = [browser.wait(timeout=90), tcp.wait(timeout=90)]
            rc = proc.wait(timeout=15)
        finally:
            for p in (browser, tcp, proc):
                if p is not None and p.poll() is None:
                    p.kill()
    assert rcs == [0, 0], f"volunteer processes exited {rcs}"
    assert rc == 0, f"gateway server exited {rc}"
    print(f"# OK gateway smoke [browser-thin]: browser thin client over "
          f"WebSocket + TCP volunteer finished the {policy} run at "
          f"v{n_updates}; browser pushed zero PublishModel frames")


def _smoke_real_applier(args) -> None:
    """Leg 7 — the REAL JAX applier over the socket: (a) one real-compute
    volunteer against a ``--real-apply`` server PROCESS must land on a final
    model BIT-IDENTICAL to ``sequential_async`` (fetched back over the wire);
    (b) three concurrent real-compute volunteers must finish the run with
    contiguous versions — the combining-lock drain path under real races."""
    from repro.core.mapreduce import sequential_async
    import numpy as np
    policy = "staleness:2"
    problem = _real_problem()
    n_versions = 2                       # 2 * n_mb(4) = 8 updates
    n_updates = make_policy(policy).n_updates(problem, n_versions)
    extra = ("--policy", policy, "--real-apply")

    def serve_run(vids):
        with tempfile.TemporaryDirectory() as td:
            port_file = os.path.join(td, "gw.port")
            proc = _spawn_server(
                args, port_file,
                extra=extra + ("--n-versions", str(n_versions)))
            try:
                port = _wait_port(port_file, proc)
                results: Dict[str, Tuple[int, int]] = {}

                def drive(vid: str) -> None:
                    tr = SocketTransport("127.0.0.1", port, vid)
                    results[vid] = run_volunteer(tr, vid, n_updates,
                                                 policy=policy,
                                                 problem=problem)
                    tr.close()

                threads = [threading.Thread(target=drive, args=(v,),
                                            daemon=True) for v in vids[1:]]
                for th in threads:
                    th.start()
                # the first vid runs on THIS thread and fetches the final
                # model over the wire before saying goodbye
                tr = SocketTransport("127.0.0.1", port, vids[0])
                results[vids[0]] = run_volunteer(tr, vids[0], n_updates,
                                                 policy=policy,
                                                 problem=problem)
                for th in threads:
                    th.join(timeout=120)
                    assert not th.is_alive(), "real volunteer deadlocked"
                final_blob = tr.call(FetchModel(n_updates)).blob
                tr.close()
                rc = proc.wait(timeout=15)
            finally:
                if proc.poll() is None:
                    proc.kill()
        assert rc == 0, f"gateway server exited {rc}"
        finals = [results[v][0] for v in sorted(results)]
        assert finals == [n_updates] * len(vids), finals
        return final_blob

    # (a) one volunteer: commit order is serialized, so the wire-fetched
    # final model must BIT-match the sequential reference
    blob = serve_run(["r0"])
    ref_p, ref_s, _ = sequential_async(problem, n_updates=n_updates)
    import jax
    same = jax.tree.all(jax.tree.map(
        lambda a, b: bool((np.asarray(a) == np.asarray(b)).all()),
        blob, (ref_p, ref_s)))
    assert same, "real-apply final model != sequential_async bits"
    # (b) three racing volunteers: liveness + a contiguous final version
    serve_run(["r0", "r1", "r2"])
    print(f"# OK gateway smoke [real-applier]: --real-apply served real JAX "
          f"applies over the socket — 1-volunteer run bit-matched "
          f"sequential_async at v{n_updates}; 3 racing volunteers finished "
          f"the drained run")


def _smoke_cluster(args) -> int:
    """``--smoke-cluster`` — the multi-gateway control plane under kill -9:
    three gateway PROCESSES share one consistent-hash ring; the MODEL
    owner is SIGKILLed mid-run; the deterministic adopter replays its op
    log, volunteers fail over to surviving ports, and the run completes at
    the reference final version (the chaos contract's wall-clock twin)."""
    k = 3
    target = _target(args)
    ring = GatewayRing(range(k))
    victim = ring.owner_of(MODEL_KEY)    # hardest slice: model state adopts
    with tempfile.TemporaryDirectory() as td:
        procs = []
        for gid in range(k):
            procs.append(subprocess.Popen(
                [sys.executable, "-m", "repro.core.gateway", "--serve",
                 "--gid", str(gid), "--gateways", str(k),
                 "--cluster-dir", td,
                 "--n-versions", str(args.n_versions),
                 "--n-mb", str(args.n_mb), "--policy", args.policy,
                 "--visibility-timeout", "2.0", "--snapshot-every", "8",
                 "--timeout", "120"],
                env=os.environ.copy()))
        try:
            ports = []
            for gid in range(k):
                ports.append(_wait_port(os.path.join(td, f"gw{gid}.port"),
                                        procs[gid]))
            results: Dict[int, Tuple[int, int, int]] = {}

            def drive(i: int, home: int) -> None:
                order = [ports[home]] + [p for j, p in enumerate(ports)
                                         if j != home]
                results[i] = run_volunteer_resilient(
                    "127.0.0.1", order[0], f"cv{i}", target,
                    policy=args.policy, task_delay=0.15,
                    fallback_ports=tuple(order[1:]))

            # one volunteer homed on the victim (exercises port failover),
            # one on a survivor (exercises re-forwarding after adoption)
            homes = [victim, (victim + 1) % k]
            threads = [threading.Thread(target=drive, args=(i, h),
                                        daemon=True)
                       for i, h in enumerate(homes)]
            t0 = _CLOCK.now()
            for th in threads:
                th.start()
            time.sleep(1.0)                      # mid-run (28 tasks x 150ms)
            assert procs[victim].poll() is None, "victim exited early"
            procs[victim].send_signal(signal.SIGKILL)
            procs[victim].wait(timeout=10)
            for th in threads:
                th.join(timeout=110)
                assert not th.is_alive(), "cluster volunteer deadlocked"
            wall = _CLOCK.now() - t0
            rcs = [procs[g].wait(timeout=60) for g in range(k)
                   if g != victim]
        finally:
            for p in procs:
                if p.poll() is None:
                    p.kill()
    finals = [results[i][0] for i in sorted(results)]
    reconnects = sum(results[i][2] for i in results)
    assert finals == [target] * 2, f"cluster run did not converge: {finals}"
    assert rcs == [0] * (k - 1), f"surviving gateways exited {rcs}"
    assert reconnects >= 1, "no volunteer ever observed the kill"
    print(f"# OK gateway smoke [cluster]: 3-gateway ring, model owner "
          f"gw{victim} kill -9'd mid-run; adopter replayed its op log and "
          f"every volunteer finished at v{target} "
          f"({reconnects} reconnects) in {wall:.1f}s")
    return 0


def _smoke(args) -> int:
    _smoke_transport_equivalence(args)
    _smoke_lease_sweeper(args)
    _smoke_crash_recovery(args)
    _smoke_server_applier(args)
    _smoke_ws_dialect(args)
    _smoke_browser_thin(args)
    _smoke_real_applier(args)
    print("# OK gateway smoke: all 7 legs green (transport equivalence, "
          "wall-clock lease sweeper, kill -9 crash recovery, server-side "
          "applier, ws dialect, browser thin client, real applier)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--serve", action="store_true")
    mode.add_argument("--volunteer", action="store_true")
    mode.add_argument("--smoke", action="store_true")
    mode.add_argument("--smoke-cluster", action="store_true",
                      help="multi-gateway leg: 3-process ring, model owner "
                           "kill -9'd mid-run, op-log failover completes it")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--gid", type=int, default=0,
                    help="serve: this gateway's id on the cluster ring")
    ap.add_argument("--gateways", type=int, default=1,
                    help="serve: ring size; >1 enables the multi-gateway "
                         "control plane (needs --cluster-dir)")
    ap.add_argument("--cluster-dir", default=None,
                    help="per-gateway op logs + port files; set with "
                         "--gateways 1 to get op-log durability alone")
    ap.add_argument("--ports", default=None,
                    help="volunteer: comma-separated fallback gateway ports "
                         "tried round-robin on reconnect")
    ap.add_argument("--port-file", default=None)
    ap.add_argument("--vid", default="gw0")
    ap.add_argument("--dialect", choices=sorted(_DIALECTS), default="tcp",
                    help="volunteer framing: native length-prefixed TCP or "
                         "RFC 6455 WebSocket (one server port serves both)")
    ap.add_argument("--n-versions", type=int, default=4)
    ap.add_argument("--n-mb", type=int, default=6)
    ap.add_argument("--policy", default="sync",
                    help="sync | staleness:<s> | local:<k> (barrierless "
                         "policies enable the server-side applier)")
    ap.add_argument("--real-apply", action="store_true",
                    help="serve: host the REAL JAX applier (batched drains, "
                         "measured blob sizes) on the seed-deterministic "
                         "shrunk paper problem; volunteer: compute real "
                         "gradients for the same problem")
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--visibility-timeout", type=float, default=float("inf"),
                    help="wall-clock lease seconds before the sweeper "
                         "requeues an unacked task (default: infinite)")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="snapshot after every K state-changing requests "
                         "(0 = never)")
    ap.add_argument("--snapshot-path", default=None)
    ap.add_argument("--restore-from", default=None,
                    help="boot from a snapshot instead of a fresh enqueue")
    ap.add_argument("--task-delay", type=float, default=0.0,
                    help="volunteer: sleep per compute (chaos kill window)")
    ap.add_argument("--expect-final", type=int, default=None)
    ap.add_argument("--timeout", type=float, default=60.0)
    args = ap.parse_args(argv)
    if args.serve:
        rc = _serve(args)
    elif args.volunteer:
        rc = _volunteer(args)
    elif args.smoke_cluster:
        rc = _smoke_cluster(args)
    else:
        rc = _smoke(args)
    mon = _monitor()
    if mon is not None:
        # instrumented runs fail on any recorded lock/invariant violation,
        # even if the protocol run itself succeeded
        rc = max(rc, mon.report())
    return rc


if __name__ == "__main__":
    sys.exit(main())
