"""Transports: how protocol messages reach the servers.

A ``Transport`` carries ``repro.core.protocol`` messages both ways: the client
(``VolunteerSession``) issues a request through ``call`` and gets the reply;
async notifications (``Wake``, ``VersionReady``) flow back through the
``deliver(consumer, msg)`` sink the owning engine installs. Three
implementations, one contract:

- ``InProcessTransport`` — direct dispatch onto the in-process
  ``ServerEndpoint``; zero copies, zero serialization. The engines' default:
  bit-matches the pre-transport direct-call behavior exactly.

- ``WireTransport`` — every request, reply, AND notification round-trips
  through canonical bytes (``encode_message``/``decode_message``), proving
  the whole protocol is serializable and *measuring* real message sizes:
  ``bytes_sent``/``bytes_received`` totals plus a ``take_bytes()`` tap the
  Simulator's network cost model reads instead of hand-estimated sizes.

- ``FaultyTransport`` — wraps another transport and injects chaos at message
  granularity on the notification path: seeded drop / duplicate / delay of
  ``Wake`` and ``VersionReady`` fires (the ROADMAP's "stale reads, lost watch
  fires" rung). Requests pass through untouched — queue state stays sound;
  only *delivery* misbehaves, which is exactly the failure the lease-expiry
  path must absorb. Deterministic: decisions come from ``random.Random(seed)``
  in delivery order, so a fault schedule replays bit-for-bit and applies
  identically to the single-server and sharded runs of a metamorphic pair.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Union

from repro.core.protocol import (ServerEndpoint, VersionReady, Wake,
                                 decode_message, encode_message)

Deliver = Callable[[str, Any], None]


def make_transport(transport: Union[str, Callable, None],
                   endpoint: ServerEndpoint) -> "Transport":
    """Resolve an engine's ``transport=`` argument: "inproc" | "wire" | a
    factory ``endpoint -> Transport`` (e.g. for a custom FaultyTransport
    stack). A factory — not a pre-built instance — because a Transport is
    bound to ONE endpoint, and it must be the engine's own (where the task
    graph was enqueued), not whatever a caller happened to wrap."""
    if transport is None or transport == "inproc":
        return InProcessTransport(endpoint)
    if transport == "wire":
        return WireTransport(endpoint)
    if callable(transport):
        built = transport(endpoint)
        if not isinstance(built, Transport):
            raise TypeError(f"transport factory returned {type(built).__name__},"
                            f" not a Transport")
        return built
    raise ValueError(f"unknown transport {transport!r}")


class Transport:
    """Message port: synchronous request/reply + async notification sink."""

    measures_bytes = False

    def call(self, msg):
        raise NotImplementedError

    def set_deliver(self, deliver: Deliver) -> None:
        """Install the engine's notification sink."""
        raise NotImplementedError

    def take_bytes(self) -> float:
        """Bytes moved since the last take (0 when nothing is measured)."""
        return 0.0


class InProcessTransport(Transport):
    """Direct calls onto the endpoint — the zero-copy fast path."""

    def __init__(self, endpoint: ServerEndpoint):
        self.endpoint = endpoint
        self._deliver: Deliver = lambda c, m: None
        endpoint.set_notify(self._notify)
        self.calls = 0

    def set_deliver(self, deliver: Deliver) -> None:
        self._deliver = deliver

    def call(self, msg):
        self.calls += 1
        return self.endpoint.handle(msg)

    def _notify(self, consumer: str, msg) -> None:
        self._deliver(consumer, msg)


class WireTransport(Transport):
    """Round-trip every message through bytes; measure what actually moves."""

    measures_bytes = True

    def __init__(self, endpoint: ServerEndpoint,
                 codec: Optional[str] = None):
        self.endpoint = endpoint
        self.codec = codec
        self._deliver: Deliver = lambda c, m: None
        endpoint.set_notify(self._notify)
        self.calls = 0
        self.bytes_sent = 0          # client -> server (requests)
        self.bytes_received = 0      # server -> client (replies, notifications)
        self._tap = 0.0

    def set_deliver(self, deliver: Deliver) -> None:
        self._deliver = deliver

    def _account(self, n: int, *, sent: bool) -> None:
        if sent:
            self.bytes_sent += n
        else:
            self.bytes_received += n
        self._tap += n

    def take_bytes(self) -> float:
        n, self._tap = self._tap, 0.0
        return n

    def call(self, msg):
        self.calls += 1
        req = encode_message(msg, codec=self.codec)
        self._account(len(req), sent=True)
        reply = self.endpoint.handle(decode_message(req))
        rep = encode_message(reply, codec=self.codec)
        self._account(len(rep), sent=False)
        return decode_message(rep)

    def _notify(self, consumer: str, msg) -> None:
        data = encode_message(msg, codec=self.codec)
        self._account(len(data), sent=False)
        self._deliver(consumer, decode_message(data))


@dataclass(frozen=True)
class FaultSpec:
    """Seeded notification-fault distribution. Probabilities are evaluated
    per delivery, in delivery order; ``max_faults`` caps total injections so a
    schedule can target e.g. exactly one lost watch fire."""
    drop_wake: float = 0.0            # lose a queue-subscription fire
    drop_version_ready: float = 0.0   # lose a DataServer watch fire
    duplicate: float = 0.0            # deliver a notification twice
    delay: float = 0.0                # defer a delivery by ``delay_dt``
    delay_dt: float = 0.5
    max_faults: int = 10 ** 9


class FaultyTransport(Transport):
    """Chaos at message granularity, on the notification path only.

    ``defer(dt, fn)`` is the engine's timer (the Simulator posts to its event
    heap); without one, delay faults degrade to immediate delivery.
    """

    def __init__(self, inner: Transport, spec: FaultSpec, *, seed: int = 0,
                 defer: Optional[Callable[[float, Callable[[], None]], None]]
                 = None):
        self.inner = inner
        self.spec = spec
        self.rng = random.Random(seed)
        self.defer = defer
        self._deliver: Deliver = lambda c, m: None
        inner.set_deliver(self._on_notify)
        self.faults: Dict[str, int] = {"drop": 0, "duplicate": 0, "delay": 0}

    @property
    def measures_bytes(self):  # type: ignore[override]
        return self.inner.measures_bytes

    def set_deliver(self, deliver: Deliver) -> None:
        self._deliver = deliver

    def take_bytes(self) -> float:
        return self.inner.take_bytes()

    def call(self, msg):
        return self.inner.call(msg)

    def _budget(self) -> bool:
        return sum(self.faults.values()) < self.spec.max_faults

    def _on_notify(self, consumer: str, msg) -> None:
        s = self.spec
        p_drop = (s.drop_version_ready if isinstance(msg, VersionReady)
                  else s.drop_wake if isinstance(msg, Wake) else 0.0)
        # three rng draws per delivery, unconditionally, so the consumed
        # sequence — and every later decision — is identical across runs
        r_drop, r_dup, r_delay = (self.rng.random() for _ in range(3))
        if r_drop < p_drop and self._budget():
            self.faults["drop"] += 1
            return
        if r_dup < s.duplicate and self._budget():
            self.faults["duplicate"] += 1
            self._deliver(consumer, msg)
        if r_delay < s.delay and self._budget() \
                and self.defer is not None:
            self.faults["delay"] += 1
            self.defer(s.delay_dt,
                       lambda c=consumer, m=msg: self._deliver(c, m))
            return
        self._deliver(consumer, msg)
