"""Task and message types of the JSDoop map-reduce training protocol (§IV.G).

One *batch* (size 128) = ``n_mb`` map tasks (mini-batch 8 gradients against
model version v) + 1 reduce task (accumulate all n_mb gradients, RMSprop-apply,
publish model v+1). The model version required by a batch's tasks equals the
global batch index: version = epoch * batches_per_epoch + batch.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

INITIAL_QUEUE = "initial"


def results_queue(version: int) -> str:
    """Per-batch results queue (the paper's MapResultsQueue, sharded per batch —
    'it is possible to use several QueueServers in which each one stores a
    different type of task')."""
    return f"map-results:v{version}"


@dataclass(frozen=True)
class MapTask:
    version: int              # model version the gradient must be computed on
    epoch: int
    batch: int
    mb_index: int             # which mini-batch slice of the 128-batch
    mb_size: int

    kind: str = "map"


@dataclass(frozen=True)
class ReduceTask:
    version: int              # consumes results for `version`, publishes version+1
    epoch: int
    batch: int
    n_mb: int

    kind: str = "reduce"


@dataclass(frozen=True)
class GradResult:
    version: int
    mb_index: int
    payload: Any              # grads pytree (or encoded payload) | None in sim
    nbytes: int = 0
    loss: float = 0.0
    worker: str = ""


# task/result bodies that may ride inside protocol messages — registered with
# the wire codec in repro.core.protocol so they round-trip bytes by name
WIRE_TYPES = (MapTask, ReduceTask, GradResult)
