"""Task and message types of the JSDoop map-reduce training protocol (§IV.G).

One *batch* (size 128) = ``n_mb`` map tasks (mini-batch 8 gradients against
model version v) + 1 reduce task (accumulate all n_mb gradients, RMSprop-apply,
publish model v+1). The model version required by a batch's tasks equals the
global batch index: version = epoch * batches_per_epoch + batch.

That is the ``SyncBSP`` work-unit vocabulary; the other aggregation policies
(``repro.core.aggregation``) reuse ``MapTask`` as an async gradient ticket
(its ``version`` then names the data-schedule slot, not a required model
version) and add ``LocalTask``/``DeltaResult`` for local-steps model
averaging. Results are version-stamped: ``computed_at`` records the model
version a payload was actually computed against, which is what the policy's
admission rule judges.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

INITIAL_QUEUE = "initial"

RESULTS_PREFIX = "map-results:"


def results_queue(version: int) -> str:
    """Per-batch results queue (the paper's MapResultsQueue, sharded per batch —
    'it is possible to use several QueueServers in which each one stores a
    different type of task')."""
    return f"{RESULTS_PREFIX}v{version}"


@dataclass(frozen=True)
class MapTask:
    version: int              # model version the gradient must be computed on
                              # (async policies: the data-schedule slot only)
    epoch: int
    batch: int
    mb_index: int             # which mini-batch slice of the 128-batch
    mb_size: int

    kind: str = "map"


@dataclass(frozen=True)
class ReduceTask:
    version: int              # consumes results for `version`, publishes version+1
    epoch: int
    batch: int
    n_mb: int

    kind: str = "reduce"


@dataclass(frozen=True)
class LocalTask:
    """LocalSteps ticket: run ``k`` local optimizer steps starting at global
    mini-batch stream offset ``start`` and contribute the model delta."""
    slot: int                 # schedule slot (commit order is arrival order)
    start: int                # first index into the global mini-batch stream
    k: int                    # local optimizer steps per contribution
    mb_size: int

    kind: str = "local"


@dataclass(frozen=True)
class GradResult:
    version: int
    mb_index: int
    payload: Any              # grads pytree (or encoded payload) | None in sim
    nbytes: int = 0
    loss: float = 0.0
    worker: str = ""
    computed_at: int = -1     # model version the gradient was computed at
                              # (== version under SyncBSP; the admission
                              # observable under BoundedStaleness)


@dataclass(frozen=True)
class DeltaResult:
    """A LocalSteps volunteer's k-step model delta (its FedAvg/MLitB-style
    contribution), stamped with the base version it trained from."""
    slot: int
    computed_at: int          # base model version the local run started from
    payload: Any              # (delta_params, delta_opt_state) | None in sim
    nbytes: int = 0
    loss: float = 0.0
    worker: str = ""
    n_steps: int = 0
    weight: float = 1.0


# task/result bodies that may ride inside protocol messages — registered with
# the wire codec in repro.core.protocol so they round-trip bytes by name
WIRE_TYPES = (MapTask, ReduceTask, LocalTask, GradResult, DeltaResult)
