"""Initiator — sets up the problem and enqueues the task graph (paper §IV.B,
§IV.F steps 0-1). The aggregation policy owns the work-unit schedule: SyncBSP
enqueues n_mb map tasks + 1 reduce task per batch (the paper's graph),
BoundedStaleness one gradient ticket per stream slot (no barriers), LocalSteps
one k-step ticket per averaging round. FIFO into the InitialQueue; the model's
version-0 blob into the DataServer."""
from __future__ import annotations

from typing import Optional

from repro.core.aggregation import PolicyLike, make_policy
from repro.core.dataserver import DataServer
from repro.core.mapreduce import TrainingProblem
from repro.core.queue import QueueServer
from repro.core.tasks import INITIAL_QUEUE


def enqueue_problem(problem: TrainingProblem, qs: QueueServer, ds: DataServer,
                    *, n_versions: Optional[int] = None,
                    policy: PolicyLike = None,
                    store_real_model: bool = True) -> int:
    """Returns the number of tasks enqueued."""
    pol = make_policy(policy)
    n = n_versions if n_versions is not None else problem.n_versions
    count = 0
    qs.declare(INITIAL_QUEUE)
    for task in pol.schedule(problem, n):
        qs.publish(INITIAL_QUEUE, task)
        count += 1
    blob = ((problem.params0, problem.opt_state0) if store_real_model else "v0")
    ds.publish_model(0, blob, nbytes=problem.model_bytes)
    return count
