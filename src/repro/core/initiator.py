"""Initiator — sets up the problem and enqueues the task graph (paper §IV.B,
§IV.F steps 0-1): n_mb map tasks + 1 reduce task per batch, FIFO into the
InitialQueue; the model's version-0 blob into the DataServer."""
from __future__ import annotations

from typing import Optional

from repro.core.dataserver import DataServer
from repro.core.mapreduce import TrainingProblem
from repro.core.queue import QueueServer
from repro.core.tasks import INITIAL_QUEUE, MapTask, ReduceTask


def enqueue_problem(problem: TrainingProblem, qs: QueueServer, ds: DataServer,
                    *, n_versions: Optional[int] = None,
                    store_real_model: bool = True) -> int:
    """Returns the number of tasks enqueued."""
    tp = problem.tp
    n = n_versions if n_versions is not None else problem.n_versions
    count = 0
    qs.declare(INITIAL_QUEUE)
    for v in range(n):
        e, b = problem.version_to_epoch_batch(v)
        for mb in range(tp.mini_batches_to_accumulate):
            qs.publish(INITIAL_QUEUE, MapTask(v, e, b, mb, tp.mini_batch_size))
            count += 1
        qs.publish(INITIAL_QUEUE,
                   ReduceTask(v, e, b, tp.mini_batches_to_accumulate))
        count += 1
    blob = ((problem.params0, problem.opt_state0) if store_real_model else "v0")
    ds.publish_model(0, blob, nbytes=problem.model_bytes)
    return count
