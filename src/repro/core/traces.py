"""Volunteer session traces: diurnal churn, heavy tails, device mixtures.

The paper's deployment observation is that volunteers are *people*: JSDoop's
users were online about 6.5 h/day, their browsers span phones to desktops,
and sessions end whenever a tab closes — seconds to hours, with a heavy
tail. A believable 100k–1M volunteer sweep (``benchmarks/browser_scale.py``)
therefore needs fleets shaped like that, not N identical always-on workers.

``generate_sessions`` turns a ``TraceParams`` into ``VolunteerSpec``s for
the Simulator — one spec per SESSION (vid ``d<i>s<j>``), because a device
that reconnects is, to the protocol, a fresh volunteer with the same
identity pattern the gateway's reconnect path exercises. The generative
model, per device:

- **device class** drawn from a speed mixture (mobile / laptop / desktop);
- **sessions** alternate with offline gaps. Gap lengths are exponential,
  scaled so the long-run duty cycle matches ``online_frac`` (the paper's
  6.5/24), and modulated by a sinusoidal **diurnal intensity**: gaps drawn
  at the trough of the day run ~``(1+amp)/(1-amp)`` times longer than at
  the peak, so arrivals bunch into "evening" hours;
- **session lengths** are lognormal (median ``session_median``, shape
  ``session_sigma``) — most sessions are short, a few run very long;
- **warm start**: each device's renewal process is simulated from a burn-in
  period BEFORE t=0 and only the [0, horizon) intersection is emitted (a
  session straddling 0 joins at 0), so the fleet opens in steady state —
  ~``online_frac`` of devices already online — instead of an empty cold
  start no real deployment snapshot would show.

Everything is seeded and pure: the same ``TraceParams`` yields the
bit-identical trace on every call (``random.Random`` per device, keyed on
``(seed, device)``), which the benchmark's determinism and the tests rely
on. The ``day`` period is compressible — benchmarks shrink a "day" to
minutes of virtual time so multi-day availability patterns fit in a run.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from repro.core.simulator import VolunteerSpec


@dataclass(frozen=True)
class DeviceClass:
    name: str
    speed: float                     # relative to CostModel.flops_per_sec
    weight: float                    # mixture probability (normalized)


# JSDoop Table 3's fleet in miniature: slow phones are the most common
# volunteer, desktops the fastest and rarest.
DEVICE_MIX: Tuple[DeviceClass, ...] = (
    DeviceClass("mobile", 0.3, 0.45),
    DeviceClass("laptop", 1.0, 0.35),
    DeviceClass("desktop", 2.2, 0.20),
)


@dataclass(frozen=True)
class TraceParams:
    n_devices: int                   # people, not sessions
    horizon: float                   # trace length (virtual seconds)
    day: float = 86_400.0            # diurnal period (compress for sims)
    online_frac: float = 6.5 / 24.0  # paper: users online ~6.5 h/day
    diurnal_amplitude: float = 0.6   # 0 = flat arrivals, ->1 = all at peak
    session_median: float = 1800.0   # median session length (s)
    session_sigma: float = 1.2       # lognormal shape: the heavy tail
    device_mix: Tuple[DeviceClass, ...] = DEVICE_MIX
    seed: int = 0


def _intensity(t: float, p: TraceParams, phase: float) -> float:
    """Arrival intensity at time ``t``: 1 +- amplitude over one day."""
    return 1.0 + p.diurnal_amplitude * math.sin(
        2.0 * math.pi * t / p.day + phase)


def _pick_device(rng: random.Random,
                 mix: Tuple[DeviceClass, ...]) -> DeviceClass:
    total = sum(d.weight for d in mix)
    x = rng.random() * total
    for d in mix:
        x -= d.weight
        if x <= 0:
            return d
    return mix[-1]


def generate_sessions(p: TraceParams) -> List[VolunteerSpec]:
    """The full fleet's sessions as simulator specs, sorted by join time."""
    if p.n_devices <= 0:
        raise ValueError("n_devices must be positive")
    if not 0.0 < p.online_frac < 1.0:
        raise ValueError("online_frac must be in (0, 1)")
    if not 0.0 <= p.diurnal_amplitude < 1.0:
        raise ValueError("diurnal_amplitude must be in [0, 1)")
    mu = math.log(p.session_median)
    mean_session = math.exp(mu + 0.5 * p.session_sigma ** 2)
    # long-run duty cycle f = mean_session / (mean_session + mean_gap)
    mean_gap = mean_session * (1.0 - p.online_frac) / p.online_frac
    specs: List[VolunteerSpec] = []
    for i in range(p.n_devices):
        # int seeding, not the tuple form: tuple seeds go through the
        # deprecated hash-based path (a warning per device at 1M devices)
        rng = random.Random((p.seed << 32) | i)
        device = _pick_device(rng, p.device_mix)
        # small per-device phase jitter: the population shares one "day"
        # (the diurnal signal is correlated) but people aren't synchronized
        # to the minute
        phase = rng.gauss(0.0, 0.35)
        # burn-in: run the renewal process from before t=0 so the window
        # opens in steady state (~online_frac of the fleet mid-session)
        burn = 3.0 * (mean_session + mean_gap)
        t = -burn + rng.random() * mean_gap   # stagger first arrivals
        j = 0
        while t < p.horizon:
            # thinning-style modulation: the mean gap stretches at the
            # trough of the day and shrinks at the peak
            gap = rng.expovariate(1.0 / mean_gap) / _intensity(t, p, phase)
            join = t + gap
            if join >= p.horizon:
                break
            length = rng.lognormvariate(mu, p.session_sigma)
            leave = min(join + length, p.horizon)
            t = join + length
            join = max(join, 0.0)             # clip the straddling session
            if leave > join:
                specs.append(VolunteerSpec(f"d{i}s{j}", speed=device.speed,
                                           join_time=join, leave_time=leave))
                j += 1
    specs.sort(key=lambda s: (s.join_time, s.vid))
    return specs


@dataclass
class TraceStats:
    n_devices: int
    n_sessions: int
    duty_cycle: float                # achieved online fraction of the fleet
    median_session: float
    p95_session: float
    peak_to_trough: float            # hourly join-rate max/min over the day
    speed_counts: Dict[float, int] = field(default_factory=dict)


def trace_stats(specs: List[VolunteerSpec], p: TraceParams) -> TraceStats:
    """Sanity metrics the tests (and benchmark logs) assert against."""
    if not specs:
        raise ValueError("empty trace")
    lengths = sorted(s.leave_time - s.join_time for s in specs)
    online = sum(lengths)
    devices = {s.vid.split("s")[0] for s in specs}
    # hourly (day/24 bucket) join counts, folded onto one day; sessions
    # clipped to the warm-start boundary (join 0.0) aren't real arrivals
    buckets = [0] * 24
    for s in specs:
        if s.join_time > 0.0:
            buckets[int((s.join_time % p.day) / p.day * 24)] += 1
    trough = max(min(buckets), 1)
    speed_counts: Dict[float, int] = {}
    for s in specs:
        speed_counts[s.speed] = speed_counts.get(s.speed, 0) + 1
    return TraceStats(
        n_devices=len(devices),
        n_sessions=len(specs),
        duty_cycle=online / (p.n_devices * p.horizon),
        median_session=lengths[len(lengths) // 2],
        p95_session=lengths[int(len(lengths) * 0.95)],
        peak_to_trough=max(buckets) / trough,
        speed_counts=speed_counts)
