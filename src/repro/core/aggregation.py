"""Pluggable aggregation semantics — the consistency model as a config axis.

The paper trains with exactly one consistency model: bulk-synchronous
map/reduce (one barrier per model version, §IV.G Fig. 3). Related
browser-training systems show the rest of the design space — MLitB trains via
periodic model averaging over heterogeneous volunteers, DistML.js evaluates
both synchronous and communication-reduced schemes, Hogwild/SSP-style systems
admit bounded-stale gradients — and which one wins depends on the volunteer
population. This module extracts that decision into one object, the
``AggregationPolicy``, consumed by every layer that used to hard-code it:

- **Initiator** (``enqueue_problem``): the policy emits the work-unit
  schedule — what tasks exist for a run of ``n_versions`` BSP-equivalent
  rounds. All three policies schedule the *same* global mini-batch stream
  (``n_versions x n_mb`` gradient computations), so cross-policy benchmarks
  compare equal work.
- **VolunteerSession** (``repro.core.protocol``): the policy decides the
  per-task protocol shape (barrier reduce vs barrierless fetch-latest ->
  compute -> admit/commit) and the admission rule for an arriving
  version-stamped result (``admit(computed_at, latest)``).
- **Engines** (Coordinator / Simulator / ChaosSimulator): the policy sets the
  run's commit target (``n_updates``) and which compute the engine must
  supply (one gradient, a reduce, or ``k`` local optimizer steps).

Three concrete policies:

- ``SyncBSP`` — the paper baseline. Schedule, admission and apply are
  bit-identical to the pre-policy code: ``n_mb`` map tasks + 1 reduce barrier
  per version; a result is admitted only while the model is still at its
  version; the reduce applies the mean gradient. Any Coordinator run
  bit-matches ``sequential_accumulated``.
- ``BoundedStaleness(s)`` — async SGD with a staleness bound (SSP-style): no
  reduce barrier; a volunteer fetches the *latest* model (version ``v``),
  computes one gradient, and the gradient is admitted while
  ``current - v <= s`` — applied immediately to the current model,
  publishing version ``current + 1``. Stale gradients are discarded and
  their ticket nacked for a fresh-version recompute.
- ``LocalSteps(k, weight)`` — MLitB/FedAvg-style communication reduction: a
  volunteer fetches the latest model, runs ``k`` local optimizer steps, and
  publishes the weighted model delta through the existing ``PublishModel``
  path (applied to the then-current model). An optional staleness bound
  gates admission like the async policy.

Every policy is deterministic given the engine's event order, so the chaos
metamorphic contract (sharded SimResult == single-server SimResult for any
seeded fault schedule) holds per policy, not just for the paper baseline.

``python -m repro.core.aggregation --smoke`` is the CI matrix: all three
policies on the reduced real problem, over in-process AND wire transports,
each checked against its sequential reference.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional, Union

from repro.core.tasks import LocalTask, MapTask, ReduceTask


class AggregationPolicy:
    """Base: schedule of work units, result admission, commit target.

    ``barrier`` is the session-level switch: barrier policies run the paper's
    map/reduce conversation; barrierless policies run fetch-latest ->
    compute -> admit/commit.
    """

    name: str = "base"
    barrier: bool = True

    # -- schedule ------------------------------------------------------------
    def n_updates(self, problem, n_versions: int) -> int:
        """Model versions a run of ``n_versions`` BSP rounds must commit."""
        raise NotImplementedError

    def schedule(self, problem, n_versions: int) -> Iterator:
        """The work units to enqueue, in FIFO order."""
        raise NotImplementedError

    # -- admission -----------------------------------------------------------
    def admit(self, computed_at: int, latest: int) -> bool:
        """May a result computed at model version ``computed_at`` still be
        applied while the current version is ``latest``?"""
        return True

    # -- description ---------------------------------------------------------
    @property
    def spec(self) -> str:
        return self.name

    def describe(self) -> dict:
        return {"policy": self.name, "spec": self.spec,
                "barrier": self.barrier}


@dataclass(frozen=True)
class SyncBSP(AggregationPolicy):
    """The paper's bulk-synchronous baseline (must bit-match
    ``sequential_accumulated`` — the schedule below IS the legacy enqueue
    order)."""

    name = "sync-bsp"
    barrier = True

    def n_updates(self, problem, n_versions: int) -> int:
        return n_versions

    def schedule(self, problem, n_versions: int):
        tp = problem.tp
        for v in range(n_versions):
            e, b = problem.version_to_epoch_batch(v)
            for mb in range(tp.mini_batches_to_accumulate):
                yield MapTask(v, e, b, mb, tp.mini_batch_size)
            yield ReduceTask(v, e, b, tp.mini_batches_to_accumulate)

    def admit(self, computed_at: int, latest: int) -> bool:
        # synchronous: a result is only usable while the model has not moved
        return latest <= computed_at

    @property
    def spec(self) -> str:
        return "sync"

    def describe(self) -> dict:
        return {**super().describe(), "staleness": 0,
                "guarantee": "bit-equal to sequential batch SGD"}


@dataclass(frozen=True)
class BoundedStaleness(AggregationPolicy):
    """Async SGD with an SSP-style staleness bound: one ticket per gradient,
    no reduce barrier, gradients older than ``staleness`` versions are
    discarded (their ticket requeues for a fresh recompute)."""

    staleness: int = 2

    name = "bounded-staleness"
    barrier = False

    def n_updates(self, problem, n_versions: int) -> int:
        return n_versions * problem.tp.mini_batches_to_accumulate

    def schedule(self, problem, n_versions: int):
        # the same global mini-batch stream as SyncBSP, minus the barriers:
        # ticket i covers stream slot i = (version i//n_mb, mini-batch i%n_mb)
        tp = problem.tp
        for v in range(n_versions):
            e, b = problem.version_to_epoch_batch(v)
            for mb in range(tp.mini_batches_to_accumulate):
                yield MapTask(v, e, b, mb, tp.mini_batch_size)

    def admit(self, computed_at: int, latest: int) -> bool:
        return (latest - computed_at) <= self.staleness

    @property
    def spec(self) -> str:
        return f"staleness:{self.staleness}"

    def describe(self) -> dict:
        return {**super().describe(), "staleness": self.staleness,
                "guarantee": f"async SGD, gradients at most "
                             f"{self.staleness} versions stale"}


@dataclass(frozen=True)
class LocalSteps(AggregationPolicy):
    """MLitB/FedAvg-style model averaging: one ticket = ``k`` local optimizer
    steps; the volunteer publishes its weighted model delta via PublishModel.
    ``staleness=None`` admits any delta (pure periodic averaging); an integer
    bound gates admission like the async policy."""

    k: int = 4
    weight: float = 1.0
    staleness: Optional[int] = None

    name = "local-steps"
    barrier = False

    def __post_init__(self):
        if self.k < 1:
            raise ValueError("LocalSteps needs k >= 1")

    def n_updates(self, problem, n_versions: int) -> int:
        total = n_versions * problem.tp.mini_batches_to_accumulate
        return -(-total // self.k)            # ceil: same total gradient work

    def schedule(self, problem, n_versions: int):
        tp = problem.tp
        for slot in range(self.n_updates(problem, n_versions)):
            yield LocalTask(slot, slot * self.k, self.k, tp.mini_batch_size)

    def admit(self, computed_at: int, latest: int) -> bool:
        if self.staleness is None:
            return True
        return (latest - computed_at) <= self.staleness

    @property
    def spec(self) -> str:
        w = "" if self.weight == 1.0 else f":{self.weight}"
        return f"local:{self.k}{w}"

    def describe(self) -> dict:
        return {**super().describe(),
                "staleness": ("unbounded" if self.staleness is None
                              else self.staleness),
                "guarantee": f"periodic model averaging, k={self.k} local "
                             f"steps, server weight {self.weight}"}


PolicyLike = Union[None, str, AggregationPolicy]


def make_policy(spec: PolicyLike) -> AggregationPolicy:
    """Resolve an engine's ``policy=`` argument: None -> the paper baseline;
    an ``AggregationPolicy`` instance passes through; strings parse as
    "sync" | "staleness:<s>" | "local:<k>[:<weight>]"."""
    if spec is None:
        return SyncBSP()
    if isinstance(spec, AggregationPolicy):
        return spec
    if isinstance(spec, str):
        parts = spec.strip().lower().split(":")
        head = parts[0]
        if head in ("sync", "bsp", "sync-bsp") and len(parts) == 1:
            return SyncBSP()
        if head in ("staleness", "async", "bounded-staleness"):
            if len(parts) == 1:
                return BoundedStaleness()
            if len(parts) == 2:
                return BoundedStaleness(staleness=int(parts[1]))
        if head in ("local", "local-steps") and 1 <= len(parts) <= 3:
            k = int(parts[1]) if len(parts) >= 2 else 4
            w = float(parts[2]) if len(parts) == 3 else 1.0
            return LocalSteps(k=k, weight=w)
    raise ValueError(f"unknown aggregation policy {spec!r} (want 'sync', "
                     f"'staleness:<s>', 'local:<k>[:<weight>]', or an "
                     f"AggregationPolicy instance)")


# ---------------------------------------------------------------------------
# CI smoke: 3 policies x 2 transports on the reduced real problem
# ---------------------------------------------------------------------------

def _bitmatch(a, b) -> bool:
    import jax
    import numpy as np
    return all(bool((np.asarray(x) == np.asarray(y)).all())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b),
                               strict=True))


def main(n_workers: int = 3) -> None:
    """CI smoke (ISSUE 4): for each policy x {inproc, wire}, a real
    Coordinator run on the reduced problem must (a) commit every scheduled
    update, (b) bit-match the policy's sequential reference, and (c) be
    transport-invariant. SyncBSP's reference is ``sequential_accumulated`` —
    the paper's Table-4 equality, now one row of a matrix."""
    from repro.configs.paper_lstm import TrainParams
    from repro.core.coordinator import Coordinator
    from repro.core.mapreduce import (TrainingProblem, sequential_accumulated,
                                      sequential_async, sequential_local)
    from repro.data.text import synthetic_corpus

    tp = TrainParams(batch_size=16, examples_per_epoch=64, num_epochs=1,
                     sample_len=20, mini_batch_size=4,
                     mini_batches_to_accumulate=4)
    problem = TrainingProblem.paper_problem(corpus=synthetic_corpus(6000),
                                            tp=tp)
    refs = {
        "sync": sequential_accumulated(problem)[0],
        "staleness:2": sequential_async(problem)[0],
        "local:4": sequential_local(problem, k=4)[0],
    }
    print("policy,transport,final_version,n_updates,tasks,stale_discards,"
          "bitmatch")
    for spec in ("sync", "staleness:2", "local:4"):
        policy = make_policy(spec)
        expected = policy.n_updates(problem, problem.n_versions)
        for transport in ("inproc", "wire"):
            res = Coordinator(problem, n_workers=n_workers, policy=policy,
                              transport=transport).run()
            ok = _bitmatch(res.params, refs[spec])
            print(f"aggregation_smoke,{spec},{transport},{res.final_version},"
                  f"{expected},{sum(res.tasks_by_worker.values())},"
                  f"{res.stale_discards},{ok}")
            assert res.final_version == expected, (spec, transport,
                                                   res.final_version)
            assert ok, f"{spec}/{transport} diverged from the sequential ref"
    print(f"# OK: 3-policy x 2-transport matrix green — every policy "
          f"commits its full schedule and bit-matches its sequential "
          f"reference with {n_workers} volunteers")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="run the 3-policy x 2-transport matrix")
    ap.add_argument("--workers", type=int, default=3)
    args = ap.parse_args()
    if not args.smoke:
        ap.error("nothing to do: pass --smoke to run the policy matrix")
    # run through the canonical module instance, not the __main__ copy, so
    # the policy classes here are the ones the engines isinstance-check
    from repro.core import aggregation as _canonical
    _canonical.main(n_workers=args.workers)
