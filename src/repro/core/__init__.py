"""The paper's primary contribution: the JSDoop volunteer map-reduce runtime."""
from repro.core.queue import (  # noqa: F401
    Queue, QueueServer, ShardedQueueServer, colocate_results,
)
from repro.core.dataserver import DataServer  # noqa: F401
from repro.core.tasks import (  # noqa: F401
    INITIAL_QUEUE, MapTask, ReduceTask, LocalTask, GradResult, DeltaResult,
    results_queue,
)
from repro.core.aggregation import (  # noqa: F401
    AggregationPolicy, SyncBSP, BoundedStaleness, LocalSteps, make_policy,
)
from repro.core.mapreduce import (  # noqa: F401
    TrainingProblem, sequential_accumulated, sequential_async,
    sequential_fullbatch, sequential_local,
)
from repro.core.initiator import enqueue_problem  # noqa: F401
from repro.core.protocol import (  # noqa: F401
    ServerEndpoint, VolunteerSession, encode_message, decode_message,
    wire_size,
)
from repro.core.transport import (  # noqa: F401
    Transport, InProcessTransport, WireTransport, FaultyTransport, FaultSpec,
    make_transport,
)
from repro.core.coordinator import Coordinator, RunResult  # noqa: F401
from repro.core.simulator import (  # noqa: F401
    Simulator, SimResult, VolunteerSpec, CostModel, TimelineEvent,
    SyntheticProblem,
)
