"""Browser-tier thin volunteer — the paper's design point as a client shape.

JSDoop's volunteers are web pages: they arrive over WebSocket, lease work,
fetch the latest model, and push one small gradient per task — they never
upload a full model, because a browser tab on hotel wifi cannot pay the
model push per update (MLitB's thin-client stance; the server-side applier
PR 5 built is the other half of that contract).

``BrowserClient`` is that volunteer: ``WsClientTransport`` (RFC 6455
framing, the only dialect a browser's ``WebSocket`` object speaks) driving
the stock ``run_volunteer`` loop under a **barrierless** policy, so every
commit rides one ``SubmitUpdate`` frame. The thin-client contract is
enforced twice:

- at construction: a barrier policy (sync BSP) is refused outright — it
  would require the volunteer to fetch-at-admission and push the reduced
  model, exactly the bytes a browser must not pay;
- after the run: the transport's request histogram must contain ZERO
  ``PublishModel`` frames, or ``run()`` raises.

``python -m repro.core.browser --port P --policy staleness:2`` is the CLI
used by the gateway's ``--smoke`` browser leg and the README quickstart.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional, Tuple

from repro.core.aggregation import PolicyLike, make_policy
from repro.core.gateway import WsClientTransport, run_volunteer


class BrowserClient:
    """A browser-shaped volunteer: WebSocket framing, barrierless policy,
    zero model pushes — lease, fetch-latest, ``SubmitUpdate``, repeat."""

    def __init__(self, host: str, port: int, vid: str, *,
                 policy: PolicyLike, connect_timeout: float = 10.0,
                 task_delay: float = 0.0):
        self.policy = make_policy(policy)
        if self.policy.barrier:
            raise ValueError(
                f"BrowserClient needs a barrierless policy (staleness:<s> "
                f"or local:<k>), got {self.policy.spec!r}: a barrier policy "
                f"makes the volunteer push reduced models, which the "
                f"browser tier never does")
        self.vid = vid
        self.task_delay = task_delay
        self.transport = WsClientTransport(host, port, vid,
                                           connect_timeout=connect_timeout)

    def run(self, n_updates: int) -> Tuple[int, int]:
        """Volunteer until the run reaches ``n_updates`` committed versions.
        Returns (final_version, tasks_done); raises if the thin-client
        contract was broken (any PublishModel frame on the wire)."""
        final, tasks = run_volunteer(
            self.transport, self.vid, n_updates, policy=self.policy,
            task_delay=self.task_delay)
        pushed = self.transport.sent.get("PublishModel", 0)
        if pushed:
            raise RuntimeError(
                f"browser thin-client contract broken: {pushed} "
                f"PublishModel frame(s) sent ({self.transport.sent})")
        return final, tasks

    def close(self) -> None:
        self.transport.close()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--vid", default="browser0")
    ap.add_argument("--policy", default="staleness:2",
                    help="barrierless only: staleness:<s> | local:<k>")
    ap.add_argument("--n-versions", type=int, default=4)
    ap.add_argument("--n-mb", type=int, default=6)
    ap.add_argument("--task-delay", type=float, default=0.0)
    ap.add_argument("--expect-final", type=int, default=None)
    args = ap.parse_args(argv)
    from repro.core.simulator import SyntheticProblem
    problem = SyntheticProblem(n_versions=args.n_versions, n_mb=args.n_mb)
    policy = make_policy(args.policy)
    n_updates = policy.n_updates(problem, args.n_versions)
    client = BrowserClient(args.host, args.port, args.vid, policy=policy,
                           task_delay=args.task_delay)
    try:
        final, tasks = client.run(n_updates)
    finally:
        client.close()
    sent = dict(client.transport.sent)
    print(f"browser {args.vid} [ws]: final_version={final} tasks={tasks} "
          f"submit_updates={sent.get('SubmitUpdate', 0)} "
          f"publish_models={sent.get('PublishModel', 0)}", flush=True)
    if args.expect_final is not None and final != args.expect_final:
        print(f"FAIL: expected final_version={args.expect_final}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
