"""Real-JAX server-side applier: the gateway-hosted half of the DistML.js
split (thin browser clients push contributions; the parameter server owns the
optimizer step).

The applier OWNS its hot model/optimizer state and never re-reads it from the
DataServer: within one server process the blob stored for version v and the
applier's state at version v are the same values, and ownership is what makes
buffer donation legal — ``apply_batch_flat(donate=True)`` reuses the carry
buffers in place, which would destroy a DataServer-stored blob for every
later reader.

Two modes:

* ``batch=False`` — the pre-batching baseline: pytree ``apply_one`` /
  ``apply_delta`` per update (no donation; published blobs are the fresh
  output pytrees). ``benchmarks/applier_bench.py`` measures this as
  "single-dispatch".
* ``batch=True`` — the fast path: flat donated ``lax.scan`` chains a whole
  admitted drain in ONE jitted dispatch, and every intermediate version is
  published as a ``LazyModelBlob`` that unflattens only if somebody actually
  fetches it (most intermediate versions are GC'd unseen, and eagerly
  unflattening each one would cost more than the batching saves).

Bit-exactness of the two modes — and of any drain split — is the contract
tests/test_applier.py enforces against the ``sequential_async`` /
``sequential_local`` references.
"""
from __future__ import annotations

from typing import Any, List, Optional

from repro.core.protocol import ModelBlob, ServerApplier, wire_size
from repro.core.tasks import GradResult


class LazyModelBlob:
    """A published model version materialized on first access.

    The batched applier publishes B intermediate versions per drain as views
    into the scan's stacked per-step outputs; ``materialize()`` slices and
    unflattens exactly once, caching the pytree. ``ServerEndpoint`` serves
    ``FetchModel`` with the materialized value and ``DataServer.snapshot``
    solidifies stored blobs, so laziness never crosses the wire or lands in
    a checkpoint."""

    __slots__ = ("_thunk", "_value")

    def __init__(self, thunk):
        self._thunk = thunk
        self._value = None

    def materialize(self):
        if self._thunk is not None:
            self._value = self._thunk()
            self._thunk = None
        return self._value


class RealApplier:
    """Backend state for a real-JAX ``ServerApplier`` (see module docstring).

    Exposed as ``ServerApplier.backend`` by ``make_real_applier``; the
    gateway uses ``reseed`` after a snapshot restore to re-anchor the hot
    state on the restored latest blob."""

    def __init__(self, problem, *, batch: bool = True):
        self.problem = problem
        self.batch = bool(batch) and problem.supports_flat_apply
        self.version = 0
        self._nbytes: Optional[int] = None
        if self.batch:
            self._carry = problem.flat_carry(problem.params0,
                                             problem.opt_state0)
        else:
            self._params = problem.params0
            self._opt_state = problem.opt_state0

    # --------------------------------------------------------------- hooks
    def apply(self, blob, result, version: int):
        return self._advance([result], version)[0]

    def apply_batch(self, blob, results: List[Any],
                    base_version: int) -> List[Any]:
        return self._advance(results, base_version)

    def measure(self, blob) -> int:
        """Encoded size of a published blob as a ``ModelBlob`` reply would
        carry it. The serialized size is a pure function of array shapes and
        dtypes (raw buffer bytes + fixed headers), so one measurement covers
        every version of the same model."""
        if self._nbytes is None:
            mat = (blob.materialize() if isinstance(blob, LazyModelBlob)
                   else blob)
            self._nbytes = wire_size(ModelBlob(0, True, mat))
        return self._nbytes

    # --------------------------------------------------------------- state
    def reseed(self, blob, version: int) -> None:
        """Re-anchor the hot state on ``blob`` at ``version`` (snapshot
        restore: the DataServer's latest blob becomes the applier's truth)."""
        p, s = (blob.materialize() if isinstance(blob, LazyModelBlob)
                else blob)
        if self.batch:
            self._carry = self.problem.flat_carry(p, s)
        else:
            self._params, self._opt_state = p, s
        self.version = version

    def _advance(self, results: List[Any], base_version: int) -> List[Any]:
        """Apply a homogeneous admitted run (the endpoint segments drains by
        result type) and return the successive post-update blobs."""
        if base_version != self.version:
            raise ValueError(
                f"applier state is at version {self.version} but the "
                f"endpoint is applying onto {base_version} — the applier "
                f"must be the only writer of model versions")
        prob = self.problem
        blobs: List[Any] = []
        if not self.batch:
            p, s = self._params, self._opt_state
            for r in results:
                if isinstance(r, GradResult):
                    p, s = prob.apply_one(p, s, r.payload)
                else:
                    p, s = prob.apply_delta(p, s, r.payload, r.weight)
                blobs.append((p, s))
            self._params, self._opt_state = p, s
        elif isinstance(results[0], GradResult):
            rows = prob.pack_grad_rows([r.payload for r in results])
            self._carry, steps = prob.apply_batch_flat(self._carry, rows,
                                                       donate=True)
            for i in range(len(results)):
                blobs.append(LazyModelBlob(
                    lambda i=i: prob.unflatten_step(steps, i)))
        else:
            # LocalSteps deltas: weighted pytree adds, chained eagerly (the
            # delta path is model-transfer-bound, not dispatch-bound); the
            # repack below copies, so the published pytrees stay valid
            p, s = prob.unflatten_carry(self._carry)
            for r in results:
                p, s = prob.apply_delta(p, s, r.payload, r.weight)
                blobs.append((p, s))
            self._carry = prob.flat_carry(p, s)
        self.version += len(results)
        return blobs


def make_real_applier(problem, policy, *, batch: bool = True,
                      gc_keep: Optional[int] = None) -> ServerApplier:
    """A ``ServerApplier`` serving REAL JAX applies for ``problem``.

    The caller must have published ``(problem.params0, problem.opt_state0)``
    as model version 0 (``enqueue_problem(store_real_model=True)`` does), and
    the returned applier must be the only writer of later versions. The
    backend rides along as ``applier.backend`` (for ``reseed`` and tests)."""
    backend = RealApplier(problem, batch=batch)
    applier = ServerApplier(
        policy, backend.apply, gc_keep=gc_keep,
        measure=backend.measure,
        apply_batch=backend.apply_batch if backend.batch else None)
    applier.backend = backend
    return applier
