"""QueueServer — AMQP-style named queues with at-least-once delivery.

Mirrors the semantics JSDoop gets from RabbitMQ (paper §IV.D/§IV.F step 5):

- ``publish`` appends a message.
- ``lease`` hands a message to a consumer WITHOUT removing it: the message moves
  to the in-flight table with a visibility deadline ("the Initiator can set a
  maximum time to solve a task").
- ``ack`` removes it permanently ("tasks are not removed from the queue until an
  ACK is received").
- ``expire``/``drop_consumer`` requeue in-flight messages whose deadline passed
  or whose consumer disconnected ("if a volunteer disconnects while solving a
  task, the task is added back to the queue").
- ``subscribe`` registers a one-shot waiter: the next publish or requeue wakes
  exactly one registered waiter (FIFO), replacing client-side polling. This is
  the push/notify coordination Pando and DistML.js use to scale volunteer
  computing beyond a handful of browsers.

Time is explicit (virtual): both the real coordinator (logical step clock) and
the discrete-event simulator (seconds) drive the same implementation.

``ShardedQueueServer`` federates K ``QueueServer`` instances behind the same
API, routing queue names with consistent hashing — the paper's §IV observation
that "it is possible to use several QueueServers in which each one stores a
different type of task", made concrete as a load-balanced hash ring.
"""
from __future__ import annotations

import bisect
import hashlib
import heapq
import itertools
import math
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple


@dataclass
class _InFlight:
    body: Any
    consumer: str
    deadline: float
    requeues: int


class Queue:
    def __init__(self, name: str, default_timeout: float = float("inf")):
        self.name = name
        self.default_timeout = default_timeout
        self._pending: deque = deque()            # (tag, body)
        self._in_flight: Dict[int, _InFlight] = {}
        self._tags = itertools.count()
        # expiry index: (deadline, tag) min-heap; entries go stale when a tag is
        # acked or re-leased — validated lazily against the in-flight table.
        self._deadlines: List[Tuple[float, int]] = []
        # one-shot waiters. "any" wakes on publish OR requeue (a task became
        # leasable); "publish" wakes on publish only (new data arrived — the
        # reduce-barrier watcher, which must not be woken by its own nacks).
        self._waiters: deque = deque()            # (consumer, callback)
        self._pub_waiters: deque = deque()
        self._signal = False                      # event arrived with no waiter
        self._pub_signal = False
        self.published = 0
        self.acked = 0
        self.requeued = 0
        self.wakeups = 0

    # -- producer ------------------------------------------------------------
    def publish(self, body: Any) -> int:
        tag = next(self._tags)
        self._pending.append((tag, body))
        self.published += 1
        self._notify(publish=True)
        return tag

    # -- consumer ------------------------------------------------------------
    def lease(self, consumer: str, now: float,
              timeout: Optional[float] = None) -> Optional[Tuple[int, Any]]:
        if not self._pending:
            return None
        tag, body = self._pending.popleft()
        t = self.default_timeout if timeout is None else timeout
        deadline = now + t
        self._in_flight[tag] = _InFlight(body, consumer, deadline, 0)
        if math.isfinite(deadline):
            heapq.heappush(self._deadlines, (deadline, tag))
        return tag, body

    def ack(self, tag: int) -> bool:
        if tag in self._in_flight:
            del self._in_flight[tag]
            self.acked += 1
            return True
        return False

    def nack(self, tag: int, *, front: bool = True) -> bool:
        """Voluntary give-back (e.g. dependency not ready)."""
        inf = self._in_flight.pop(tag, None)
        if inf is None:
            return False
        if front:
            self._pending.appendleft((tag, inf.body))
        else:
            self._pending.append((tag, inf.body))
        self.requeued += 1
        self._notify(publish=False)
        return True

    # -- subscriptions ---------------------------------------------------------
    def subscribe(self, consumer: str, callback: Callable[[], None], *,
                  kind: str = "any") -> None:
        """Register a one-shot waiter. The next publish (or, for kind="any",
        requeue) wakes exactly ONE waiter in FIFO order. If an event already
        arrived while nobody was waiting, the callback fires immediately —
        a spurious wake at worst; waiters re-check queue state on wake, so the
        check-then-subscribe pattern is lossless under this single-threaded
        virtual clock."""
        if kind not in ("any", "publish"):
            raise ValueError(f"unknown subscription kind {kind!r}")
        if kind == "publish":
            if self._pub_signal:
                self._pub_signal = False
                self.wakeups += 1
                callback()
            else:
                self._pub_waiters.append((consumer, callback))
            return
        if self._signal:
            self._signal = False
            self.wakeups += 1
            callback()
        else:
            self._waiters.append((consumer, callback))

    def unsubscribe(self, consumer: str) -> int:
        """Remove every waiter registered by this consumer (volunteer left)."""
        n = len(self._waiters) + len(self._pub_waiters)
        self._waiters = deque((c, cb) for c, cb in self._waiters
                              if c != consumer)
        self._pub_waiters = deque((c, cb) for c, cb in self._pub_waiters
                                  if c != consumer)
        return n - len(self._waiters) - len(self._pub_waiters)

    def kick(self) -> None:
        """Hand a consumed wake to the next waiter — used when a woken consumer
        turns out to have left and cannot serve the event it was woken for."""
        self._notify(publish=False)

    def _notify(self, *, publish: bool) -> None:
        if self._waiters:
            _, cb = self._waiters.popleft()
            self.wakeups += 1
            cb()
        else:
            self._signal = True
        if publish:
            if self._pub_waiters:
                _, cb = self._pub_waiters.popleft()
                self.wakeups += 1
                cb()
            else:
                self._pub_signal = True

    # -- fault tolerance -------------------------------------------------------
    def expire(self, now: float) -> int:
        """Requeue every in-flight message whose visibility deadline passed.
        Amortized O(expired) via the deadline heap (stale entries skipped)."""
        n = 0
        while self._deadlines and self._deadlines[0][0] <= now:
            _, tag = heapq.heappop(self._deadlines)
            inf = self._in_flight.get(tag)
            if inf is not None and inf.deadline <= now:
                self.nack(tag, front=True)
                n += 1
        return n

    def next_deadline(self) -> Optional[float]:
        """Earliest live visibility deadline, or None."""
        while self._deadlines:
            dl, tag = self._deadlines[0]
            inf = self._in_flight.get(tag)
            if inf is not None and inf.deadline == dl:
                return dl
            heapq.heappop(self._deadlines)
        return None

    def drop_consumer(self, consumer: str) -> int:
        """A volunteer closed the browser: requeue everything it held."""
        held = [t for t, inf in self._in_flight.items() if inf.consumer == consumer]
        for t in held:
            self.nack(t, front=True)
        return len(held)

    # -- introspection ---------------------------------------------------------
    @property
    def depth(self) -> int:
        return len(self._pending)

    @property
    def in_flight(self) -> int:
        return len(self._in_flight)

    @property
    def drained(self) -> bool:
        return not self._pending and not self._in_flight

    @property
    def waiters(self) -> int:
        return len(self._waiters) + len(self._pub_waiters)

    def peek_all(self) -> List[Any]:
        return [b for _, b in self._pending]


class QueueServer:
    """Named queues. Multiple QueueServers are modelled by multiple instances
    (the paper's load-balancing story — see ShardedQueueServer); the API is
    identical."""

    def __init__(self, default_timeout: float = float("inf")):
        self.default_timeout = default_timeout
        self.queues: Dict[str, Queue] = {}

    def declare(self, name: str, timeout: Optional[float] = None) -> Queue:
        if name not in self.queues:
            self.queues[name] = Queue(
                name, self.default_timeout if timeout is None else timeout)
        return self.queues[name]

    def publish(self, qname: str, body: Any) -> int:
        return self.declare(qname).publish(body)

    def lease(self, qname: str, consumer: str, now: float,
              timeout: Optional[float] = None):
        return self.declare(qname).lease(consumer, now, timeout)

    def ack(self, qname: str, tag: int) -> bool:
        return self.declare(qname).ack(tag)

    def nack(self, qname: str, tag: int, *, front: bool = True) -> bool:
        return self.declare(qname).nack(tag, front=front)

    def subscribe(self, qname: str, consumer: str,
                  callback: Callable[[], None], *, kind: str = "any") -> None:
        self.declare(qname).subscribe(consumer, callback, kind=kind)

    def unsubscribe(self, consumer: str) -> int:
        return sum(q.unsubscribe(consumer) for q in self.queues.values())

    def kick(self, qname: str) -> None:
        self.declare(qname).kick()

    def expire_all(self, now: float) -> int:
        return sum(q.expire(now) for q in self.queues.values())

    def next_deadline(self) -> Optional[float]:
        dls = [d for d in (q.next_deadline() for q in self.queues.values())
               if d is not None]
        return min(dls) if dls else None

    def drop_consumer(self, consumer: str) -> int:
        return sum(q.drop_consumer(consumer) for q in self.queues.values())

    def drained(self, names: Optional[Iterable[str]] = None) -> bool:
        qs = (self.queues[n] for n in names if n in self.queues) if names \
            else self.queues.values()
        return all(q.drained for q in qs)

    def depth(self, qname: str) -> int:
        return self.declare(qname).depth

    @property
    def total_requeued(self) -> int:
        return sum(q.requeued for q in self.queues.values())

    @property
    def total_wakeups(self) -> int:
        return sum(q.wakeups for q in self.queues.values())


def _stable_hash(key: str) -> int:
    """Process-independent 64-bit hash (Python's str hash is salted)."""
    return int.from_bytes(
        hashlib.blake2b(key.encode(), digest_size=8).digest(), "big")


class ShardedQueueServer:
    """K federated QueueServer instances behind the QueueServer API.

    Queue names route to shards via a consistent-hash ring with virtual nodes,
    so (a) load spreads evenly over the federation and (b) adding/removing a
    shard remaps only ~1/K of the queue names — the standard scaling story for
    the paper's "several QueueServers" deployment. Every per-queue operation is
    a pure delegation to the owning shard, so federation is semantics-invisible
    (asserted by tests: a sharded run bit-matches a single-server run).
    """

    def __init__(self, n_shards: int, default_timeout: float = float("inf"),
                 *, vnodes: int = 64):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.shards: List[QueueServer] = [
            QueueServer(default_timeout) for _ in range(n_shards)]
        self.default_timeout = default_timeout
        ring: List[Tuple[int, int]] = []
        for i in range(n_shards):
            for r in range(vnodes):
                ring.append((_stable_hash(f"qshard-{i}#{r}"), i))
        ring.sort()
        self._ring_keys = [h for h, _ in ring]
        self._ring_vals = [i for _, i in ring]

    def shard_of(self, qname: str) -> int:
        """Index of the shard owning this queue name (clockwise successor)."""
        h = _stable_hash(qname)
        i = bisect.bisect_right(self._ring_keys, h) % len(self._ring_keys)
        return self._ring_vals[i]

    def route(self, qname: str) -> QueueServer:
        return self.shards[self.shard_of(qname)]

    # -- per-queue ops: delegate to the owning shard ---------------------------
    def declare(self, name: str, timeout: Optional[float] = None) -> Queue:
        return self.route(name).declare(name, timeout)

    def publish(self, qname: str, body: Any) -> int:
        return self.route(qname).publish(qname, body)

    def lease(self, qname: str, consumer: str, now: float,
              timeout: Optional[float] = None):
        return self.route(qname).lease(qname, consumer, now, timeout)

    def ack(self, qname: str, tag: int) -> bool:
        return self.route(qname).ack(qname, tag)

    def nack(self, qname: str, tag: int, *, front: bool = True) -> bool:
        return self.route(qname).nack(qname, tag, front=front)

    def subscribe(self, qname: str, consumer: str,
                  callback: Callable[[], None], *, kind: str = "any") -> None:
        self.route(qname).subscribe(qname, consumer, callback, kind=kind)

    def kick(self, qname: str) -> None:
        self.route(qname).kick(qname)

    def depth(self, qname: str) -> int:
        return self.route(qname).depth(qname)

    # -- federation-wide ops ---------------------------------------------------
    def unsubscribe(self, consumer: str) -> int:
        return sum(s.unsubscribe(consumer) for s in self.shards)

    def expire_all(self, now: float) -> int:
        return sum(s.expire_all(now) for s in self.shards)

    def next_deadline(self) -> Optional[float]:
        dls = [d for d in (s.next_deadline() for s in self.shards)
               if d is not None]
        return min(dls) if dls else None

    def drop_consumer(self, consumer: str) -> int:
        return sum(s.drop_consumer(consumer) for s in self.shards)

    def drained(self, names: Optional[Iterable[str]] = None) -> bool:
        if names:
            return all(self.route(n).drained([n]) for n in names)
        return all(s.drained() for s in self.shards)

    @property
    def queues(self) -> Dict[str, Queue]:
        """Merged read-only view over all shards (names are unique: each queue
        lives on exactly one shard)."""
        merged: Dict[str, Queue] = {}
        for s in self.shards:
            merged.update(s.queues)
        return merged

    @property
    def total_requeued(self) -> int:
        return sum(s.total_requeued for s in self.shards)

    @property
    def total_wakeups(self) -> int:
        return sum(s.total_wakeups for s in self.shards)

    def shard_loads(self) -> List[int]:
        """Queues per shard — the load-balance observable."""
        return [len(s.queues) for s in self.shards]
