"""QueueServer — AMQP-style named queues with at-least-once delivery.

Mirrors the semantics JSDoop gets from RabbitMQ (paper §IV.D/§IV.F step 5):

- ``publish`` appends a message.
- ``lease`` hands a message to a consumer WITHOUT removing it: the message moves
  to the in-flight table with a visibility deadline ("the Initiator can set a
  maximum time to solve a task").
- ``ack`` removes it permanently ("tasks are not removed from the queue until an
  ACK is received").
- ``expire``/``drop_consumer`` requeue in-flight messages whose deadline passed
  or whose consumer disconnected ("if a volunteer disconnects while solving a
  task, the task is added back to the queue").

Time is explicit (virtual): both the real coordinator (logical step clock) and
the discrete-event simulator (seconds) drive the same implementation.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Tuple


@dataclass
class _InFlight:
    body: Any
    consumer: str
    deadline: float
    requeues: int


class Queue:
    def __init__(self, name: str, default_timeout: float = float("inf")):
        self.name = name
        self.default_timeout = default_timeout
        self._pending: deque = deque()            # (tag, body)
        self._in_flight: Dict[int, _InFlight] = {}
        self._tags = itertools.count()
        self.published = 0
        self.acked = 0
        self.requeued = 0

    # -- producer ------------------------------------------------------------
    def publish(self, body: Any) -> int:
        tag = next(self._tags)
        self._pending.append((tag, body))
        self.published += 1
        return tag

    # -- consumer ------------------------------------------------------------
    def lease(self, consumer: str, now: float,
              timeout: Optional[float] = None) -> Optional[Tuple[int, Any]]:
        if not self._pending:
            return None
        tag, body = self._pending.popleft()
        t = self.default_timeout if timeout is None else timeout
        self._in_flight[tag] = _InFlight(body, consumer, now + t, 0)
        return tag, body

    def ack(self, tag: int) -> bool:
        if tag in self._in_flight:
            del self._in_flight[tag]
            self.acked += 1
            return True
        return False

    def nack(self, tag: int, *, front: bool = True) -> bool:
        """Voluntary give-back (e.g. dependency not ready)."""
        inf = self._in_flight.pop(tag, None)
        if inf is None:
            return False
        if front:
            self._pending.appendleft((tag, inf.body))
        else:
            self._pending.append((tag, inf.body))
        self.requeued += 1
        return True

    # -- fault tolerance -------------------------------------------------------
    def expire(self, now: float) -> int:
        """Requeue every in-flight message whose visibility deadline passed."""
        dead = [t for t, inf in self._in_flight.items() if inf.deadline <= now]
        for t in dead:
            self.nack(t, front=True)
        return len(dead)

    def drop_consumer(self, consumer: str) -> int:
        """A volunteer closed the browser: requeue everything it held."""
        held = [t for t, inf in self._in_flight.items() if inf.consumer == consumer]
        for t in held:
            self.nack(t, front=True)
        return len(held)

    # -- introspection ---------------------------------------------------------
    @property
    def depth(self) -> int:
        return len(self._pending)

    @property
    def in_flight(self) -> int:
        return len(self._in_flight)

    @property
    def drained(self) -> bool:
        return not self._pending and not self._in_flight

    def peek_all(self) -> List[Any]:
        return [b for _, b in self._pending]


class QueueServer:
    """Named queues. Multiple QueueServers are modelled by multiple instances
    (the paper's load-balancing story); the API is identical."""

    def __init__(self, default_timeout: float = float("inf")):
        self.default_timeout = default_timeout
        self.queues: Dict[str, Queue] = {}

    def declare(self, name: str, timeout: Optional[float] = None) -> Queue:
        if name not in self.queues:
            self.queues[name] = Queue(
                name, self.default_timeout if timeout is None else timeout)
        return self.queues[name]

    def publish(self, qname: str, body: Any) -> int:
        return self.declare(qname).publish(body)

    def lease(self, qname: str, consumer: str, now: float,
              timeout: Optional[float] = None):
        return self.declare(qname).lease(consumer, now, timeout)

    def ack(self, qname: str, tag: int) -> bool:
        return self.declare(qname).ack(tag)

    def nack(self, qname: str, tag: int, *, front: bool = True) -> bool:
        return self.declare(qname).nack(tag, front=front)

    def expire_all(self, now: float) -> int:
        return sum(q.expire(now) for q in self.queues.values())

    def drop_consumer(self, consumer: str) -> int:
        return sum(q.drop_consumer(consumer) for q in self.queues.values())

    def drained(self, names: Optional[Iterable[str]] = None) -> bool:
        qs = (self.queues[n] for n in names) if names else self.queues.values()
        return all(q.drained for q in qs)

    def depth(self, qname: str) -> int:
        return self.declare(qname).depth
