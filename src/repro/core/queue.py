"""QueueServer — AMQP-style named queues with at-least-once delivery.

Mirrors the semantics JSDoop gets from RabbitMQ (paper §IV.D/§IV.F step 5):

- ``publish`` appends a message.
- ``lease`` hands a message to a consumer WITHOUT removing it: the message moves
  to the in-flight table with a visibility deadline ("the Initiator can set a
  maximum time to solve a task").
- ``ack`` removes it permanently ("tasks are not removed from the queue until an
  ACK is received").
- ``expire``/``drop_consumer`` requeue in-flight messages whose deadline passed
  or whose consumer disconnected ("if a volunteer disconnects while solving a
  task, the task is added back to the queue").
- ``subscribe`` registers a one-shot waiter: the next publish or requeue wakes
  exactly one registered waiter (FIFO), replacing client-side polling. This is
  the push/notify coordination Pando and DistML.js use to scale volunteer
  computing beyond a handful of browsers.

Time is explicit (virtual): both the real coordinator (logical step clock) and
the discrete-event simulator (seconds) drive the same implementation.

``ShardedQueueServer`` federates K ``QueueServer`` instances behind the same
API, routing queue names with consistent hashing — the paper's §IV observation
that "it is possible to use several QueueServers in which each one stores a
different type of task", made concrete as a load-balanced hash ring. The
federation is *elastic*: ``add_shard()`` / ``remove_shard(i)`` recompute the
ring and migrate the full live state of every remapped queue (pending FIFO,
in-flight table + deadlines, banked signals, registered waiters, counters), so
a rebalance is invisible to consumers except that ~1/K of queue names change
owner. Cross-queue side-effect order (expiry requeues, consumer drops) is
defined by (deadline, queue-name) / queue-name, NOT by shard layout, so a
sharded run is bit-identical to a single-server run — asserted by the chaos
metamorphic suite (``repro.core.chaos``).
"""
from __future__ import annotations

import bisect
import hashlib
import heapq
import math
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple


class LeaseClock:
    """Time source for visibility deadlines.

    The queue semantics are clock-agnostic: ``lease(now)`` stamps a deadline
    and ``expire_all(now)`` enforces it, for whatever ``now`` means. The
    engines own virtual clocks (the Simulator's event time, the Coordinator's
    logical step count); a real deployment owns wall time. ``LeaseClock``
    names that choice so a server endpoint — and the gateway's sweeper thread
    — can ask "what time is it for lease purposes?" without knowing which
    regime it runs under.
    """

    def now(self) -> float:
        raise NotImplementedError


class WallClock(LeaseClock):
    """Real deployments: visibility deadlines are wall-clock seconds
    (monotonic, so a system clock step cannot mass-expire leases)."""

    def now(self) -> float:
        return time.monotonic()


class VirtualClock(LeaseClock):
    """Engines: deadlines live on the engine's own virtual/logical clock.
    Wraps a zero-arg callable (e.g. ``lambda: sim._now``) so the clock always
    reads the engine's current instant, never a stale copy."""

    def __init__(self, read: Callable[[], float]):
        self._read = read

    def now(self) -> float:
        return self._read()


@dataclass
class _InFlight:
    body: Any
    consumer: str
    deadline: float
    requeues: int


class Queue:
    def __init__(self, name: str, default_timeout: float = float("inf")):
        self.name = name
        self.default_timeout = default_timeout
        self._pending: deque = deque()            # (tag, body)
        self._in_flight: Dict[int, _InFlight] = {}
        self._next_tag = 0                        # plain int: snapshotable
        # owning QueueServer's deadline index hook (set by declare/attach):
        # called with (qname, deadline) whenever a finite deadline is created,
        # so the server can skip expiry scans until something can have expired.
        self._server_note: Optional[Callable[[str, float], None]] = None
        # expiry index: (deadline, tag) min-heap; entries go stale when a tag is
        # acked or re-leased — validated lazily against the in-flight table.
        self._deadlines: List[Tuple[float, int]] = []
        # one-shot waiters. "any" wakes on publish OR requeue (a task became
        # leasable); "publish" wakes on publish only (new data arrived — the
        # reduce-barrier watcher, which must not be woken by its own nacks).
        # At most ONE live waiter per consumer per kind: a re-subscribe while
        # the previous waiter is still registered is a no-op (the client
        # cannot tell a live waiter from a consumed-and-lost wake, so lossy
        # transports re-subscribe defensively — without the dedupe those
        # retries would stack duplicate waiters that steal other consumers'
        # wakes). The name sets shadow the deques for O(1) membership.
        self._waiters: deque = deque()            # (consumer, callback)
        self._pub_waiters: deque = deque()
        self._waiter_names: set = set()
        self._pub_waiter_names: set = set()
        self._signal = False                      # event arrived with no waiter
        self._pub_signal = False
        self.published = 0
        self.acked = 0
        self.requeued = 0
        self.wakeups = 0

    # -- producer ------------------------------------------------------------
    def publish(self, body: Any) -> int:
        tag = self._next_tag
        self._next_tag += 1
        self._pending.append((tag, body))
        self.published += 1
        self._notify(publish=True)
        return tag

    # -- consumer ------------------------------------------------------------
    def lease(self, consumer: str, now: float,
              timeout: Optional[float] = None) -> Optional[Tuple[int, Any]]:
        if not self._pending:
            return None
        tag, body = self._pending.popleft()
        t = self.default_timeout if timeout is None else timeout
        deadline = now + t
        self._in_flight[tag] = _InFlight(body, consumer, deadline, 0)
        if math.isfinite(deadline):
            heapq.heappush(self._deadlines, (deadline, tag))
            if self._server_note is not None:
                self._server_note(self.name, deadline)
        return tag, body

    def ack(self, tag: int) -> bool:
        if tag in self._in_flight:
            del self._in_flight[tag]
            self.acked += 1
            return True
        return False

    def nack(self, tag: int, *, front: bool = True) -> bool:
        """Voluntary give-back (e.g. dependency not ready)."""
        inf = self._in_flight.pop(tag, None)
        if inf is None:
            return False
        if front:
            self._pending.appendleft((tag, inf.body))
        else:
            self._pending.append((tag, inf.body))
        self.requeued += 1
        self._notify(publish=False)
        return True

    def extend(self, tag: int, now: float,
               timeout: Optional[float] = None,
               consumer: Optional[str] = None) -> bool:
        """Lease renewal (SQS ChangeMessageVisibility): a live consumer whose
        work — or whose legitimate protocol WAIT, e.g. holding the reduce
        barrier — outlasts the visibility timeout re-stamps its deadline to
        ``now + timeout`` instead of losing the lease. Returns False if the
        tag is no longer held (already expired/requeued — the renewal lost),
        or — receipt-handle semantics — if ``consumer`` is given and the tag
        was meanwhile re-leased to SOMEONE ELSE (a zombie's heartbeat must
        not renew, and must be told it lost, another consumer's lease)."""
        inf = self._in_flight.get(tag)
        if inf is None:
            return False
        if consumer is not None and inf.consumer != consumer:
            return False
        t = self.default_timeout if timeout is None else timeout
        inf.deadline = now + t
        if math.isfinite(inf.deadline):
            heapq.heappush(self._deadlines, (inf.deadline, tag))
            if self._server_note is not None:
                self._server_note(self.name, inf.deadline)
        return True

    # -- subscriptions ---------------------------------------------------------
    def subscribe(self, consumer: str, callback: Callable[[], None], *,
                  kind: str = "any") -> None:
        """Register a one-shot waiter. The next publish (or, for kind="any",
        requeue) wakes exactly ONE waiter in FIFO order. If an event already
        arrived while nobody was waiting, the callback fires immediately —
        a spurious wake at worst; waiters re-check queue state on wake, so the
        check-then-subscribe pattern is lossless under this single-threaded
        virtual clock."""
        if kind not in ("any", "publish"):
            raise ValueError(f"unknown subscription kind {kind!r}")
        if kind == "publish":
            if self._pub_signal:
                self._pub_signal = False
                self.wakeups += 1
                callback()
            elif consumer not in self._pub_waiter_names:
                self._pub_waiters.append((consumer, callback))
                self._pub_waiter_names.add(consumer)
            return
        if self._signal:
            self._signal = False
            self.wakeups += 1
            callback()
        elif consumer not in self._waiter_names:
            self._waiters.append((consumer, callback))
            self._waiter_names.add(consumer)

    def unsubscribe(self, consumer: str) -> int:
        """Remove every waiter registered by this consumer (volunteer left)."""
        n = len(self._waiters) + len(self._pub_waiters)
        self._waiters = deque((c, cb) for c, cb in self._waiters
                              if c != consumer)
        self._pub_waiters = deque((c, cb) for c, cb in self._pub_waiters
                                  if c != consumer)
        self._waiter_names.discard(consumer)
        self._pub_waiter_names.discard(consumer)
        return n - len(self._waiters) - len(self._pub_waiters)

    def kick(self) -> None:
        """Hand a consumed wake to the next waiter — used when a woken consumer
        turns out to have left and cannot serve the event it was woken for."""
        self._notify(publish=False)

    def _notify(self, *, publish: bool) -> None:
        if self._waiters:
            c, cb = self._waiters.popleft()
            self._waiter_names.discard(c)
            self.wakeups += 1
            cb()
        else:
            self._signal = True
        if publish:
            if self._pub_waiters:
                c, cb = self._pub_waiters.popleft()
                self._pub_waiter_names.discard(c)
                self.wakeups += 1
                cb()
            else:
                self._pub_signal = True

    # -- fault tolerance -------------------------------------------------------
    def expire(self, now: float) -> int:
        """Requeue every in-flight message whose visibility deadline passed.
        Amortized O(expired) via the deadline heap (stale entries skipped)."""
        n = 0
        while self._deadlines and self._deadlines[0][0] <= now:
            _, tag = heapq.heappop(self._deadlines)
            inf = self._in_flight.get(tag)
            if inf is not None and inf.deadline <= now:
                self.nack(tag, front=True)
                n += 1
        return n

    def next_deadline(self) -> Optional[float]:
        """Earliest live visibility deadline, or None."""
        while self._deadlines:
            dl, tag = self._deadlines[0]
            inf = self._in_flight.get(tag)
            if inf is not None and inf.deadline == dl:
                return dl
            heapq.heappop(self._deadlines)
        return None

    def drop_consumer(self, consumer: str) -> int:
        """A volunteer closed the browser: requeue everything it held."""
        held = [t for t, inf in self._in_flight.items() if inf.consumer == consumer]
        for t in held:
            self.nack(t, front=True)
        return len(held)

    # -- introspection ---------------------------------------------------------
    @property
    def depth(self) -> int:
        return len(self._pending)

    @property
    def in_flight(self) -> int:
        return len(self._in_flight)

    @property
    def drained(self) -> bool:
        return not self._pending and not self._in_flight

    @property
    def waiters(self) -> int:
        return len(self._waiters) + len(self._pub_waiters)

    def peek_all(self) -> List[Any]:
        return [b for _, b in self._pending]

    def waiter_view(self) -> Dict[str, Tuple[str, ...]]:
        """Registered waiter consumers in FIFO order, per kind. Introspection
        hook for ``repro.analysis.mc`` (no-lost-wake invariant, state
        fingerprint, waiter re-registration on restore); the one-shot
        callbacks themselves stay private."""
        return {"any": tuple(c for c, _ in self._waiters),
                "publish": tuple(c for c, _ in self._pub_waiters)}

    def check_invariants(self) -> None:
        """Structural invariants that must hold at every quiescent point.

        - a tag is pending XOR in flight (never both, never duplicated),
        - every finite-deadline in-flight message has a live entry in the
          deadline heap (stale heap entries are allowed — they are lazily
          discarded — but a deadline the heap does not cover would never
          expire),
        - conservation: every publish is accounted for — acked, still
          pending, or in flight; nothing is lost to nothing.
        """
        pending_tags = [t for t, _ in self._pending]
        assert len(pending_tags) == len(set(pending_tags)), \
            f"{self.name}: duplicate tag in pending"
        overlap = set(pending_tags) & set(self._in_flight)
        assert not overlap, f"{self.name}: tags both pending and in flight: {overlap}"
        heap_entries = set(self._deadlines)
        for tag, inf in self._in_flight.items():
            if math.isfinite(inf.deadline):
                assert (inf.deadline, tag) in heap_entries, \
                    f"{self.name}: in-flight tag {tag} deadline " \
                    f"{inf.deadline} missing from deadline heap"
        assert self.published == self.acked + self.depth + self.in_flight, \
            f"{self.name}: conservation violated: published={self.published} " \
            f"!= acked={self.acked} + depth={self.depth} + " \
            f"in_flight={self.in_flight}"
        assert self._waiter_names == {c for c, _ in self._waiters}, \
            f"{self.name}: waiter name set out of sync"
        assert self._pub_waiter_names == {c for c, _ in self._pub_waiters}, \
            f"{self.name}: publish-waiter name set out of sync"

    # -- durability ------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Full serializable live state: pending FIFO (order + tags), the
        in-flight table with deadlines and requeue counts, banked signals,
        the tag counter, and all counters. Registered WAITERS are deliberately
        excluded — they are live callbacks bound to connections/sessions that
        do not survive a process, so a restored server starts with none and
        clients re-subscribe (which the protocol already requires of lossy
        transports)."""
        return {
            "name": self.name,
            "default_timeout": self.default_timeout,
            "pending": [[tag, body] for tag, body in self._pending],
            "in_flight": [[tag, inf.body, inf.consumer, inf.deadline,
                           inf.requeues]
                          for tag, inf in sorted(self._in_flight.items())],
            "next_tag": self._next_tag,
            "signal": self._signal,
            "pub_signal": self._pub_signal,
            "published": self.published,
            "acked": self.acked,
            "requeued": self.requeued,
            "wakeups": self.wakeups,
        }

    @classmethod
    def from_snapshot(cls, state: Dict[str, Any]) -> "Queue":
        q = cls(state["name"], state["default_timeout"])
        q._pending = deque((tag, body) for tag, body in state["pending"])
        for tag, body, consumer, deadline, requeues in state["in_flight"]:
            q._in_flight[tag] = _InFlight(body, consumer, deadline, requeues)
            if math.isfinite(deadline):
                q._deadlines.append((deadline, tag))
        heapq.heapify(q._deadlines)
        q._next_tag = state["next_tag"]
        q._signal = bool(state["signal"])
        q._pub_signal = bool(state["pub_signal"])
        q.published = state["published"]
        q.acked = state["acked"]
        q.requeued = state["requeued"]
        q.wakeups = state["wakeups"]
        return q

    def adopt_waiters(self, src: "Queue") -> None:
        """Carry another queue object's live waiter registrations into this
        one (in-place restore: the snapshot cannot hold callbacks, but the
        process may still hold the subscribers)."""
        self._waiters = src._waiters
        self._pub_waiters = src._pub_waiters
        self._waiter_names = src._waiter_names
        self._pub_waiter_names = src._pub_waiter_names

    def adopt_session_state(self, src: "Queue") -> None:
        """Adopt ALL of another queue object's session-coupled wake state:
        waiter registrations plus the banked signal flags. An op-log replay
        reconstructs the durable half of a queue but not its wake state —
        subscriptions are never logged (they are connection-bound), so a
        replayed queue over-banks signals that a live subscriber already
        consumed. A gateway adopting a slice takes the wake state from the
        LIVE session side (volunteers that are still connected), exactly as
        ``restore(waiters_from=...)`` does for waiters."""
        self.adopt_waiters(src)
        self._signal = src._signal
        self._pub_signal = src._pub_signal


class QueueServer:
    """Named queues. Multiple QueueServers are modelled by multiple instances
    (the paper's load-balancing story — see ShardedQueueServer); the API is
    identical."""

    def __init__(self, default_timeout: float = float("inf")):
        self.default_timeout = default_timeout
        self.queues: Dict[str, Queue] = {}
        # server-level deadline index: (deadline, qname), lazily pruned — lets
        # next_deadline()/expire_all() cost O(log) instead of O(all queues).
        self._dl_heap: List[Tuple[float, str]] = []

    def _note_deadline(self, qname: str, deadline: float) -> None:
        heapq.heappush(self._dl_heap, (deadline, qname))

    def declare(self, name: str, timeout: Optional[float] = None) -> Queue:
        if name not in self.queues:
            q = Queue(name, self.default_timeout if timeout is None else timeout)
            q._server_note = self._note_deadline
            self.queues[name] = q
        return self.queues[name]

    # -- live-state migration (elastic federation) -----------------------------
    def detach(self, name: str) -> Queue:
        """Remove a queue — with its FULL live state — for migration to
        another server. Stale entries for it in this server's deadline index
        are pruned lazily."""
        q = self.queues.pop(name)
        q._server_note = None
        return q

    def attach(self, q: Queue) -> None:
        """Adopt a migrated queue: index its live in-flight deadlines in this
        server's deadline heap (and compact the queue's own heap, dropping
        entries that went stale at the source). Pending FIFO order, the
        in-flight table, banked signals, registered waiters, the tag counter
        and all counters ride along inside the Queue — no callback fires, so
        migration is invisible to consumers."""
        assert q.name not in self.queues, f"queue {q.name!r} already attached"
        q._deadlines = [(inf.deadline, tag)
                        for tag, inf in q._in_flight.items()
                        if math.isfinite(inf.deadline)]
        heapq.heapify(q._deadlines)
        for dl, _ in q._deadlines:
            heapq.heappush(self._dl_heap, (dl, q.name))
        q._server_note = self._note_deadline
        self.queues[q.name] = q

    # -- durability ------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Serializable state of every queue, in name order (deterministic
        bytes for identical state). See ``Queue.snapshot`` for what rides
        along and why waiters do not."""
        return {"kind": "QueueServer",
                "default_timeout": self.default_timeout,
                "queues": [self.queues[n].snapshot()
                           for n in sorted(self.queues)]}

    def restore(self, state: Dict[str, Any], *,
                waiters_from: Optional[Dict[str, Queue]] = None) -> None:
        """Replace this server's entire state with a snapshot, in place (the
        object identity survives, so endpoints/transports keep working).

        ``waiters_from`` maps queue names to live Queue objects whose waiter
        registrations should be adopted by the restored queues — defaults to
        this server's own current queues, which makes a same-process
        snapshot -> restore round-trip invisible to subscribed consumers.
        After a process crash there are no live waiters to adopt and restored
        queues start with none; reconnecting clients re-subscribe, and any
        lease the dead clients held expires via the visibility sweeper."""
        if state.get("kind") != "QueueServer":
            raise ValueError(f"not a QueueServer snapshot: {state.get('kind')!r}")
        old = self.queues if waiters_from is None else waiters_from
        self.default_timeout = state["default_timeout"]
        self.queues = {}
        self._dl_heap = []
        for qstate in state["queues"]:
            q = Queue.from_snapshot(qstate)
            if q.name in old:
                q.adopt_waiters(old[q.name])
            q._server_note = self._note_deadline
            for dl, _ in q._deadlines:
                heapq.heappush(self._dl_heap, (dl, q.name))
            self.queues[q.name] = q

    def publish(self, qname: str, body: Any) -> int:
        return self.declare(qname).publish(body)

    def lease(self, qname: str, consumer: str, now: float,
              timeout: Optional[float] = None):
        return self.declare(qname).lease(consumer, now, timeout)

    def ack(self, qname: str, tag: int) -> bool:
        return self.declare(qname).ack(tag)

    def nack(self, qname: str, tag: int, *, front: bool = True) -> bool:
        return self.declare(qname).nack(tag, front=front)

    def extend(self, qname: str, tag: int, now: float,
               timeout: Optional[float] = None,
               consumer: Optional[str] = None) -> bool:
        return self.declare(qname).extend(tag, now, timeout, consumer)

    def subscribe(self, qname: str, consumer: str,
                  callback: Callable[[], None], *, kind: str = "any") -> None:
        self.declare(qname).subscribe(consumer, callback, kind=kind)

    def unsubscribe(self, consumer: str) -> int:
        return sum(q.unsubscribe(consumer) for q in self.queues.values())

    def kick(self, qname: str) -> None:
        self.declare(qname).kick()

    def _peek_deadline(self) -> Optional[Tuple[float, str]]:
        """Earliest live (deadline, qname), lazily pruning stale index entries
        (acked / re-leased / migrated-away queues)."""
        while self._dl_heap:
            dl, qn = self._dl_heap[0]
            q = self.queues.get(qn)
            if q is not None and q.next_deadline() == dl:
                return dl, qn
            heapq.heappop(self._dl_heap)
        return None

    def expire_all(self, now: float) -> int:
        """Requeue every expired in-flight message, queue by queue in
        (deadline, qname) order — O(expired), and an order that is a pure
        function of queue state (never of shard layout)."""
        n = 0
        while True:
            head = self._peek_deadline()
            if head is None or head[0] > now:
                break
            n += self.queues[head[1]].expire(now)
        return n

    def next_deadline(self) -> Optional[float]:
        head = self._peek_deadline()
        return None if head is None else head[0]

    def drop_consumer(self, consumer: str) -> int:
        # qname order, so requeue notifications fire in an order independent
        # of queue-creation (and, federated, shard) layout
        return sum(self.queues[n].drop_consumer(consumer)
                   for n in sorted(self.queues))

    def drained(self, names: Optional[Iterable[str]] = None) -> bool:
        qs = (self.queues[n] for n in names if n in self.queues) if names \
            else self.queues.values()
        return all(q.drained for q in qs)

    def depth(self, qname: str) -> int:
        return self.declare(qname).depth

    def waiter_views(self) -> Dict[str, Dict[str, Tuple[str, ...]]]:
        """Per-queue ``waiter_view``s, sorted by queue name (model checker)."""
        return {n: self.queues[n].waiter_view() for n in sorted(self.queues)}

    @property
    def total_requeued(self) -> int:
        return sum(q.requeued for q in self.queues.values())

    @property
    def total_wakeups(self) -> int:
        return sum(q.wakeups for q in self.queues.values())


def _stable_hash(key: str) -> int:
    """Process-independent 64-bit hash (Python's str hash is salted)."""
    return int.from_bytes(
        hashlib.blake2b(key.encode(), digest_size=8).digest(), "big")


def colocate_results(qname: str) -> str:
    """Placement rule for ``ShardedQueueServer(placement=...)``: route every
    ``map-results:v*`` queue to the shard owning the task queue, so a reduce
    barrier (drain + ack the results queue, ack the task) touches exactly ONE
    shard instead of two. Placement only picks the owner — queue semantics
    (and the chaos bit-match contract) are placement-invariant."""
    from repro.core.tasks import INITIAL_QUEUE, RESULTS_PREFIX
    return INITIAL_QUEUE if qname.startswith(RESULTS_PREFIX) else qname


class ShardedQueueServer:
    """K federated QueueServer instances behind the QueueServer API.

    Queue names route to shards via a consistent-hash ring with virtual nodes,
    so (a) load spreads evenly over the federation and (b) adding/removing a
    shard remaps only ~1/K of the queue names — the standard scaling story for
    the paper's "several QueueServers" deployment. Every per-queue operation is
    a pure delegation to the owning shard, so federation is semantics-invisible
    (asserted by tests: a sharded run bit-matches a single-server run).

    The federation is elastic: ``add_shard()`` / ``remove_shard(i)`` change
    ring membership at runtime and migrate the full live state of every
    remapped queue to its new owner (see ``QueueServer.detach/attach``). Shards
    carry stable ids independent of their list position, so a membership
    change only adds/removes that member's virtual nodes — every other vnode
    keeps its ring position, which is what bounds the remap to ~1/K of names.
    Both methods return the migrated queue names (the rebalance observable).
    """

    def __init__(self, n_shards: int, default_timeout: float = float("inf"),
                 *, vnodes: int = 64,
                 placement: Optional[Callable[[str], str]] = None):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.default_timeout = default_timeout
        self._vnodes = vnodes
        # placement maps a queue name to the KEY the ring hashes (e.g.
        # ``colocate_results`` rides result queues with their task queue);
        # identity by default. Routing stays a pure function of the name.
        self._place: Callable[[str], str] = placement or (lambda name: name)
        self.shards: List[QueueServer] = []
        self._sids: List[int] = []            # stable id per shard (ring key)
        self._next_sid = 0
        self._ring: List[Tuple[int, int]] = []  # sorted (hash, sid)
        self._ring_keys: List[int] = []
        self._ring_vals: List[int] = []         # shard INDEX per ring slot
        for _ in range(n_shards):
            self.add_shard()

    def _reindex(self) -> None:
        index_of = {sid: i for i, sid in enumerate(self._sids)}
        self._ring_keys = [h for h, _ in self._ring]
        self._ring_vals = [index_of[sid] for _, sid in self._ring]

    def add_shard(self) -> List[str]:
        """Join a new (empty) shard and migrate the ~1/K of live queues whose
        ring successor is now one of its virtual nodes. Returns the migrated
        queue names."""
        sid = self._next_sid
        self._next_sid += 1
        self.shards.append(QueueServer(self.default_timeout))
        self._sids.append(sid)
        for r in range(self._vnodes):
            bisect.insort(self._ring, (_stable_hash(f"qshard-{sid}#{r}"), sid))
        self._reindex()
        migrated: List[str] = []
        for si, shard in enumerate(self.shards[:-1]):
            for name in sorted(n for n in shard.queues
                               if self.shard_of(n) != si):
                self.shards[self.shard_of(name)].attach(shard.detach(name))
                migrated.append(name)
        return migrated

    def remove_shard(self, index: int) -> List[str]:
        """Leave: retire the shard at ``index``, migrating ALL of its live
        queues (≈1/K of the federation) to their new ring successors — zero
        messages lost, waiters and banked signals included. Returns the
        migrated queue names."""
        if len(self.shards) <= 1:
            raise ValueError("cannot remove the last shard")
        sid = self._sids.pop(index)
        src = self.shards.pop(index)
        self._ring = [(h, s) for h, s in self._ring if s != sid]
        self._reindex()
        migrated: List[str] = []
        for name in sorted(src.queues):
            self.shards[self.shard_of(name)].attach(src.detach(name))
            migrated.append(name)
        return migrated

    # -- durability ------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Per-shard snapshots plus the ring membership (stable shard ids and
        the id counter), so a restore reproduces the exact queue->shard
        placement — including ids burned by shards that have since left."""
        return {"kind": "ShardedQueueServer",
                "default_timeout": self.default_timeout,
                "vnodes": self._vnodes,
                "next_sid": self._next_sid,
                "sids": list(self._sids),
                "shards": [s.snapshot() for s in self.shards]}

    def restore(self, state: Dict[str, Any]) -> None:
        """Rebuild the ring and every shard's state in place. The placement
        rule is code, not state — the restoring server keeps its own (it must
        be constructed with the same rule, like the same codebase). Live
        waiters are adopted by queue NAME across the whole federation, so a
        same-process round-trip stays invisible even though queue->shard
        ownership is reconstructed rather than copied."""
        if state.get("kind") != "ShardedQueueServer":
            raise ValueError(
                f"not a ShardedQueueServer snapshot: {state.get('kind')!r}")
        if state["vnodes"] != self._vnodes:
            raise ValueError(f"vnodes mismatch: snapshot {state['vnodes']}, "
                             f"server {self._vnodes}")
        live = dict(self.queues)              # merged name -> Queue view
        self.default_timeout = state["default_timeout"]
        self._next_sid = state["next_sid"]
        self._sids = list(state["sids"])
        self._ring = []
        for sid in self._sids:
            for r in range(self._vnodes):
                bisect.insort(self._ring,
                              (_stable_hash(f"qshard-{sid}#{r}"), sid))
        self._reindex()
        self.shards = [QueueServer(self.default_timeout)
                       for _ in self._sids]
        for shard, sstate in zip(self.shards, state["shards"]):
            shard.restore(sstate, waiters_from=live)

    def shard_of(self, qname: str) -> int:
        """Index of the shard owning this queue name (clockwise successor of
        its placement key)."""
        h = _stable_hash(self._place(qname))
        i = bisect.bisect_right(self._ring_keys, h) % len(self._ring_keys)
        return self._ring_vals[i]

    def route(self, qname: str) -> QueueServer:
        return self.shards[self.shard_of(qname)]

    # -- per-queue ops: delegate to the owning shard ---------------------------
    def declare(self, name: str, timeout: Optional[float] = None) -> Queue:
        return self.route(name).declare(name, timeout)

    def publish(self, qname: str, body: Any) -> int:
        return self.route(qname).publish(qname, body)

    def lease(self, qname: str, consumer: str, now: float,
              timeout: Optional[float] = None):
        return self.route(qname).lease(qname, consumer, now, timeout)

    def ack(self, qname: str, tag: int) -> bool:
        return self.route(qname).ack(qname, tag)

    def nack(self, qname: str, tag: int, *, front: bool = True) -> bool:
        return self.route(qname).nack(qname, tag, front=front)

    def extend(self, qname: str, tag: int, now: float,
               timeout: Optional[float] = None,
               consumer: Optional[str] = None) -> bool:
        return self.route(qname).extend(qname, tag, now, timeout, consumer)

    def subscribe(self, qname: str, consumer: str,
                  callback: Callable[[], None], *, kind: str = "any") -> None:
        self.route(qname).subscribe(qname, consumer, callback, kind=kind)

    def kick(self, qname: str) -> None:
        self.route(qname).kick(qname)

    def depth(self, qname: str) -> int:
        return self.route(qname).depth(qname)

    # -- federation-wide ops ---------------------------------------------------
    def unsubscribe(self, consumer: str) -> int:
        return sum(s.unsubscribe(consumer) for s in self.shards)

    def expire_all(self, now: float) -> int:
        """Merge per-shard deadline indexes so expiry requeues fire in global
        (deadline, qname) order — identical to a single server holding the
        same queues, whatever the shard layout."""
        n = 0
        while True:
            best: Optional[Tuple[float, str]] = None
            best_shard: Optional[QueueServer] = None
            for s in self.shards:
                head = s._peek_deadline()
                if head is not None and head[0] <= now and \
                        (best is None or head < best):
                    best, best_shard = head, s
            if best is None:
                break
            n += best_shard.queues[best[1]].expire(now)
        return n

    def next_deadline(self) -> Optional[float]:
        dls = [d for d in (s.next_deadline() for s in self.shards)
               if d is not None]
        return min(dls) if dls else None

    def drop_consumer(self, consumer: str) -> int:
        # global qname order — matches the single-server requeue order
        named = sorted(((n, s) for s in self.shards for n in s.queues),
                       key=lambda t: t[0])
        return sum(s.queues[n].drop_consumer(consumer) for n, s in named)

    def drained(self, names: Optional[Iterable[str]] = None) -> bool:
        if names:
            return all(self.route(n).drained([n]) for n in names)
        return all(s.drained() for s in self.shards)

    @property
    def queues(self) -> Dict[str, Queue]:
        """Merged read-only view over all shards (names are unique: each queue
        lives on exactly one shard)."""
        merged: Dict[str, Queue] = {}
        for s in self.shards:
            merged.update(s.queues)
        return merged

    @property
    def total_requeued(self) -> int:
        return sum(s.total_requeued for s in self.shards)

    @property
    def total_wakeups(self) -> int:
        return sum(s.total_wakeups for s in self.shards)

    def shard_loads(self) -> List[int]:
        """Queues per shard — the load-balance observable."""
        return [len(s.queues) for s in self.shards]
