"""repro — JSDoop (IEEE Access 2019) reproduced as a JAX/TPU training framework.

Layers:
- ``repro.core``        — faithful JSDoop runtime (queues, DataServer, volunteers,
                          discrete-event simulator).
- ``repro.models``      — pure-JAX model zoo (10 assigned architectures + the
                          paper's LSTM).
- ``repro.optim``       — RMSprop/SGD/Adam + gradient compression.
- ``repro.distributed`` — pjit/shard_map production mapping of the JSDoop schedule.
- ``repro.kernels``     — Pallas TPU kernels (validated in interpret mode).
- ``repro.launch``      — mesh / dry-run / train / serve entry points.
"""
__version__ = "1.0.0"
