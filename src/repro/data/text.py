"""Character-level text pipeline — the paper's workload data path.

The paper trains on "TensorFlow.js code (compiled, 0.11.7)" — i.e., the system's
own source text. We do exactly the analogous thing: the default corpus is this
repository's own Python source, concatenated deterministically (sorted paths).
A seeded synthetic corpus is provided for hermetic tests.

The batch schedule is a pure function of (seed, epoch, batch) so the sequential
baseline, the L1 volunteer runtime, and the L2 SPMD mapping all consume the
*identical* sample stream — this is what makes the paper's Table-4 invariance
(same loss for every worker count) testable as an exact equality.
"""
from __future__ import annotations

import hashlib
import pathlib
from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np


def repo_corpus(root: str | None = None, max_chars: int = 200_000) -> str:
    """Concatenate this package's own source files (sorted), like the paper
    trained on tfjs's own code."""
    base = pathlib.Path(root) if root else pathlib.Path(__file__).resolve().parents[1]
    parts: List[str] = []
    total = 0
    for p in sorted(base.rglob("*.py")):
        try:
            t = p.read_text(errors="ignore")
        except OSError:
            continue
        parts.append(t)
        total += len(t)
        if total >= max_chars:
            break
    text = "".join(parts)[:max_chars]
    if len(text) < 10_000:  # safety: never return a degenerate corpus
        text = (text + synthetic_corpus(10_000 - len(text)))
    return text


def synthetic_corpus(n_chars: int = 50_000, seed: int = 7) -> str:
    """Deterministic pseudo-code text (hermetic fallback for tests)."""
    rng = np.random.RandomState(seed)
    words = ["const", "let", "function", "return", "tensor", "model", "train",
             "gradient", "queue", "task", "reduce", "map", "worker", "async",
             "await", "batch", "epoch", "loss", "browser", "volunteer"]
    out: List[str] = []
    n = 0
    while n < n_chars:
        w = words[rng.randint(len(words))]
        frag = f"{w}({rng.randint(100)});\n" if rng.rand() < 0.3 else f"{w} "
        out.append(frag)
        n += len(frag)
    return "".join(out)[:n_chars]


@dataclass
class CharVocab:
    chars: str

    @classmethod
    def from_text(cls, text: str) -> "CharVocab":
        return cls("".join(sorted(set(text))))

    @property
    def size(self) -> int:
        return len(self.chars)

    def encode(self, text: str) -> np.ndarray:
        table = {c: i for i, c in enumerate(self.chars)}
        return np.asarray([table[c] for c in text], np.int32)

    def decode(self, ids) -> str:
        return "".join(self.chars[int(i)] for i in ids)


@dataclass
class TextTask:
    """The full data context for the paper's experiment."""
    ids: np.ndarray          # encoded corpus
    vocab: CharVocab
    sample_len: int
    seed: int = 1234

    @classmethod
    def build(cls, text: str | None = None, sample_len: int = 40,
              seed: int = 1234) -> "TextTask":
        text = text if text is not None else repo_corpus()
        vocab = CharVocab.from_text(text)
        return cls(vocab.encode(text), vocab, sample_len, seed)

    # -- deterministic schedule --------------------------------------------
    def starts(self, epoch: int, batch: int, batch_size: int) -> np.ndarray:
        """Window start offsets for (epoch, batch) — pure function of seed."""
        h = hashlib.sha256(f"{self.seed}:{epoch}:{batch}".encode()).digest()
        rng = np.random.RandomState(int.from_bytes(h[:4], "little"))
        hi = len(self.ids) - self.sample_len - 1
        return rng.randint(0, hi, size=batch_size).astype(np.int64)

    def make_batch(self, starts: np.ndarray) -> Dict[str, np.ndarray]:
        """{'x': one-hot [B, T, V] float32, 'y': next-char ids [B]}."""
        T, V = self.sample_len, self.vocab.size
        idx = starts[:, None] + np.arange(T)[None, :]
        x_ids = self.ids[idx]                                   # [B, T]
        y = self.ids[starts + T]                                # [B]
        x = np.zeros((len(starts), T, V), np.float32)
        np.put_along_axis(x, x_ids[..., None], 1.0, axis=-1)
        return {"x": x, "y": y.astype(np.int32)}

    def batch(self, epoch: int, batch: int, batch_size: int):
        return self.make_batch(self.starts(epoch, batch, batch_size))

    def minibatch(self, epoch: int, batch: int, batch_size: int,
                  mb_index: int, mb_size: int):
        """Slice mini-batch ``mb_index`` out of the batch — the map-task unit.
        Slicing the same schedule guarantees distributed == sequential."""
        starts = self.starts(epoch, batch, batch_size)
        return self.make_batch(starts[mb_index * mb_size:(mb_index + 1) * mb_size])
