from repro.data.text import TextTask, CharVocab, repo_corpus, synthetic_corpus  # noqa: F401
from repro.data.tokens import lm_batch, shard_slice  # noqa: F401
