"""Synthetic token pipeline for the assigned LM architectures.

Deterministic (seed, step) -> global batch; sharded loading gives each data-
parallel host only its slice (the pattern a real multi-pod input pipeline uses).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.configs.base import ArchConfig, InputShape


def lm_batch(cfg: ArchConfig, batch: int, seq: int, seed: int, step: int
             ) -> Dict[str, np.ndarray]:
    rng = np.random.RandomState((seed * 1_000_003 + step) % (2 ** 31 - 1))
    out: Dict[str, np.ndarray] = {}
    if cfg.family == "encdec":
        out["frames"] = rng.randn(batch, cfg.encoder_seq, cfg.d_model).astype(
            np.float32) * 0.02
        out["tokens"] = rng.randint(0, cfg.vocab, (batch, seq + 1)).astype(np.int32)
    elif cfg.family == "vlm":
        st = seq - cfg.vision_prefix
        out["patches"] = rng.randn(batch, cfg.vision_prefix, cfg.d_model).astype(
            np.float32) * 0.02
        out["tokens"] = rng.randint(0, cfg.vocab, (batch, st + 1)).astype(np.int32)
    else:
        out["tokens"] = rng.randint(0, cfg.vocab, (batch, seq + 1)).astype(np.int32)
    return out


def shard_slice(batch: Dict[str, np.ndarray], shard: int, num_shards: int):
    """Per-host slice of the global batch along the batch dim."""
    def sl(x):
        n = x.shape[0]
        assert n % num_shards == 0, (n, num_shards)
        per = n // num_shards
        return x[shard * per:(shard + 1) * per]
    return {k: sl(v) for k, v in batch.items()}
