"""Pytree <-> bytes via msgpack (+ optional zstd). Used by the DataServer wire
protocol (gradient/model messages) and the durable checkpoint store."""
from __future__ import annotations

from typing import Any, Tuple

import msgpack
import numpy as np
import zstandard

_ARR = "__nd__"
_CTX = zstandard.ZstdCompressor(level=3)
_DCTX = zstandard.ZstdDecompressor()


def _dtype_of(name: str) -> np.dtype:
    """Resolve a dtype by name, including ml_dtypes extension types (bfloat16
    et al.), which numpy's ``dtype.str`` cannot round-trip."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _pack_leaf(x):
    if isinstance(x, (np.ndarray, np.generic)) or hasattr(x, "__array__"):
        a = np.asarray(x)
        return {_ARR: True, "d": a.dtype.name, "s": list(a.shape),
                "b": a.tobytes()}
    return x


def _unpack_leaf(x):
    if isinstance(x, dict) and x.get(_ARR):
        return np.frombuffer(x["b"], _dtype_of(x["d"])).reshape(x["s"]).copy()
    return x


def _walk(tree, fn):
    if isinstance(tree, dict) and not tree.get(_ARR):
        return {k: _walk(v, fn) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return [_walk(v, fn) for v in tree]
    return fn(tree)


def dumps(tree: Any, compress: bool = True) -> bytes:
    raw = msgpack.packb(_walk(tree, _pack_leaf), use_bin_type=True)
    if compress:
        return b"Z" + _CTX.compress(raw)
    return b"R" + raw


def loads(data: bytes) -> Any:
    tag, body = data[:1], data[1:]
    if tag == b"Z":
        body = _DCTX.decompress(body)
    tree = msgpack.unpackb(body, raw=False, strict_map_key=False)
    return _walk(tree, _unpack_leaf)
