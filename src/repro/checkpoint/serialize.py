"""Pytree <-> bytes via msgpack (+ optional compression). Used by the
DataServer wire protocol (gradient/model messages) and the durable checkpoint
store.

The first byte of every blob is the codec header, so either side can decode
regardless of which codecs it has installed:

- ``Z`` zstandard (preferred when the optional ``zstandard`` package exists)
- ``D`` stdlib zlib/deflate (always available fallback)
- ``R`` raw / uncompressed
"""
from __future__ import annotations

import struct
import zlib
from typing import Any, Optional, Tuple

import msgpack
import numpy as np

try:  # optional: zstd compresses better/faster, but the stdlib must suffice
    import zstandard
    _CTX = zstandard.ZstdCompressor(level=3)
    _DCTX = zstandard.ZstdDecompressor()
except ImportError:
    zstandard = None
    _CTX = _DCTX = None

_ARR = "__nd__"

# op-log record header: payload length + crc32 of the payload
_REC = struct.Struct(">II")

DEFAULT_CODEC = "zstd" if zstandard is not None else "zlib"


def _dtype_of(name: str) -> np.dtype:
    """Resolve a dtype by name, including ml_dtypes extension types (bfloat16
    et al.), which numpy's ``dtype.str`` cannot round-trip."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _pack_leaf(x):
    if isinstance(x, (np.ndarray, np.generic)) or hasattr(x, "__array__"):
        a = np.asarray(x)
        return {_ARR: True, "d": a.dtype.name, "s": list(a.shape),
                "b": a.tobytes()}
    return x


def _unpack_leaf(x):
    if isinstance(x, dict) and x.get(_ARR):
        return np.frombuffer(x["b"], _dtype_of(x["d"])).reshape(x["s"]).copy()
    return x


def _walk(tree, fn):
    if isinstance(tree, dict) and not tree.get(_ARR):
        return {k: _walk(v, fn) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return [_walk(v, fn) for v in tree]
    return fn(tree)


def dumps(tree: Any, compress: bool = True,
          codec: Optional[str] = None) -> bytes:
    """Serialize. ``codec`` forces "zstd"/"zlib"; default picks zstd when
    installed, zlib otherwise. The choice is recorded in the header byte."""
    raw = msgpack.packb(_walk(tree, _pack_leaf), use_bin_type=True)
    if not compress:
        return b"R" + raw
    codec = codec or DEFAULT_CODEC
    if codec == "zstd":
        if zstandard is None:
            raise RuntimeError(
                "codec='zstd' requested but the zstandard package is not "
                "installed; use codec='zlib' or install zstandard")
        return b"Z" + _CTX.compress(raw)
    if codec == "zlib":
        return b"D" + zlib.compress(raw, 6)
    raise ValueError(f"unknown codec {codec!r}")


def atomic_write(path: str, data: bytes) -> int:
    """Write bytes to a file ATOMICALLY (tmp + fsync + rename): a reader —
    e.g. a gateway restarting from its latest snapshot — can never observe a
    half-written blob, even if the writer is kill -9'd mid-write. Returns the
    byte size written."""
    import os
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return len(data)


def read_bytes(path: str) -> bytes:
    with open(path, "rb") as f:
        return f.read()


def dump_path(tree: Any, path: str, compress: bool = True,
              codec: Optional[str] = None) -> int:
    """``dumps`` straight to a file, atomically."""
    return atomic_write(path, dumps(tree, compress=compress, codec=codec))


def load_path(path: str) -> Any:
    return loads(read_bytes(path))


def pack_record(data: bytes) -> bytes:
    """Frame one op-log record: 8-byte header (u32 length, u32 crc32 of the
    payload, both big-endian) + payload. The crc makes a torn or bit-rotted
    tail detectable, so an append-only log survives kill -9 mid-write."""
    return _REC.pack(len(data), zlib.crc32(data) & 0xFFFFFFFF) + data


def append_record(path: str, data: bytes, *, fsync: bool = True) -> int:
    """Append one framed record to an append-only log file, creating it if
    needed. ``fsync=True`` (the default) makes the record durable before
    returning — the op-log contract: an operation acknowledged to a client
    is recoverable after kill -9. Returns bytes written."""
    import os
    rec = pack_record(data)
    with open(path, "ab") as f:
        f.write(rec)
        f.flush()
        if fsync:
            os.fsync(f.fileno())
    return len(rec)


def iter_records(data: bytes):
    """Yield the framed record payloads in ``data`` in order, stopping at the
    first incomplete or corrupt record. A torn tail (the writer was killed
    mid-append) is EXPECTED, not an error: every record before it is intact
    by construction (appends are sequential), so replay simply ends there."""
    off, n = 0, len(data)
    while off + _REC.size <= n:
        length, crc = _REC.unpack_from(data, off)
        body = data[off + _REC.size:off + _REC.size + length]
        if len(body) < length or (zlib.crc32(body) & 0xFFFFFFFF) != crc:
            return
        yield body
        off += _REC.size + length


def loads(data: bytes) -> Any:
    tag, body = data[:1], data[1:]
    if tag == b"Z":
        if _DCTX is None:
            raise RuntimeError(
                "checkpoint was written with zstd but the zstandard package "
                "is not installed on this side")
        body = _DCTX.decompress(body)
    elif tag == b"D":
        body = zlib.decompress(body)
    elif tag != b"R":
        raise ValueError(f"unknown serialization header {tag!r}")
    tree = msgpack.unpackb(body, raw=False, strict_map_key=False)
    return _walk(tree, _unpack_leaf)
