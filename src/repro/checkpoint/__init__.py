from repro.checkpoint.serialize import dumps, loads  # noqa: F401
from repro.checkpoint.store import CheckpointStore  # noqa: F401
