"""Versioned checkpoint store — the durable form of the paper's DataServer.

Each version is one file ``v{N:08d}.ckpt`` (msgpack+zstd). The store is
append-only with optional retention; ``latest()`` resumes training, matching
the paper's "QueueServer is able to recover from failures without losing
execution status" availability claim at the model level.
"""
from __future__ import annotations

import os
import pathlib
import tempfile
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.serialize import dumps, loads


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 0):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    def _path(self, version: int) -> pathlib.Path:
        return self.dir / f"v{version:08d}.ckpt"

    def save(self, version: int, tree: Any, meta: Optional[dict] = None) -> str:
        host = jax.tree.map(np.asarray, tree)
        payload = {"meta": meta or {}, "tree": host, "version": version}
        # atomic write
        fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".tmp")
        with os.fdopen(fd, "wb") as f:
            f.write(dumps(payload))
        os.replace(tmp, self._path(version))
        if self.keep:
            for v in self.versions()[:-self.keep]:
                self._path(v).unlink(missing_ok=True)
        return str(self._path(version))

    def load(self, version: int) -> Tuple[Any, dict]:
        payload = loads(self._path(version).read_bytes())
        return payload["tree"], payload["meta"]

    def versions(self) -> List[int]:
        return sorted(int(p.stem[1:]) for p in self.dir.glob("v*.ckpt"))

    def latest(self) -> Optional[int]:
        vs = self.versions()
        return vs[-1] if vs else None
