"""Shared plumbing for the repro.analysis passes: the ``Violation`` record
and the ``# analysis: ignore[RULE-ID]`` escape hatch.

Every pass reports the same shape — (rule id, file, line, message) — so the
driver prints uniformly and CI fails on any of them. The ignore comment is
deliberately rule-scoped (no blanket ignores): it must name the exact rule
id, and strict mode additionally fails on ignores that no longer suppress
anything, so an escape cannot outlive the code it excused.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, List, Tuple


@dataclass(frozen=True)
class Violation:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclass(frozen=True)
class Ignore:
    """One ``# analysis: ignore[...]`` comment: the rules it names and the
    source lines it covers (its own line, plus the next line when the
    comment stands alone — for statements too long to carry it trailing)."""
    line: int
    rules: frozenset
    covers: Tuple[int, ...]


_IGNORE = re.compile(r"#\s*analysis:\s*ignore\[([A-Za-z0-9_,\- ]+)\]")


def parse_ignores(source: str) -> List[Ignore]:
    out = []
    for lineno, text in enumerate(source.splitlines(), start=1):
        m = _IGNORE.search(text)
        if m is None:
            continue
        rules = frozenset(x.strip() for x in m.group(1).split(",") if x.strip())
        covers = (lineno, lineno + 1) if text.lstrip().startswith("#") \
            else (lineno,)
        out.append(Ignore(lineno, rules, covers))
    return out


def apply_ignores(violations: List[Violation], ignores: List[Ignore],
                  path: str) -> Tuple[List[Violation], List[Violation]]:
    """Suppress violations covered by an ignore comment. Returns
    ``(kept, stale)`` where ``stale`` reports (as ANALYSIS-IGNORE
    violations) every ignore comment that suppressed nothing — strict mode
    fails on those, so dead escapes get cleaned up."""
    used = set()
    kept = []
    for v in violations:
        hit = next((ig for ig in ignores
                    if v.line in ig.covers and v.rule in ig.rules), None)
        if hit is None:
            kept.append(v)
        else:
            used.add(hit.line)
    stale = [Violation("ANALYSIS-IGNORE", path, ig.line,
                       f"ignore[{', '.join(sorted(ig.rules))}] suppresses "
                       f"nothing — remove it")
             for ig in ignores if ig.line not in used]
    return kept, stale
