"""Driver: ``python -m repro.analysis [--strict] [--only PASS ...]``.

Runs the rules / locks / schema passes (all three by default), prints every
violation as ``path:line: [RULE-ID] message``, and exits non-zero if any
fired — the CI contract. ``--strict`` additionally fails on stale
``# analysis: ignore[...]`` comments so escapes can't outlive the code they
excused. ``--paths`` / ``--doc`` point a pass at other files (used by the
fixture tests to prove each rule fires).
"""
from __future__ import annotations

import argparse
import importlib.util
import pathlib
import sys
from typing import List

from repro.analysis import locks, rules, schema
from repro.analysis.base import Violation


def _core_paths() -> List[pathlib.Path]:
    spec = importlib.util.find_spec("repro.core")
    core = pathlib.Path(spec.origin).parent
    return sorted(core.glob("*.py"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-native static analysis: layering linter, "
                    "lock-order race detector, wire-schema checker")
    ap.add_argument("--strict", action="store_true",
                    help="also fail on stale ignore comments")
    ap.add_argument("--only", action="append",
                    choices=["rules", "locks", "schema"],
                    help="run only this pass (repeatable; default: all)")
    ap.add_argument("--paths", nargs="+", default=None,
                    help="files for the rules/locks passes "
                         "(default: src/repro/core/*.py)")
    ap.add_argument("--doc", default=None,
                    help="protocol doc for the schema pass "
                         "(default: docs/protocol.md)")
    args = ap.parse_args(argv)
    only = set(args.only or ["rules", "locks", "schema"])

    violations: List[Violation] = []
    if "rules" in only:
        paths = args.paths or _core_paths()
        vs, stale = rules.check_paths(paths)
        violations.extend(vs)
        if args.strict:
            violations.extend(stale)
    if "locks" in only:
        violations.extend(locks.check(args.paths or locks.default_paths()))
    if "schema" in only:
        violations.extend(schema.run(doc_path=args.doc))

    for v in violations:
        print(v)
    names = "+".join(sorted(only))
    if violations:
        print(f"# repro.analysis [{names}]: {len(violations)} violation(s)",
              flush=True)
        return 1
    print(f"# repro.analysis [{names}]: clean", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
