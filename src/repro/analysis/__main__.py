"""Driver: ``python -m repro.analysis [--strict] [--mc] [--only PASS ...]``.

Runs the rules / locks / schema passes (those three by default), prints
every violation as ``path:line: [RULE-ID] message``, and exits non-zero if
any fired — the CI contract. ``--strict`` additionally fails on stale
``# analysis: ignore[...]`` comments so escapes can't outlive the code they
excused. ``--mc`` (or ``--only mc``) adds the model-checking pass: bounded
exhaustive exploration of the protocol under faults, with ``--mc-policy`` /
``--mc-states`` / ``--mc-depth`` / ``--mc-seconds`` setting the budget and
``--mc-fixture`` pointing it at a fixture module's world (used by the
mutation-fixture tests to prove the checker rediscovers seeded historical
bugs). ``--paths`` / ``--doc`` point a pass at other files (used by the
fixture tests to prove each rule fires).
"""
from __future__ import annotations

import argparse
import importlib.util
import pathlib
import sys
from typing import List

from repro.analysis import locks, rules, schema
from repro.analysis.base import Violation


def _core_paths() -> List[pathlib.Path]:
    spec = importlib.util.find_spec("repro.core")
    core = pathlib.Path(spec.origin).parent
    return sorted(core.glob("*.py"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-native static analysis: layering linter, "
                    "lock-order race detector, wire-schema checker")
    ap.add_argument("--strict", action="store_true",
                    help="also fail on stale ignore comments")
    ap.add_argument("--only", action="append",
                    choices=["rules", "locks", "schema", "mc"],
                    help="run only this pass (repeatable; default: "
                         "rules+locks+schema, plus mc with --mc)")
    ap.add_argument("--paths", nargs="+", default=None,
                    help="files for the rules/locks passes "
                         "(default: src/repro/core/*.py)")
    ap.add_argument("--doc", default=None,
                    help="protocol doc for the schema pass "
                         "(default: docs/protocol.md)")
    ap.add_argument("--mc", action="store_true",
                    help="also run the model-checking pass")
    ap.add_argument("--mc-policy", action="append", default=None,
                    help="policy world(s) for the mc pass (repeatable; "
                         "default: sync, staleness:1, local:2)")
    ap.add_argument("--mc-states", type=int, default=4000,
                    help="mc state budget per world (default 4000)")
    ap.add_argument("--mc-depth", type=int, default=50,
                    help="mc depth budget (default 50)")
    ap.add_argument("--mc-seconds", type=float, default=12.0,
                    help="mc wall-clock budget per world (default 12)")
    ap.add_argument("--mc-fixture", default=None,
                    help="explore a fixture module's world (the module must "
                         "expose configure() -> MCConfig) instead of the "
                         "default policy worlds")
    args = ap.parse_args(argv)
    only = set(args.only or ["rules", "locks", "schema"])
    if args.mc:
        only.add("mc")

    violations: List[Violation] = []
    if "rules" in only:
        paths = args.paths or _core_paths()
        vs, stale = rules.check_paths(paths)
        violations.extend(vs)
        if args.strict:
            violations.extend(stale)
    if "locks" in only:
        violations.extend(locks.check(args.paths or locks.default_paths()))
    if "schema" in only:
        violations.extend(schema.run(doc_path=args.doc))
    if "mc" in only:
        from repro.analysis.mc import run_mc
        stats = {}
        violations.extend(run_mc(
            args.mc_policy, max_states=args.mc_states,
            max_depth=args.mc_depth, max_seconds=args.mc_seconds,
            fixture=args.mc_fixture, stats_out=stats))
        for label, st in stats.items():
            print(f"# mc[{label}]: {st.states} states, "
                  f"{st.transitions} transitions, "
                  f"{st.states_per_sec:.0f} states/s, "
                  f"depth {st.max_depth}, "
                  f"reduction x{st.reduction_factor:.1f}"
                  f"{', TRUNCATED' if st.truncated else ' (exhaustive)'}")

    for v in violations:
        print(v)
    names = "+".join(sorted(only))
    if violations:
        print(f"# repro.analysis [{names}]: {len(violations)} violation(s)",
              flush=True)
        return 1
    print(f"# repro.analysis [{names}]: clean", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
