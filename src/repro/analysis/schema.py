"""Wire-schema exhaustiveness checker (pass "schema").

A wire type that exists but fails any one of these checks is a latent
protocol hole: a message that can't cross the wire, a request the server
silently drops, a task that vanishes across a crash, or a type the protocol
doc lies about by omission. Every dataclass in the ``@wire`` registry
(``protocol._WIRE_TYPES`` — protocol messages plus ``tasks.WIRE_TYPES``
bodies) must therefore be:

- **SCHEMA-ROUNDTRIP** — byte-round-trippable: a sample instance survives
  ``encode_message``/``decode_message`` unchanged.
- **SCHEMA-PARTITION** — classified in exactly one of REQUEST_TYPES,
  REPLY_TYPES, NOTIFICATION_TYPES, or tasks.WIRE_TYPES; an unclassified
  type is unreachable, a doubly-classified one is ambiguous to dispatch.
- **SCHEMA-DISPATCH** — reachable from ``ServerEndpoint``: every request
  type appears in an ``isinstance`` dispatch arm in protocol.py, and every
  reply/notification type is actually constructed there.
- **SCHEMA-SNAPSHOT** — durable where it claims to be: each task body
  published into a ``QueueServer`` survives snapshot -> encode -> decode ->
  restore with a byte-identical second snapshot.
- **SCHEMA-DOC** — listed (as a backticked name) in docs/protocol.md.
  ``scripts/check_docs.py`` delegates its wire-type check here so the two
  can't drift.
- **SCHEMA-MC** — modeled by the model checker: every REQUEST and
  NOTIFICATION type must map to an exploration action in
  ``repro.analysis.mc.COVERED_MESSAGES``, so the protocol cannot grow a
  message the exhaustive search silently never exercises (the coverage test
  in tests/test_mc.py proves each mapping is real, not just declared).

Unlike the other passes this one imports the code under test — round-trip
and snapshot coverage are semantic claims AST inspection can't make.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
from typing import Dict, Iterable, List, Optional, Tuple

from repro.analysis.base import Violation
from repro.core import protocol, tasks
from repro.core.queue import QueueServer

RULES = {
    "SCHEMA-ROUNDTRIP": "wire type does not survive encode/decode",
    "SCHEMA-PARTITION": "wire type not in exactly one protocol role",
    "SCHEMA-DISPATCH": "request not dispatched / reply never constructed",
    "SCHEMA-SNAPSHOT": "task body does not survive snapshot/restore",
    "SCHEMA-DOC": "wire type missing from docs/protocol.md",
    "SCHEMA-MC": "wire type not modeled by any model-checker action",
}

_PROTO = "protocol.py"


def registered_types() -> Dict[str, type]:
    """Name -> class for every ``@wire``-registered dataclass."""
    return dict(protocol._WIRE_TYPES)


def default_doc_path() -> pathlib.Path:
    return pathlib.Path(protocol.__file__).resolve().parents[3] \
        / "docs" / "protocol.md"


def sample(cls):
    """A representative instance: required fields filled by annotation
    (stringified under ``from __future__ import annotations``), defaults
    left alone. Payload-ish ``Any`` fields get None — the codec must carry
    that (simulated volunteers send exactly that shape)."""
    kwargs = {}
    for f in dataclasses.fields(cls):
        if f.default is not dataclasses.MISSING or \
                f.default_factory is not dataclasses.MISSING:
            continue
        ann = str(f.type)
        if "bool" in ann:
            kwargs[f.name] = True
        elif "int" in ann:
            kwargs[f.name] = 1
        elif "float" in ann:
            kwargs[f.name] = 0.5
        elif "str" in ann:
            kwargs[f.name] = "x"
        elif "Dict" in ann or "dict" in ann:
            kwargs[f.name] = {}
        elif "List" in ann or "list" in ann:
            kwargs[f.name] = []
        elif "Tuple" in ann or "tuple" in ann:
            kwargs[f.name] = ()
        else:
            kwargs[f.name] = None
    return cls(**kwargs)


def check_roundtrip(types: Dict[str, type]) -> List[Violation]:
    out = []
    for name, cls in sorted(types.items()):
        try:
            inst = sample(cls)
            back = protocol.decode_message(protocol.encode_message(inst))
        except Exception as e:
            out.append(Violation(
                "SCHEMA-ROUNDTRIP", _PROTO, 0,
                f"{name} failed encode/decode: {e!r}"))
            continue
        if back != inst:
            out.append(Violation(
                "SCHEMA-ROUNDTRIP", _PROTO, 0,
                f"{name} round-trip changed the value: {inst!r} -> {back!r}"))
    return out


def check_partition(types: Dict[str, type]) -> List[Violation]:
    roles = (("request", set(protocol.REQUEST_TYPES)),
             ("reply", set(protocol.REPLY_TYPES)),
             ("notification", set(protocol.NOTIFICATION_TYPES)),
             ("task body", set(tasks.WIRE_TYPES)))
    out = []
    for name, cls in sorted(types.items()):
        hits = [role for role, members in roles if cls in members]
        if len(hits) != 1:
            what = "none of" if not hits else f"multiple ({', '.join(hits)})"
            out.append(Violation(
                "SCHEMA-PARTITION", _PROTO, 0,
                f"{name} is registered on the wire but classified in {what} "
                f"REQUEST/REPLY/NOTIFICATION/task-body roles — dispatch "
                f"cannot place it"))
    return out


def check_dispatch() -> List[Violation]:
    """Requests must appear in an ``isinstance(msg, X)`` arm; replies and
    notifications must be constructed somewhere in protocol.py."""
    tree = ast.parse(pathlib.Path(protocol.__file__).read_text())
    dispatched, constructed = set(), set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if isinstance(fn, ast.Name) and fn.id == "isinstance" \
                and len(node.args) == 2:
            arg = node.args[1]
            elts = arg.elts if isinstance(arg, ast.Tuple) else [arg]
            dispatched |= {e.id for e in elts if isinstance(e, ast.Name)}
        elif isinstance(fn, ast.Name):
            constructed.add(fn.id)
        elif isinstance(fn, ast.Attribute):
            constructed.add(fn.attr)
    out = []
    for cls in protocol.REQUEST_TYPES:
        if cls.__name__ not in dispatched:
            out.append(Violation(
                "SCHEMA-DISPATCH", _PROTO, 0,
                f"request {cls.__name__} has no isinstance arm in "
                f"ServerEndpoint dispatch — the server drops it silently"))
    for cls in protocol.REPLY_TYPES + protocol.NOTIFICATION_TYPES:
        if cls.__name__ not in constructed:
            out.append(Violation(
                "SCHEMA-DISPATCH", _PROTO, 0,
                f"reply/notification {cls.__name__} is never constructed in "
                f"protocol.py — dead wire type or dispatch hole"))
    return out


def check_snapshot(types: Optional[Iterable[type]] = None) -> List[Violation]:
    """Each task body must survive a full durable cycle: publish -> lease ->
    snapshot -> wire bytes -> restore -> identical second snapshot."""
    out = []
    for cls in (tasks.WIRE_TYPES if types is None else types):
        name = cls.__name__
        try:
            qs = QueueServer(default_timeout=5.0)
            qs.publish("q", sample(cls))
            qs.publish("q", sample(cls))
            qs.lease("q", "w0", 0.0)
            snap = qs.snapshot()
            blob = protocol.encode_message(snap)
            qs2 = QueueServer(default_timeout=5.0)
            qs2.restore(protocol.decode_message(blob))
            again = qs2.snapshot()
        except Exception as e:
            out.append(Violation(
                "SCHEMA-SNAPSHOT", _PROTO, 0,
                f"{name} broke the snapshot/restore cycle: {e!r}"))
            continue
        if again != snap:
            out.append(Violation(
                "SCHEMA-SNAPSHOT", _PROTO, 0,
                f"{name}: restored snapshot differs from the original — "
                f"this task body does not survive a server restart"))
    return out


def check_doc(doc_path=None,
              types: Optional[Dict[str, type]] = None) -> List[Violation]:
    doc_path = default_doc_path() if doc_path is None else \
        pathlib.Path(doc_path)
    types = registered_types() if types is None else types
    try:
        text = doc_path.read_text()
    except OSError as e:
        return [Violation("SCHEMA-DOC", str(doc_path), 0,
                          f"protocol doc unreadable: {e}")]
    out = []
    for name in sorted(types):
        if f"`{name}`" not in text:
            out.append(Violation(
                "SCHEMA-DOC", str(doc_path), 0,
                f"wire type {name} is not documented — add a `{name}` entry"))
    return out


def check_mc_coverage(
        covered: Optional[Dict[str, str]] = None) -> List[Violation]:
    """Every REQUEST/NOTIFICATION wire type must map to a model-checker
    action in ``repro.analysis.mc.COVERED_MESSAGES`` — otherwise the
    protocol has grown a message the exhaustive search never exercises.
    Replies are excluded: they only exist as the return values of the
    requests that elicit them, so request coverage subsumes them. ``covered``
    overrides the shipped map (the fixture tests inject a partial one)."""
    if covered is None:
        from repro.analysis.mc import COVERED_MESSAGES
        covered = COVERED_MESSAGES
    out = []
    for cls in (*protocol.REQUEST_TYPES, *protocol.NOTIFICATION_TYPES):
        name = cls.__name__
        if not str(covered.get(name, "") or "").strip():
            out.append(Violation(
                "SCHEMA-MC", _PROTO, 0,
                f"wire type {name} has no model-checker action mapping — "
                f"add it to repro.analysis.mc.COVERED_MESSAGES and model "
                f"the action that sends it"))
    return out


def run(doc_path=None,
        extra_types: Tuple[type, ...] = ()) -> List[Violation]:
    """All six checks over the registry (plus ``extra_types``, which tests
    use to inject rogue types without touching the global registry)."""
    types = registered_types()
    for cls in extra_types:
        types[cls.__name__] = cls
    out: List[Violation] = []
    out.extend(check_roundtrip(types))
    out.extend(check_partition(types))
    out.extend(check_dispatch())
    out.extend(check_snapshot())
    out.extend(check_doc(doc_path, types))
    out.extend(check_mc_coverage())
    return out
