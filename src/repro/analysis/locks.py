"""Lock-order race detector (pass "locks") — the static half.

Extracts the lock-acquisition graph of the threaded core modules from their
ASTs and fails on cycles. Nodes are lock attributes assigned from a lock
factory (``threading.Lock()``/``RLock()`` or the gateway's ``_make_lock``
seam), named ``<module>.<attr>``; an edge ``a -> b`` means some code path
acquires ``b`` while holding ``a`` — from nested ``with`` statements, from
bare ``.acquire()`` calls, and from transitive intra-module call resolution:
a ``with self._lock:`` body calling a method that (through any bounded,
cycle-safe chain of same-module helpers, lock-free intermediates included)
takes another lock contributes the edge — a helper that takes no lock itself
cannot hide the locks past it. Any cycle is a potential deadlock: two
threads entering the cycle
from different ends can each hold what the other needs, and no test will
reliably catch the interleaving.

The default file set is gateway.py + queue.py + dataserver.py: the gateway
is the only threaded engine, and queue.py/dataserver.py are deliberately
lock-free (single-threaded under the dispatch lock) — if a lock ever
appears there, it joins this graph automatically.

The runtime half (``repro.analysis.runtime``) replays this check against
ORDERS actually observed during the instrumented ``gateway --smoke`` legs:
``static_edges()`` is loaded by ``Analysis.instrument()`` so an observed
acquisition that inverts the static graph is flagged even if the opposing
static path never runs in that process.
"""
from __future__ import annotations

import ast
import importlib.util
import pathlib
from collections import defaultdict
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.base import Violation

LOCK_FACTORIES = {"Lock", "RLock", "_make_lock", "allocate_lock"}

#: core modules whose lock graph CI checks (see module docstring)
DEFAULT_MODULES = ("gateway", "queue", "dataserver")


def default_paths() -> List[pathlib.Path]:
    out = []
    for mod in DEFAULT_MODULES:
        spec = importlib.util.find_spec(f"repro.core.{mod}")
        out.append(pathlib.Path(spec.origin))
    return out


def _call_name(func: ast.AST) -> Optional[str]:
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


def _resolvable(func: ast.AST) -> bool:
    """True when a call may target a same-module function/method: a bare
    name ``f()`` or a ``self.f()``/``cls.f()`` method call. Calls through any
    other receiver (``qs.snapshot()``, ``self.qs.lease()``) are a foreign
    object's methods — resolving those by simple name would conflate e.g.
    ``QueueServer.snapshot`` with the gateway's own ``snapshot``."""
    if isinstance(func, ast.Name):
        return True
    if isinstance(func, ast.Attribute):
        return isinstance(func.value, ast.Name) and \
            func.value.id in ("self", "cls")
    return False


def _lock_attrs(tree: ast.AST) -> Set[str]:
    """Attribute/variable names assigned from a lock factory."""
    names = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call) \
                and _call_name(node.value.func) in LOCK_FACTORIES:
            for t in node.targets:
                if isinstance(t, ast.Attribute):
                    names.add(t.attr)
                elif isinstance(t, ast.Name):
                    names.add(t.id)
    return names


def _lock_of(expr: ast.AST, lockset: Set[str]) -> Optional[str]:
    """``self._lock`` / ``_lock`` -> the lock's attr name, if known."""
    if isinstance(expr, ast.Attribute) and expr.attr in lockset:
        return expr.attr
    if isinstance(expr, ast.Name) and expr.id in lockset:
        return expr.id
    return None


class _FnInfo:
    """Per-function facts: locks it acquires anywhere, direct nesting edges,
    calls made while holding locks (the edge sources), and ALL calls made
    anywhere in the body (the resolution graph — a lock-free helper in the
    middle of a call chain must not hide the locks past it)."""

    def __init__(self):
        self.acquires: Set[str] = set()
        self.edges: Set[Tuple[str, str]] = set()
        self.calls_while_held: List[Tuple[Tuple[str, ...], str]] = []
        self.calls: Set[str] = set()


_SCOPES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef, ast.Lambda)


def _scan(node: ast.AST, held: Tuple[str, ...], lockset: Set[str],
          qual, info: _FnInfo) -> None:
    """Walk one statement/expression threading the held-lock stack through
    nested ``with`` blocks. Bare ``.acquire()`` contributes edges and
    membership but not held-ness (no linear release tracking — ``with`` is
    the idiom the core uses; acquire/release pairs still register in the
    graph)."""
    if isinstance(node, _SCOPES):
        return                       # separate scope: scanned on its own
    if isinstance(node, (ast.With, ast.AsyncWith)):
        got = held
        for item in node.items:
            lk = _lock_of(item.context_expr, lockset)
            if lk is not None:
                name = qual(lk)
                for h in got:
                    info.edges.add((h, name))
                info.acquires.add(name)
                got = got + (name,)
            else:
                _scan(item.context_expr, got, lockset, qual, info)
        for st in node.body:
            _scan(st, got, lockset, qual, info)
        return
    if isinstance(node, ast.Call):
        nm = _call_name(node.func)
        if nm == "acquire" and isinstance(node.func, ast.Attribute):
            lk = _lock_of(node.func.value, lockset)
            if lk is not None:
                name = qual(lk)
                for h in held:
                    info.edges.add((h, name))
                info.acquires.add(name)
        elif nm is not None and nm != "release" and _resolvable(node.func):
            info.calls.add(nm)
            if held:
                info.calls_while_held.append((held, nm))
    for child in ast.iter_child_nodes(node):
        _scan(child, held, lockset, qual, info)


def _scan_file(path: pathlib.Path):
    """-> (lock names, edges, per-name _FnInfo map) for one module."""
    source = path.read_text()
    tree = ast.parse(source, filename=str(path))
    lockset = _lock_attrs(tree)
    stem = path.stem

    def qual(attr: str) -> str:
        return f"{stem}.{attr}"

    functions: Dict[str, _FnInfo] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            info = functions.setdefault(node.name, _FnInfo())
            for st in node.body:
                _scan(st, (), lockset, qual, info)
    # resolve calls made under a held lock: the callee's transitive acquires
    # (same module, matched by simple name) become edges from each held lock.
    # Resolution follows ALL calls — including ones made with no lock held —
    # so a lock-free helper between the holder and the acquirer cannot hide
    # the edge; it is cycle-safe (``seen``) and depth-bounded (recursion
    # deeper than any sane same-module helper chain stops contributing)
    _MAX_RESOLVE_DEPTH = 16

    def all_acquires(name: str, seen: frozenset) -> Set[str]:
        info = functions.get(name)
        if info is None or name in seen or len(seen) >= _MAX_RESOLVE_DEPTH:
            return set()
        acq = set(info.acquires)
        for callee in info.calls:
            acq |= all_acquires(callee, seen | {name})
        return acq

    edges: Set[Tuple[str, str]] = set()
    for info in functions.values():
        edges |= info.edges
        for held, callee in info.calls_while_held:
            for lk in all_acquires(callee, frozenset()):
                for h in held:
                    if h != lk:
                        edges.add((h, lk))
    locks = {qual(a) for a in lockset}
    return locks, edges


def lock_graph(paths: Iterable) -> Tuple[Set[str], Set[Tuple[str, str]]]:
    """Union of every file's (locks, edges)."""
    locks: Set[str] = set()
    edges: Set[Tuple[str, str]] = set()
    for path in paths:
        lk, ed = _scan_file(pathlib.Path(path))
        locks |= lk
        edges |= ed
    return locks, edges


def static_edges(paths: Iterable) -> Set[Tuple[str, str]]:
    """The acquisition-order edges alone (what the runtime monitor loads)."""
    return lock_graph(paths)[1]


def find_cycles(edges: Set[Tuple[str, str]]) -> List[List[str]]:
    graph = defaultdict(set)
    for a, b in edges:
        graph[a].add(b)
    cycles: List[List[str]] = []
    seen_sets: Set[frozenset] = set()
    done: Set[str] = set()

    def dfs(n: str, path: List[str], onpath: Set[str]) -> None:
        for m in sorted(graph[n]):
            if m in onpath:
                cyc = path[path.index(m):] + [m]
                key = frozenset(cyc)
                if key not in seen_sets:
                    seen_sets.add(key)
                    cycles.append(cyc)
            elif m not in done:
                dfs(m, path + [m], onpath | {m})
        done.add(n)

    for n in sorted(graph):
        if n not in done:
            dfs(n, [n], {n})
    return cycles


def check(paths: Iterable) -> List[Violation]:
    """One LOCK-ORDER violation per distinct cycle in the union graph."""
    paths = [pathlib.Path(p) for p in paths]
    _, edges = lock_graph(paths)
    out = []
    for cyc in find_cycles(edges):
        out.append(Violation(
            "LOCK-ORDER", str(paths[0]) if paths else "<locks>", 0,
            "lock-acquisition cycle " + " -> ".join(cyc) +
            " — two threads entering from different ends deadlock; pick one "
            "global order"))
    return out
