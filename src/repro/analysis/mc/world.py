"""The model checker's concrete world: real protocol objects, one state.

An ``MCWorld`` wires N real ``VolunteerSession`` objects to a real
``ServerEndpoint`` (``QueueServer`` + ``DataServer``) over an
``InProcessTransport`` — no mocks; the shipped ``protocol.py`` IS the model —
and exposes the three verbs an explicit-state explorer needs:

- ``enabled_actions()`` — every action legal in this state, deterministic
  order. One action = one engine event (one session API call and the atomic
  protocol sequence inside it), matching the granularity at which the real
  engines (Simulator/gateway) interleave volunteers.
- ``apply(action)``    — execute one action, mutating the world in place.
- ``capture()`` / ``restore(cap)`` — branch points. Restore REBUILDS fresh
  servers from ``QueueServer.snapshot()``/``DataServer.snapshot()`` (the same
  wire-durable state the gateway persists) and re-registers waiters/watches
  through real ``SubscribeQueue``/``WatchVersion`` messages, so every single
  explored transition doubles as a snapshot/restore injection between two
  dispatches — durability is exercised at every edge, not sampled.

## The action alphabet

Per volunteer: ``lease``, ``advance``, ``finish``, ``wake`` (consume one
delivered notification), ``heartbeat``, ``release`` (the step-aside escape
hatch), ``crash`` (hard: connection drops, leases recover only via expiry),
``rejoin`` (fresh session, zombie cleanup via ``abort``), ``leave`` (clean
``bye``). Global: ``deliver``/``drop``/``dup`` — the fate of the OLDEST
undelivered notification (the ``FaultyTransport`` fault set, budgeted by
``max_drops``/``max_dups``) — and ``expire`` (advance virtual time to the
next lease deadline and sweep, i.e. lease expiry at every legal point).

Partial-order reduction: only the head of the pending-notification list
branches. Notifications to different consumers commute (delivery only
appends to disjoint per-volunteer mailboxes; *acting* on a mailbox is a
separate ``wake`` action), so exploring all fates of the head is sound.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Tuple

from repro.core.aggregation import AggregationPolicy, make_policy
from repro.core.dataserver import DataServer
from repro.core.initiator import enqueue_problem
from repro.core.protocol import (ApplyWork, Blocked, Busy, ExpireAll, Hello,
                                 LocalWork, MapWork, NoTask, ReduceWork,
                                 ServerApplier, ServerEndpoint,
                                 SubscribeQueue, TaskDone, VolunteerSession,
                                 WatchVersion)
from repro.core.queue import QueueServer, VirtualClock
from repro.core.simulator import SyntheticProblem
from repro.core.tasks import INITIAL_QUEUE, results_queue
from repro.core.transport import InProcessTransport

# actions whose availability means the run can still move forward; fault
# injection (crash/drop/dup/leave) and lease renewal (heartbeat) cannot
# unstick a run by themselves, so they do not count against deadlock
PROGRESS_KINDS = frozenset(
    {"lease", "advance", "finish", "wake", "deliver", "expire", "release"})

_ALIVE = ("idle", "task", "parked", "parked_idle", "computing")


@dataclass(frozen=True)
class MCConfig:
    """One bounded exploration problem: fleet, policy, and fault budget.

    ``policy_object`` overrides the ``policy`` spec string with a concrete
    ``AggregationPolicy`` instance — the hook mutation fixtures use to plant
    a buggy policy the checker must catch.
    """
    policy: str = "sync"
    n_volunteers: int = 2
    n_versions: int = 2
    n_mb: int = 2
    visibility_timeout: float = 10.0
    crashable: Tuple[str, ...] = ()
    max_crashes: int = 0
    rejoin: bool = False
    leavable: Tuple[str, ...] = ()
    max_leaves: int = 0
    max_drops: int = 0
    max_dups: int = 0
    # expiry injections are unbounded by default (None) — the realistic
    # setting, but it makes every world with in-flight tickets inexhaustible
    # (expire/re-lease cycles never dedup: redelivery accounting grows).
    # A finite budget turns a tiny world into a genuinely exhaustive search.
    max_expiries: Optional[int] = None
    allow_release: bool = True
    allow_heartbeat: bool = False
    server_apply: bool = False
    gc_keep: Optional[int] = None
    policy_object: Any = None

    def make_policy(self) -> AggregationPolicy:
        return make_policy(
            self.policy_object if self.policy_object is not None
            else self.policy)

    def make_world(self) -> "MCWorld":
        """The concrete world this config describes. Subclasses (the gateway
        micro-world) override so the explorer/replayer construct the right
        world type from the config alone."""
        return MCWorld(self)

    def default_invariants(self) -> List["Invariant"]:  # noqa: F821
        """The invariant catalog checked when the caller supplies none;
        subclasses extend it with world-specific invariants."""
        from repro.analysis.mc.invariants import DEFAULT_INVARIANTS
        return list(DEFAULT_INVARIANTS)

    def to_json(self) -> Dict[str, Any]:
        d = {f: getattr(self, f) for f in self.__dataclass_fields__}
        d.pop("policy_object")
        d["crashable"] = list(self.crashable)
        d["leavable"] = list(self.leavable)
        return d

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "MCConfig":
        kw = dict(d)
        world = kw.pop("world", None)
        if world == "gateway" and cls is MCConfig:
            from repro.analysis.mc.gateway_world import GatewayMCConfig
            return GatewayMCConfig.from_json(d)
        kw["crashable"] = tuple(kw.get("crashable", ()))
        kw["leavable"] = tuple(kw.get("leavable", ()))
        return cls(**kw)


@dataclass
class _Driver:
    """The engine-side view of one volunteer: what the loop around the
    session would be doing (idle / holding / parked / computing / dead)."""
    vid: str
    state: str = "idle"
    blocked: Optional[Blocked] = None
    work: Any = None
    mailbox: List[Any] = field(default_factory=list)
    dropped: int = 0   # injected drops aimed at this volunteer (sticky)


class _Port(InProcessTransport):
    """InProcessTransport that records every request type it carries, so the
    coverage test can prove ``COVERED_MESSAGES`` is honest (every declared
    wire type is actually exercised, not just listed)."""

    def __init__(self, endpoint: ServerEndpoint, sent: set):
        super().__init__(endpoint)
        self._sent = sent

    def call(self, msg):
        self._sent.add(type(msg).__name__)
        return super().call(msg)


class MCWorld:
    def __init__(self, cfg: MCConfig):
        self.cfg = cfg
        self.policy = cfg.make_policy()
        self.problem = SyntheticProblem(
            n_versions=cfg.n_versions, n_mb=cfg.n_mb, mini_batch_size=1,
            model_bytes=8, grad_bytes=8, map_flops=1.0, reduce_flops=1.0)
        self.n_updates = self.policy.n_updates(self.problem, cfg.n_versions)
        self.vids = tuple(f"w{i}" for i in range(cfg.n_volunteers))
        self.sent_types: set = set()   # exploration-global coverage ledger
        self.now = 0.0
        self.pending: List[Tuple[str, Any]] = []   # undelivered notifications
        self.crashes = self.leaves = self.drops = self.dups = 0
        self.expiries = 0
        self.undeliverable = 0
        self.applied: List[Tuple[int, int]] = []   # (computed_at, applied_at)
        self.commit_meta: List[Tuple[int, str]] = []  # (version, slot key)
        self._fresh_servers()
        self.n_scheduled = enqueue_problem(
            self.problem, self.qs, self.ds, n_versions=cfg.n_versions,
            policy=self.policy, store_real_model=False)
        if self.policy.barrier:
            # pre-declare the per-version results queues so a DepthReq probe
            # (declare-on-read) cannot make two otherwise-equal states differ
            for v in range(cfg.n_versions):
                self.qs.declare(results_queue(v))
        self.sessions: Dict[str, VolunteerSession] = {}
        self.drivers: Dict[str, _Driver] = {}
        for vid in self.vids:
            self.sessions[vid] = VolunteerSession(vid, self.port,
                                                  policy=self.policy)
            self.drivers[vid] = _Driver(vid)
            self.port.call(Hello(vid))

    # -- wiring -------------------------------------------------------------
    def _fresh_servers(self) -> None:
        cfg = self.cfg
        self.qs = QueueServer(default_timeout=cfg.visibility_timeout)
        self.ds = DataServer()
        applier = None
        if cfg.server_apply:
            applier = ServerApplier(self.policy,
                                    lambda blob, result, v: "blob",
                                    gc_keep=cfg.gc_keep)
        self.endpoint = ServerEndpoint(
            self.qs, self.ds, clock=VirtualClock(lambda: self.now),
            applier=applier)
        self.port = _Port(self.endpoint, self.sent_types)
        self.port.set_deliver(self._on_notify)

    def _on_notify(self, consumer: str, msg) -> None:
        self.sent_types.add(type(msg).__name__)
        d = self.drivers.get(consumer) if hasattr(self, "drivers") else None
        if d is None or d.state not in _ALIVE:
            # the connection is gone: the frame falls on the floor (this is
            # delivery loss the SERVER caused by crash/leave, not a budgeted
            # injected fault)
            self.undeliverable += 1
            return
        self.pending.append((consumer, msg))

    # -- predicates ---------------------------------------------------------
    def complete(self) -> bool:
        return self.ds.latest_version >= self.n_updates

    def enabled_actions(self) -> List[Tuple[str, ...]]:
        """Every action legal in this state, in a deterministic order with
        protocol moves (deliver/wake/lease/advance/finish) first and fault
        injections (crash/leave/drop/dup/expire/heartbeat/release) last: the
        explorer's DFS stack pops the LAST element first, so it dives into
        the fault corners — where the bugs live — before exhausting the
        happy-path interleavings, and counterexamples surface early even
        when the budget truncates the search."""
        cfg = self.cfg
        faults: List[Tuple[str, ...]] = []
        moves: List[Tuple[str, ...]] = []
        if self.pending:
            if self.drops < cfg.max_drops:
                faults.append(("drop",))
            if self.dups < cfg.max_dups:
                faults.append(("dup",))
            moves.append(("deliver",))
        if self.qs.next_deadline() is not None and \
                (cfg.max_expiries is None or
                 self.expiries < cfg.max_expiries):
            faults.append(("expire",))
        for vid in self.vids:
            d = self.drivers[vid]
            if d.state == "crashed":
                if cfg.rejoin:
                    faults.append(("rejoin", vid))
                continue
            if d.state in ("gone", "done"):
                continue
            if vid in cfg.crashable and self.crashes < cfg.max_crashes:
                faults.append(("crash", vid))
            if vid in cfg.leavable and self.leaves < cfg.max_leaves:
                faults.append(("leave", vid))
            if cfg.allow_heartbeat and self.sessions[vid].holding and \
                    d.state in ("task", "parked", "computing"):
                faults.append(("heartbeat", vid))
            if d.state == "parked" and cfg.allow_release and \
                    self.sessions[vid].holding and \
                    self.qs.depth(INITIAL_QUEUE) > 0:
                faults.append(("release", vid))
            if d.mailbox:
                moves.append(("wake", vid))
            if d.state == "idle":
                moves.append(("lease", vid))
            elif d.state == "task":
                moves.append(("advance", vid))
            elif d.state == "computing":
                moves.append(("finish", vid))
        return moves + faults

    def symmetry_possible(self) -> bool:
        """True when at least two volunteers have identical fault-capability
        flags — the precondition for the symmetry reduction to ever merge two
        DIFFERENT concrete states. When false the explorer skips the raw
        (unrenamed) fingerprint bookkeeping entirely: every volunteer's blob
        carries distinct flags, so any state isomorphism fixes every
        volunteer and canonical equality coincides with concrete equality."""
        caps = [(v in self.cfg.crashable, v in self.cfg.leavable)
                for v in self.vids]
        return len(set(caps)) < len(caps)

    def progress_possible(self, acts=None) -> bool:
        acts = self.enabled_actions() if acts is None else acts
        return any(a[0] in PROGRESS_KINDS for a in acts)

    def fleet_exhausted(self) -> bool:
        """Every volunteer crashed/left/retired: the run stalls because the
        fleet died, which the paper treats as the norm, not a protocol bug —
        the server just waits for new volunteers."""
        return all(self.drivers[v].state in ("crashed", "gone", "done")
                   for v in self.vids)

    def poll_ready(self) -> bool:
        """Would a watchdog poll tick un-park somebody? True when a parked
        volunteer's wait condition is ALREADY satisfied — the wake it missed
        was eaten by an injected fault (drop, or a crash clearing a mailbox);
        the real engines recover these by timed waits + re-checks, so a stuck
        state that is poll-ready is 'stranded', not deadlocked."""
        for vid in self.vids:
            d = self.drivers[vid]
            if d.state == "parked_idle":
                if self.qs.depth(INITIAL_QUEUE) > 0:
                    return True
            elif d.state == "parked" and d.blocked is not None:
                b = d.blocked
                if b.version is not None:
                    if self.ds.latest_version >= b.version:
                        return True
                elif b.queue is not None:
                    need = 1
                    task = self.sessions[vid].task
                    if b.kind == "publish" and task is not None and \
                            getattr(task, "kind", "") == "reduce":
                        need = task.n_mb
                    if self.qs.depth(b.queue) >= need:
                        return True
        return False

    # -- the step function --------------------------------------------------
    def apply(self, action: Tuple[str, ...]) -> None:
        kind = action[0]
        if kind == "deliver":
            c, m = self.pending.pop(0)
            self.drivers[c].mailbox.append(m)
        elif kind == "drop":
            c, _ = self.pending.pop(0)
            self.drops += 1
            self.drivers[c].dropped += 1
        elif kind == "dup":
            c, m = self.pending.pop(0)
            self.dups += 1
            self.drivers[c].mailbox.extend((m, m))
        elif kind == "expire":
            deadline = self.qs.next_deadline()
            assert deadline is not None, "expire with no finite deadline"
            self.expiries += 1
            self.now = max(self.now, deadline)
            # the sweep goes through the wire op (``ExpireAll`` carries the
            # authoritative now, applied verbatim) — the same message the
            # gateway's sweeper dispatches so its op log can replay expiry
            self.port.call(ExpireAll(self.now))
        elif kind == "heartbeat":
            # the shipped engines ignore the renewal result (gateway: a
            # zombie keeps acting and its eventual ack/nack hits a dead or
            # re-leased tag) — model exactly that, races included
            self.sessions[action[1]].heartbeat(self.now)
        elif kind == "release":
            vid = action[1]
            self.sessions[vid].release(front=False)
            self._to_idle(vid)
        elif kind == "crash":
            vid = action[1]
            self.crashes += 1
            self.endpoint.disconnect(vid)
            d = self.drivers[vid]
            d.state, d.blocked, d.work, d.mailbox = "crashed", None, None, []
            self.pending = [(c, m) for c, m in self.pending if c != vid]
        elif kind == "rejoin":
            vid = action[1]
            self.sessions[vid] = VolunteerSession(vid, self.port,
                                                  policy=self.policy)
            self.port.call(Hello(vid))
            self.sessions[vid].abort(kick_if_empty=True)
            self._to_idle(vid)
        elif kind == "leave":
            vid = action[1]
            self.leaves += 1
            self.sessions[vid].bye()
            d = self.drivers[vid]
            d.state, d.blocked, d.work, d.mailbox = "gone", None, None, []
            self.pending = [(c, m) for c, m in self.pending if c != vid]
        elif kind == "lease":
            self._do_lease(action[1])
        elif kind == "advance":
            self._do_advance(action[1])
        elif kind == "finish":
            self._do_finish(action[1])
        elif kind == "wake":
            vid = action[1]
            self.drivers[vid].mailbox.pop(0)
            # the engines' _continue: no task -> try to lease, else advance
            if self.sessions[vid].task is None:
                if self.drivers[vid].state in ("idle", "parked_idle"):
                    self._do_lease(vid)
            else:
                self._do_advance(vid)
        else:
            raise ValueError(f"unknown action {action!r}")

    def _to_idle(self, vid: str) -> None:
        d = self.drivers[vid]
        d.state, d.blocked, d.work = "idle", None, None

    def _do_lease(self, vid: str) -> None:
        d = self.drivers[vid]
        if self.complete():
            d.state, d.blocked = "done", None
            return
        out = self.sessions[vid].lease(self.now)
        if isinstance(out, NoTask):
            if self.sessions[vid].queue_drained():
                d.state, d.blocked = "done", None
            else:
                self.sessions[vid].subscribe_idle()
                d.state, d.blocked = "parked_idle", None
        else:
            d.state, d.blocked = "task", None

    def _do_advance(self, vid: str) -> None:
        d = self.drivers[vid]
        out = self.sessions[vid].advance(self.now)
        if isinstance(out, Busy):
            return                       # spurious wake mid-compute
        if isinstance(out, TaskDone):
            self._to_idle(vid)           # obsolete duplicate, acked
        elif isinstance(out, Blocked):
            self.sessions[vid].subscribe(out)
            d.state, d.blocked, d.work = "parked", out, None
        else:                            # MapWork | LocalWork | ReduceWork
            d.state, d.blocked, d.work = "computing", None, out

    def _do_finish(self, vid: str) -> None:
        """Compute done + the commit/submit protocol sequence, as ONE engine
        event (the same atomicity the virtual-time engines provide)."""
        d = self.drivers[vid]
        sess = self.sessions[vid]
        work, d.work = d.work, None
        before = self.ds.latest_version
        slot = self._slot_key(work.task)
        if isinstance(work, ReduceWork):
            sess.finish_reduce("blob", 0, gc_keep=self.cfg.gc_keep)
        elif self.policy.barrier:
            sess.finish_map(("g", work.task.mb_index), 0, 0.0)
        else:
            if isinstance(work, LocalWork):
                result = sess.delta_result("delta", 0, 0.0)
            else:
                result = sess.grad_result(("g", work.task.mb_index), 0, 0.0)
            if self.cfg.server_apply:
                out = sess.submit_update(result)
                if not out.stale:
                    self.applied.append((result.computed_at, out.version - 1))
            else:
                out = sess.finish_update(result)
                if isinstance(out, ApplyWork):
                    # admission + apply + publish are one atomic commit
                    self.applied.append((result.computed_at, out.version))
                    sess.commit_update("blob", 0, gc_keep=self.cfg.gc_keep)
        after = self.ds.latest_version
        if after == before + 1:
            self.commit_meta.append((after, slot))
        self._to_idle(vid)

    @staticmethod
    def _slot_key(task) -> str:
        kind = getattr(task, "kind", "?")
        if kind == "map":
            return f"map:{task.version}:{task.mb_index}"
        if kind == "reduce":
            return f"reduce:{task.version}"
        if kind == "local":
            return f"local:{task.slot}"
        return repr(task)

    # -- branch points ------------------------------------------------------
    def capture(self) -> Dict[str, Any]:
        """Everything needed to rebuild this exact state — all of it the
        protocol's own durable/introspectable surface (queue + data snapshots,
        waiter/watch views, session state views), no Python object graphs."""
        cap = {
            "qs": self.qs.snapshot(),
            "ds": self.ds.snapshot(),
            "now": self.now,
            "watches": list(self.endpoint.watch_view()),
            "waiters": self.qs.waiter_views(),
            "sessions": {v: self.sessions[v].state_view() for v in self.vids},
            "drivers": {v: {"state": d.state, "blocked": d.blocked,
                            "work": d.work, "mailbox": list(d.mailbox),
                            "dropped": d.dropped}
                        for v, d in self.drivers.items()},
            "pending": list(self.pending),
            "counters": (self.crashes, self.leaves, self.drops, self.dups,
                         self.expiries, self.undeliverable),
            "applied": list(self.applied),
            "commit_meta": list(self.commit_meta),
        }
        if self.endpoint.applier is not None:
            cap["applier"] = (self.endpoint.applier.applied,
                              self.endpoint.applier.rejected)
        return cap

    def restore(self, cap: Dict[str, Any]) -> None:
        """Rebuild from a capture: fresh servers restored from their own
        snapshots, fresh sessions loaded from their state views, and live
        waits re-registered through real SubscribeQueue/WatchVersion
        messages. Every branch the explorer takes therefore replays the
        gateway's crash-recovery path."""
        self.now = cap["now"]
        (self.crashes, self.leaves, self.drops, self.dups,
         self.expiries, self.undeliverable) = cap["counters"]
        self.applied = list(cap["applied"])
        self.commit_meta = list(cap["commit_meta"])
        self._fresh_servers()
        self.qs.restore(cap["qs"], waiters_from={})
        self.ds.restore(cap["ds"])
        if "applier" in cap:
            self.endpoint.applier.applied = cap["applier"][0]
            self.endpoint.applier.rejected = cap["applier"][1]
        self.sessions = {}
        self.drivers = {}
        for vid in self.vids:
            sess = VolunteerSession(vid, self.port, policy=self.policy)
            sess.load_view(cap["sessions"][vid])
            self.sessions[vid] = sess
            dd = cap["drivers"][vid]
            self.drivers[vid] = _Driver(
                vid, state=dd["state"], blocked=dd["blocked"],
                work=dd["work"], mailbox=list(dd["mailbox"]),
                dropped=dd["dropped"])
        self.pending = list(cap["pending"])
        self._reregister_waits(cap)

    def _reregister_waits(self, cap: Dict[str, Any]) -> None:
        """Re-register live waits in their captured FIFO order. Safe from
        immediate fires: a banked signal and a registered waiter never
        coexist (the queue consumes the bank at subscribe), and a live
        watch key implies the version is still uncommitted. The gateway
        micro-world overrides this to route each re-subscription through the
        consumer's own home gateway (exercising ``Forward`` registration)."""
        for qname, kinds in cap["waiters"].items():
            for c in kinds["any"]:
                self.endpoint.handle(SubscribeQueue(qname, c, "any"))
            for c in kinds["publish"]:
                self.endpoint.handle(SubscribeQueue(qname, c, "publish"))
        for consumer, version in cap["watches"]:
            self.endpoint.handle(WatchVersion(version, consumer))

    def fork(self) -> "MCWorld":
        """A fresh world for the same config (root state)."""
        return replace(self.cfg).make_world()
