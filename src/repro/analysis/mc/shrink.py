"""Counterexample replay, shrinking, and runnable-repro emission.

``replay`` re-executes an action trace against a fresh world, validating at
each step that the action is still enabled and checking every invariant —
deterministically, so the same trace always produces the same verdict (the
bit-determinism the regression tests assert).

``shrink`` is greedy delta-debugging over the trace: repeatedly try dropping
chunks (then single actions) and keep any candidate that still (a) stays
applicable end-to-end and (b) violates the SAME invariant. The result is
1-minimal: removing any single remaining action loses the violation.

``repro_payload`` / ``repro_script`` package a shrunk trace as JSON plus a
self-contained Python script that replays it through the chaos harness
(``repro.core.chaos.replay_mc_trace``) — a violation found by exhaustive
search becomes an ordinary runnable regression artifact.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.analysis.mc.fingerprint import fingerprint
from repro.analysis.mc.invariants import DEADLOCK, Invariant, check_all
from repro.analysis.mc.world import MCConfig

Action = Tuple[str, ...]


class Replay:
    """Outcome of replaying one trace: the violation (if any), the step it
    fired at, and the final state fingerprint (the determinism observable)."""

    def __init__(self, violation: Optional[Tuple[str, str]], step: int,
                 final_fingerprint: bytes, applied: int):
        self.violation = violation
        self.step = step
        self.final_fingerprint = final_fingerprint
        self.applied = applied

    @property
    def invariant(self) -> Optional[str]:
        return self.violation[0] if self.violation else None

    @property
    def message(self) -> Optional[str]:
        return self.violation[1] if self.violation else None


def replay(cfg: MCConfig, trace: Sequence[Action], *,
           invariants: Optional[List[Invariant]] = None,
           check_deadlock: bool = True) -> Replay:
    """Deterministically re-execute ``trace`` from the initial state.

    Stops at the first invariant violation. A trace step that is no longer
    enabled (shrinking removed something it depended on) ends the replay
    with no violation. After the last action, the stuck/deadlock
    classification runs exactly as in the explorer, so deadlock
    counterexamples replay too.
    """
    invariants = cfg.default_invariants() if invariants is None else invariants
    world = cfg.make_world()
    v = check_all(world, invariants)
    if v is not None:
        return Replay(v, 0, fingerprint(world), 0)
    for i, action in enumerate(trace):
        action = tuple(action)
        if action not in set(world.enabled_actions()):
            return Replay(None, i, fingerprint(world), i)
        try:
            world.apply(action)
        except AssertionError as e:
            return Replay(("internal-assertion", str(e)), i + 1,
                          fingerprint(world), i + 1)
        v = check_all(world, invariants)
        if v is not None:
            return Replay(v, i + 1, fingerprint(world), i + 1)
    if check_deadlock and not world.progress_possible() and \
            not world.fleet_exhausted() and not world.poll_ready():
        return Replay((DEADLOCK, "no action enabled, run incomplete"),
                      len(trace), fingerprint(world), len(trace))
    return Replay(None, len(trace), fingerprint(world), len(trace))


def shrink(cfg: MCConfig, trace: Sequence[Action], invariant: str, *,
           invariants: Optional[List[Invariant]] = None,
           max_replays: int = 500) -> Tuple[Action, ...]:
    """Greedy ddmin: smallest sub-trace still violating ``invariant``."""
    budget = [max_replays]

    def still_fails(cand: Sequence[Action]) -> bool:
        if budget[0] <= 0:
            return False
        budget[0] -= 1
        return replay(cfg, cand, invariants=invariants).invariant == invariant

    current: List[Action] = [tuple(a) for a in trace]
    # coarse pass: drop halving-sized chunks first (fast on long traces)
    chunk = max(1, len(current) // 2)
    while chunk >= 1:
        i = 0
        while i < len(current):
            cand = current[:i] + current[i + chunk:]
            if still_fails(cand):
                current = cand
            else:
                i += chunk
        if chunk == 1:
            break
        chunk //= 2
    # fine pass: guarantee 1-minimality
    changed = True
    while changed and budget[0] > 0:
        changed = False
        for i in reversed(range(len(current))):
            cand = current[:i] + current[i + 1:]
            if still_fails(cand):
                current = cand
                changed = True
    return tuple(current)


# ---------------------------------------------------------------------------
# runnable repro artifacts
# ---------------------------------------------------------------------------

def repro_payload(cfg: MCConfig, trace: Sequence[Action], invariant: str,
                  message: str, *,
                  fixture: Optional[str] = None) -> Dict[str, Any]:
    """JSON-serializable counterexample. ``fixture`` (a path to a module
    exposing ``configure() -> MCConfig``) carries configs that embed live
    policy objects the JSON form cannot."""
    return {
        "config": cfg.to_json(),
        "fixture": fixture,
        "invariant": invariant,
        "message": message,
        "trace": [list(a) for a in trace],
    }


def load_payload_config(payload: Dict[str, Any]) -> MCConfig:
    if payload.get("fixture"):
        import importlib.util
        import pathlib
        path = pathlib.Path(payload["fixture"])
        spec = importlib.util.spec_from_file_location(path.stem, path)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        return mod.configure()
    return MCConfig.from_json(payload["config"])


def replay_payload(payload: Dict[str, Any], *,
                   invariants: Optional[List[Invariant]] = None) -> Replay:
    return replay(load_payload_config(payload), payload["trace"],
                  invariants=invariants)


_SCRIPT = '''#!/usr/bin/env python
"""Minimized model-checker counterexample (auto-generated).

Replays an exhaustively-found protocol violation through the chaos
harness: PYTHONPATH=src python this_script.py
"""
import json

from repro.core.chaos import replay_mc_trace

PAYLOAD = json.loads(r"""
{payload}
""")

out = replay_mc_trace(PAYLOAD)
assert out.violation is not None, "counterexample no longer reproduces"
assert out.invariant == PAYLOAD["invariant"], (out.invariant, out.message)
print(f"reproduced at step {{out.step}}: [{{out.invariant}}] {{out.message}}")
'''


def repro_script(payload: Dict[str, Any]) -> str:
    return _SCRIPT.format(payload=json.dumps(payload, indent=1))
