"""Canonical state fingerprints for visited-state dedup.

Two reductions happen here, both on top of the protocol's own durable
surface (``QueueServer.snapshot`` / ``DataServer.snapshot`` / session state
views), serialized through the wire codec (``repro.checkpoint.serialize`` —
the same msgpack layer under ``encode_message``) and hashed:

- **Observational abstraction.** Pure accounting that cannot influence any
  future transition is stripped (requeue/wakeup tallies, byte counters), and
  lease deadlines are normalized to *time-to-expiry* (``deadline - now``) so
  states that differ only in absolute virtual time merge. Sound because the
  explorer checks every invariant on the CONCRETE state before dedup prunes
  it — abstraction only affects which successors get re-expanded, and two
  states equal under this fingerprint enable identical action sets with
  identical outcomes.

- **Symmetry reduction.** Volunteers with identical capabilities are
  interchangeable: volunteer ids are relabeled to canonical names
  (``c0, c1, ...``) ordered by each volunteer's full local signature (driver
  + session + fault-capability flags), then the rename is applied across the
  whole state — in-flight lease holders, waiter FIFOs, watch keys, pending
  notification targets, result ``worker`` stamps. Permuted-but-isomorphic
  fleets collapse to one fingerprint.

The canonical tree is hashed over its ``repr`` (deterministic for the plain
lists/strings/numbers the tree is normalized to, and an order of magnitude
faster than re-encoding through msgpack on every generated state); the claim
that the state SURVIVES the wire codec is checked separately and explicitly
by the ``snapshot-durability`` invariant, which round-trips the actual
snapshot through ``encode_message``/``decode_message``.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, Tuple

# accounting fields that cannot change any future transition
_QUEUE_DROP = ("requeued", "wakeups")

# sorted field-name tuples per dataclass type — the walk visits the same few
# types millions of times per exploration, so dataclasses.fields() and
# is_dataclass() are cached out of the hot path
_FIELDS: Dict[type, Tuple[str, ...]] = {}


def _plain(x: Any, rename: Dict[str, str]) -> Any:
    """Normalize to a canonical plain tree: dataclasses tagged by type with
    fields sorted, dicts sorted by key, tuples distinguished from lists,
    volunteer-id strings renamed."""
    t = x.__class__
    if t is str:
        return rename.get(x, x)
    if t is int or t is float or t is bool or x is None:
        return x
    if t is dict:
        return ["d", [[_plain(k, rename), _plain(v, rename)]
                      for k, v in sorted(x.items(), key=lambda kv: repr(kv[0]))]]
    if t is tuple:
        return ["t", [_plain(v, rename) for v in x]]
    if t is list:
        return ["l", [_plain(v, rename) for v in x]]
    if t is set or t is frozenset:
        return ["s", sorted((_plain(v, rename) for v in x), key=repr)]
    names = _FIELDS.get(t)
    if names is None:
        if not dataclasses.is_dataclass(x):
            return x
        names = _FIELDS[t] = tuple(sorted(
            f.name for f in dataclasses.fields(x)))
    return ["dc", t.__name__,
            [[n, _plain(getattr(x, n), rename)] for n in names]]


def _queue_abstract(qsnap: Dict[str, Any], now: float) -> Dict[str, Any]:
    out = {k: v for k, v in qsnap.items() if k not in _QUEUE_DROP}
    out["in_flight"] = [
        [tag, body, consumer, deadline - now]   # requeue count dropped
        for tag, body, consumer, deadline, _requeues in qsnap["in_flight"]]
    return out


def _volunteer_blob(world, vid: str, *, flags: bool) -> Dict[str, Any]:
    d = world.drivers[vid]
    blob = {
        "state": d.state, "blocked": d.blocked, "work": d.work,
        "mailbox": list(d.mailbox), "dropped": d.dropped,
        "session": world.sessions[vid].state_view(),
    }
    if flags:
        blob["can_crash"] = vid in world.cfg.crashable
        blob["can_leave"] = vid in world.cfg.leavable
    return blob


def _state_tree(world, *, symmetric: bool) -> Any:
    vids = list(world.vids)
    blobs = {v: _volunteer_blob(world, v, flags=symmetric) for v in vids}
    if symmetric and world.symmetry_possible():
        # order volunteers by their vid-blind local signature; ties keep the
        # original order (sound either way: the full renamed state is what
        # gets hashed, so a merge only ever unifies isomorphic states)
        blind = {v: "#v" for v in vids}
        sig = {v: repr(_plain(blobs[v], blind)) for v in vids}
        order = sorted(vids, key=lambda v: (sig[v], vids.index(v)))
        rename = {v: f"c{i}" for i, v in enumerate(order)}
    else:
        order, rename = vids, {}
    state = {
        "queues": {name: _queue_abstract(q.snapshot(), world.now)
                   for name, q in sorted(world.qs.queues.items())},
        "models": {k: v for k, v in world.ds.snapshot().items()
                   if k != "counters"},
        "waiters": world.qs.waiter_views(),
        "watches": list(world.endpoint.watch_view()),
        "volunteers": [blobs[v] for v in order],
        "pending": list(world.pending),
        "budget": [world.crashes, world.leaves, world.drops, world.dups,
                   # only meaningful when the config bounds expiries; folded
                   # to 0 otherwise so unbounded worlds keep merging states
                   # that differ only in how often they have already expired
                   world.expiries if world.cfg.max_expiries is not None
                   else 0],
    }
    # world-specific overlay (the gateway micro-world's ring/op-log state):
    # anything that can change a future transition must reach the hash, or
    # dedup could merge states with different failover futures
    extra = getattr(world, "extra_state", None)
    if extra is not None:
        state["extra"] = extra()
    return _plain(state, rename)


def canonical_state(world) -> Any:
    """The renamed, abstracted state tree (exposed for tests/debugging)."""
    return _state_tree(world, symmetric=True)


def _digest(tree: Any) -> bytes:
    return hashlib.blake2b(repr(tree).encode(), digest_size=16).digest()


def fingerprint(world) -> bytes:
    return _digest(_state_tree(world, symmetric=True))


def raw_fingerprint(world) -> bytes:
    """Fingerprint WITHOUT the symmetry rename — the explorer hashes both so
    it can report how many states symmetry actually merged."""
    return _digest(_state_tree(world, symmetric=False))
