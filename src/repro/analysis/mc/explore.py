"""Bounded explicit-state exploration (DFS) over an ``MCWorld``.

The explorer expands every enabled action of every reached state, branching
by capture/restore (each edge therefore also exercises the snapshot/restore
recovery path), dedups via the canonical fingerprint, and checks the
invariant catalog so that every concrete generated state is covered:
history-dependent invariants (whose inputs — the applied/commit logs — the
fingerprint deliberately excludes) run on every transition BEFORE dedup can
prune it; state-based invariants run once per new state, which covers every
deduped duplicate by proxy because the fingerprint includes all of their
inputs; the wire-codec round-trip probe is sampled. Violations are recorded
with the exact action trace that reached them.

Termination classification per path:

- **complete**   — ``ds.latest_version`` reached the policy's update target.
- **stranded**   — no progress action enabled, but some parked volunteer's
  wait condition already holds (a wake was eaten by an injected fault); the
  real engines recover this with timed waits — not a protocol bug.
- **fleet-exhausted** — every volunteer crashed/left/retired; the server
  correctly waits for volunteers that will never come. Not a protocol bug.
- **deadlock**   — none of the above: live parked volunteers, nothing
  enabled, conditions unmet. Reported as a ``deadlock-freedom`` violation.

Budgets (states / depth / wall seconds) bound the search; hitting one is
recorded in the stats (``truncated``) so CI output never silently passes off
a partial search as exhaustive.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.analysis.mc.fingerprint import fingerprint, raw_fingerprint
from repro.analysis.mc.invariants import DEADLOCK, Invariant, check_all
from repro.analysis.mc.world import MCConfig, MCWorld

Action = Tuple[str, ...]

# Invariants over state the fingerprint fully includes (queues, waiters,
# watches, driver/session views): two states that dedup to the same
# fingerprint agree on every input of these predicates, so checking them
# once per NEW state checks them on every generated state by proxy.
_STATE_BASED = frozenset({"ticket-conservation", "no-lost-wake"})
# The wire round-trip probe is a pure self-check of the codec (no protocol
# state feeds it that the others miss) — sampled every Nth new state.
_SAMPLED = frozenset({"snapshot-durability"})
_SAMPLE_EVERY = 8


def _split(invariants: List[Invariant]):
    """(every-transition, per-new-state, sampled). History-dependent
    invariants (the applied/commit logs are deliberately NOT in the
    fingerprint) and any caller-supplied invariant default to the
    every-transition bucket — the sound choice."""
    fast = [i for i in invariants
            if i.name not in _STATE_BASED and i.name not in _SAMPLED]
    slow = [i for i in invariants if i.name in _STATE_BASED]
    sampled = [i for i in invariants if i.name in _SAMPLED]
    return fast, slow, sampled


@dataclass
class Violation:
    invariant: str
    message: str
    trace: Tuple[Action, ...]


@dataclass
class MCStats:
    states: int = 1             # distinct states stored (root included)
    transitions: int = 0        # concrete actions executed
    dedup_hits: int = 0         # successors merged into a visited state
    symmetry_hits: int = 0      # ...of which only by volunteer relabeling
    por_skipped: int = 0        # non-head note fates not branched (POR)
    max_depth: int = 0
    completes: int = 0
    stranded: int = 0
    fleet_exhausted: int = 0
    truncated: bool = False
    elapsed: float = 0.0

    @property
    def states_per_sec(self) -> float:
        return self.states / self.elapsed if self.elapsed > 0 else 0.0

    @property
    def reduction_factor(self) -> float:
        """Merged-or-skipped successors per stored state — how much smaller
        the stored graph is than the raw interleaving tree."""
        saved = self.dedup_hits + self.por_skipped
        return (self.states + saved) / self.states if self.states else 1.0


@dataclass
class MCReport:
    config: MCConfig
    stats: MCStats
    violations: List[Violation] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations


def explore(cfg: MCConfig, *,
            invariants: Optional[List[Invariant]] = None,
            max_states: int = 20000,
            max_depth: int = 60,
            max_seconds: float = 30.0,
            first_violation: bool = True,
            world: Optional[MCWorld] = None) -> MCReport:
    invariants = cfg.default_invariants() if invariants is None else invariants
    fast, slow, sampled = _split(invariants)
    # a caller-provided world lets tests inspect exploration-global state
    # afterwards (e.g. ``sent_types``, the wire-coverage ledger)
    world = cfg.make_world() if world is None else world
    stats = MCStats()
    report = MCReport(cfg, stats)
    t0 = time.perf_counter()

    def record(name: str, msg: str, trace: Tuple[Action, ...]) -> None:
        report.violations.append(Violation(name, msg, trace))

    root_violation = check_all(world, invariants)
    if root_violation is not None:
        record(root_violation[0], root_violation[1], ())
        if first_violation:
            stats.elapsed = time.perf_counter() - t0
            return report

    track_raw = world.symmetry_possible()
    visited = {fingerprint(world)}
    raw_seen = {raw_fingerprint(world)} if track_raw else set()
    # stack of (capture, depth, trace); the capture is the parent state
    stack = [(world.capture(), 0, ())]

    while stack:
        if stats.states >= max_states or \
                time.perf_counter() - t0 > max_seconds:
            stats.truncated = True
            break
        cap, depth, trace = stack.pop()
        world.restore(cap)
        actions = world.enabled_actions()
        if not world.progress_possible(actions):
            if world.fleet_exhausted():
                stats.fleet_exhausted += 1
            elif world.poll_ready():
                stats.stranded += 1
            else:
                record(DEADLOCK,
                       "run incomplete, no action enabled, no parked "
                       "volunteer's wait condition satisfied "
                       f"(volunteers: {[world.drivers[v].state for v in world.vids]})",
                       trace)
                if first_violation:
                    break
            continue
        # POR accounting: only the head pending note's fate is branched;
        # the other queued notifications' fates are deferred, not explored
        stats.por_skipped += max(0, len(world.pending) - 1) * \
            (1 + (world.drops < cfg.max_drops) + (world.dups < cfg.max_dups))
        for action in actions:
            world.restore(cap)
            try:
                world.apply(action)
            except AssertionError as e:
                record("internal-assertion",
                       f"protocol assertion failed on {action}: {e}",
                       trace + (action,))
                if first_violation:
                    stack.clear()
                    break
                continue
            stats.transitions += 1
            v = check_all(world, fast)
            if v is not None:
                record(v[0], v[1], trace + (action,))
                if first_violation:
                    stack.clear()
                    break
                continue
            fp = fingerprint(world)
            if fp in visited:
                stats.dedup_hits += 1
                if track_raw:
                    raw = raw_fingerprint(world)
                    if raw not in raw_seen:
                        stats.symmetry_hits += 1
                        raw_seen.add(raw)
                continue
            visited.add(fp)
            if track_raw:
                raw_seen.add(raw_fingerprint(world))
            stats.states += 1
            stats.max_depth = max(stats.max_depth, depth + 1)
            v = check_all(world, slow)
            if v is None and sampled and stats.states % _SAMPLE_EVERY == 0:
                v = check_all(world, sampled)
            if v is not None:
                record(v[0], v[1], trace + (action,))
                if first_violation:
                    stack.clear()
                    break
                continue
            if world.complete():
                stats.completes += 1
                continue
            if depth + 1 >= max_depth:
                stats.truncated = True
                continue
            stack.append((world.capture(), depth + 1, trace + (action,)))

    stats.elapsed = time.perf_counter() - t0
    return report
