"""Two-gateway micro-world: the replicated control plane as a checkable
model.

``GatewayMCWorld`` extends the base ``MCWorld`` with the cluster overlay the
real multi-gateway deployment (``repro.core.gateway --gid``) adds on top of
one endpoint, using the same building blocks the gateway itself uses:

- a real ``GatewayRing`` routes every request by the gateway's placement
  rule (``colocate_results`` for queue ops, ``MODEL_KEY`` for every
  DataServer-backed op). A request whose slice owner is not the sender's
  home gateway crosses the boundary as a real ``Forward`` envelope through
  the real ``ServerEndpoint.handle`` arm — ``ForwardReply`` comes back, and
  notification fires owed to remotely-homed consumers leave as
  ``ForwardNotify`` exactly as in production (the endpoint's
  remote-consumer table is populated by the forwarded subscribes, not by
  the model).
- every dispatched ``OPLOG_TYPES`` request lands in the owning gateway's
  in-memory op log — the envelope is never logged, the inner op is (the
  gateway's own durability rule) — tagged durable iff the config's
  ``oplog_fsync`` holds. Ops without a routing key (``Bye``,
  ``DropConsumer``, ``ExpireAll``) broadcast to every live gateway's log,
  mirroring the real cluster where each gateway logs its own copy.
- ``("gw_crash", g)`` kills a gateway: its log is truncated to the durable
  watermark, then base + surviving ops replay through a scratch endpoint —
  the exact ``_on_peer_death`` recovery path — and the reconstruction must
  match the pre-crash slice state or **no-lost-forward** fires: work that
  was acknowledged (locally or across a ``Forward``) would be lost at
  failover.
- ``("gw_adopt", g)`` closes the failover window: the deterministic adopter
  (smallest live gid) takes the dead slice and re-bases its own log, with
  **single-owner-per-slice** checking the serve map at every state — no
  slice served twice, none abandoned.

While a slice is orphaned (crash observed, adoption pending) volunteer
protocol moves are held — the model twin of ``GatewayServer._owner_for``
parking requests until a peer adopts — so the only enabled actions are
notification fates and the adoption itself; ``gw_adopt`` counts as progress
for deadlock classification because it is what un-parks the cluster.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from repro.analysis.mc.invariants import Invariant
from repro.analysis.mc.world import MCConfig, MCWorld, _Port
from repro.core.dataserver import DataServer
from repro.core.elastic import MODEL_KEY, GatewayRing
from repro.core.protocol import (FetchModel, Forward, ForwardNotify,
                                 ForwardReply, GcModels, LatestReq,
                                 PublishModel, ServerApplier, ServerEndpoint,
                                 SubmitUpdate, SubscribeQueue, WatchVersion,
                                 decode_message, encode_message)
from repro.core.queue import QueueServer, VirtualClock, colocate_results

#: message types routed to the model owner regardless of any ``queue`` field
#: (``SubmitUpdate`` carries one, but its effect is the model update) — the
#: same precedence ``GatewayServer._route_key`` applies
_MODEL_OPS = (FetchModel, PublishModel, GcModels, WatchVersion, LatestReq,
              SubmitUpdate)


def route_key(msg) -> Optional[str]:
    """The ring key a request routes by, or None for sender-local /
    broadcast messages — mirrors ``GatewayServer._route_key``."""
    if isinstance(msg, _MODEL_OPS):
        return MODEL_KEY
    queue = getattr(msg, "queue", None)
    if queue is not None:
        return colocate_results(queue)
    return None


@dataclass(frozen=True)
class GatewayMCConfig(MCConfig):
    """A base world plus the cluster overlay: gateway count, which gateways
    the explorer may kill, and whether the op log fsyncs before acking
    (``oplog_fsync=False`` is the seeded mutation the fsync-drop fixture
    plants)."""
    n_gateways: int = 2
    gw_crashable: Tuple[int, ...] = ()
    max_gw_crashes: int = 0
    oplog_fsync: bool = True

    def make_world(self) -> "GatewayMCWorld":
        return GatewayMCWorld(self)

    def default_invariants(self) -> List[Invariant]:
        return super().default_invariants() + [
            Invariant("single-owner-per-slice", single_owner_per_slice),
            Invariant("no-lost-forward", no_lost_forward),
        ]

    def to_json(self) -> Dict[str, Any]:
        d = super().to_json()
        d["world"] = "gateway"
        d["gw_crashable"] = list(self.gw_crashable)
        return d

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "GatewayMCConfig":
        kw = dict(d)
        kw.pop("world", None)
        kw["crashable"] = tuple(kw.get("crashable", ()))
        kw["leavable"] = tuple(kw.get("leavable", ()))
        kw["gw_crashable"] = tuple(kw.get("gw_crashable", ()))
        return cls(**kw)


# ---------------------------------------------------------------------------
# invariants
# ---------------------------------------------------------------------------

def single_owner_per_slice(world) -> Optional[str]:
    """Every gateway's base slice is served by exactly one live gateway —
    or by none while it sits in the failover window awaiting adoption.
    A slice served twice (split brain) or a dead slice that is neither
    orphaned nor adopted (lost forever) both violate."""
    served: Dict[int, List[int]] = {}
    live = set(world.ring.live())
    for g in live:
        for s in world.gw_owned.get(g, ()):
            served.setdefault(s, []).append(g)
    for s in world.ring.gids:
        who = sorted(served.get(s, ()))
        if len(who) > 1:
            return (f"slice of gw{s} is served by {len(who)} gateways "
                    f"{who} at once")
        orphaned = s in world.gw_window
        if s in live:
            if not who:
                return f"live gw{s} does not serve its own slice"
        elif orphaned and who:
            return (f"slice of dead gw{s} is served by gw{who[0]} while "
                    f"still awaiting adoption")
        elif not orphaned and not who:
            return (f"slice of dead gw{s} is neither awaiting adoption "
                    f"nor served by any live gateway")
    return None


def no_lost_forward(world) -> Optional[str]:
    """Every op acknowledged to a client — including ops that crossed
    gateways as a ``Forward`` and were acknowledged back over the peer link
    — must survive the owner's crash via op-log replay. ``gw_crash``
    replays the durable log and records any divergence here."""
    if world.gw_lost:
        return world.gw_lost[0]
    return None


# ---------------------------------------------------------------------------
# the world
# ---------------------------------------------------------------------------

class _GatewayPort(_Port):
    """One volunteer's transport into the cluster: requests whose slice
    owner is the volunteer's home gateway dispatch directly; anything else
    crosses as a real ``Forward`` and returns the unwrapped ``ForwardReply``
    — the model-checked twin of ``_PeerLink.forward``."""

    def __init__(self, endpoint: ServerEndpoint, sent: set, world,
                 vid: Optional[str]):
        super().__init__(endpoint, sent)
        self._world = world
        self._vid = vid

    def call(self, msg):
        w = self._world
        self._sent.add(type(msg).__name__)
        key = route_key(msg)
        home = w.effective_home(self._vid)
        if key is None or w.ring.owner_of(key) == home:
            return super().call(msg)
        w.gw_seq += 1
        seq = w.gw_seq
        w.gw_forwarding = home
        try:
            reply = super().call(Forward(seq, str(home), msg))
        finally:
            w.gw_forwarding = None
        assert isinstance(reply, ForwardReply) and reply.seq == seq, reply
        w.gw_forwards += 1
        return reply.inner


def _abstract_queue(qsnap: Dict[str, Any], now: float) -> Dict[str, Any]:
    """One queue's snapshot reduced to what op-log replay must reproduce:
    session-coupled wake state out (signals bank differently when live
    subscribers consumed them), waiter-driven accounting out (wakeups), and
    lease deadlines normalized to time-to-expiry."""
    s = {k: v for k, v in qsnap.items()
         if k not in ("signal", "pub_signal", "requeued", "wakeups")}
    s["in_flight"] = [[tag, body, consumer, deadline - now]
                     for tag, body, consumer, deadline, _r
                     in qsnap["in_flight"]]
    return s


def _durable_ds(dsnap: Dict[str, Any]) -> Dict[str, Any]:
    """DataServer snapshot reduced to its durable surface. The accounting
    counters (reads/bytes_read/...) move on READ-ONLY traffic, which is
    deliberately never op-logged, so replay equality must not see them."""
    return {k: dsnap[k] for k in ("kind", "kv", "models", "latest")}


class GatewayMCWorld(MCWorld):
    """See the module docstring. One truth endpoint plays the union of all
    gateways' durable state; the overlay (ring, serve map, per-gateway op
    logs and bases) models which gateway OWNS each piece and what of it
    would survive that gateway's death."""

    def __init__(self, cfg: GatewayMCConfig):
        self.ring = GatewayRing(range(cfg.n_gateways))
        # base slice gid -> serving gateway, as serve lists per gateway
        self.gw_owned: Dict[int, List[int]] = {g: [g] for g in self.ring.gids}
        # per-gateway op log: (record bytes, durable, arrived-forwarded)
        self.gw_logs: Dict[int, List[Tuple[bytes, bool, bool]]] = {
            g: [] for g in self.ring.gids}
        self.gw_window: List[int] = []   # dead, awaiting adoption
        self.gw_crashes = 0
        self.gw_seq = 0                  # Forward envelope correlation
        self.gw_forwards = 0
        self.gw_forwarding: Optional[int] = None
        self.gw_lost: List[str] = []     # no-lost-forward evidence
        self.gw_base: Dict[int, bytes] = {}
        super().__init__(cfg)
        self._rebind_sessions()
        # the boot base each gateway persisted (post-enqueue, pre-traffic)
        self.gw_base = {g: self._slice_snapshot(g) for g in self.ring.gids}

    # -- wiring -------------------------------------------------------------
    def _fresh_servers(self) -> None:
        super()._fresh_servers()
        self.endpoint.op_sink = self._log_op
        self.port = _GatewayPort(self.endpoint, self.sent_types, self, None)
        self.ports = {vid: _GatewayPort(self.endpoint, self.sent_types,
                                        self, vid)
                      for vid in self.vids}
        # all ports share one endpoint; whichever registered its notify
        # hook last wins, so every port must deliver into the world
        for p in (self.port, *self.ports.values()):
            p.set_deliver(self._on_notify)

    def _rebind_sessions(self) -> None:
        for vid in self.vids:
            self.sessions[vid].port = self.ports[vid]

    def _on_notify(self, consumer: str, msg) -> None:
        if isinstance(msg, ForwardNotify):
            # the slice owner addressed this fire to the consumer's home
            # gateway peer link (``gw:<origin>``); the home gateway unwraps
            # and delivers down the consumer's local connection
            self.sent_types.add("ForwardNotify")
            consumer, msg = msg.consumer, msg.inner
        super()._on_notify(consumer, msg)

    def effective_home(self, vid: Optional[str]) -> int:
        """The live gateway serving ``vid``'s connection: its static home
        (round-robin by volunteer index, like ``--ports`` rotation), chased
        through adoptions once the home died — the volunteer reconnected to
        the adopter. World-level traffic (the expiry sweep) homes on the
        smallest live gid."""
        if vid is None:
            return min(self.ring.live())
        return self.ring.serving(
            self.vids.index(vid) % self.cfg.n_gateways)

    # -- op log -------------------------------------------------------------
    def _log_op(self, m) -> None:
        key = route_key(m)
        if key is None:
            owners = list(self.ring.live())   # Bye/DropConsumer/ExpireAll
        else:
            owners = [self.ring.owner_of(key)]
        rec = encode_message({"t": self.now, "m": m})
        durable = bool(self.cfg.oplog_fsync)
        fwd = self.gw_forwarding is not None
        for g in owners:
            self.gw_logs[g].append((rec, durable, fwd))

    def _served_queues(self, g: int) -> List[str]:
        slices = set(self.gw_owned.get(g, ()))
        return sorted(n for n in self.qs.queues
                      if self.ring.base_owner(colocate_results(n)) in slices)

    def _serves_model(self, g: int) -> bool:
        return self.ring.base_owner(MODEL_KEY) in set(self.gw_owned.get(g, ()))

    def _slice_snapshot(self, g: int) -> bytes:
        """The full-state base gateway ``g`` would persist: its served
        queues as a restorable QueueServer snapshot, plus the DataServer
        when it owns the model slice."""
        qsnap = {"kind": "QueueServer",
                 "default_timeout": self.qs.default_timeout,
                 "queues": [self.qs.queues[n].snapshot()
                            for n in self._served_queues(g)]}
        dsnap = self.ds.snapshot() if self._serves_model(g) else None
        return encode_message({"qs": qsnap, "ds": dsnap})

    def _slice_state(self, g: int) -> Dict[str, Any]:
        """The abstracted equality observable for ``g``'s slice, from the
        live truth."""
        queues = {n: _abstract_queue(self.qs.queues[n].snapshot(), self.now)
                  for n in self._served_queues(g)}
        dspart = _durable_ds(self.ds.snapshot()) if self._serves_model(g) \
            else None
        return {"queues": queues, "ds": dspart}

    def _replay_slice(self, g: int) -> Dict[str, Any]:
        """What a peer would reconstruct from ``g``'s base + durable ops —
        the exact ``_on_peer_death`` path: restore the base into scratch
        servers, then re-dispatch each surviving record through a real
        endpoint under the recorded clock."""
        base = decode_message(self.gw_base[g])
        rq = QueueServer(default_timeout=self.cfg.visibility_timeout)
        rq.restore(base["qs"], waiters_from={})
        rd = DataServer()
        if base["ds"] is not None:
            rd.restore(base["ds"])
        applier = None
        if self.cfg.server_apply:
            applier = ServerApplier(self.policy,
                                    lambda blob, result, v: "blob",
                                    gc_keep=self.cfg.gc_keep)
        t = {"now": 0.0}
        ep = ServerEndpoint(rq, rd, clock=VirtualClock(lambda: t["now"]),
                            applier=applier)
        for rec, durable, _fwd in self.gw_logs[g]:
            if not durable:
                continue                 # never fsynced: died with the box
            r = decode_message(rec)
            t["now"] = r["t"]
            ep.handle(r["m"])
        queues = {n: _abstract_queue(rq.queues[n].snapshot(), self.now)
                  for n in self._served_queues(g)}
        dspart = _durable_ds(rd.snapshot()) if self._serves_model(g) else None
        return {"queues": queues, "ds": dspart}

    # -- actions ------------------------------------------------------------
    def enabled_actions(self) -> List[Tuple[str, ...]]:
        cfg = self.cfg
        if self.gw_window:
            # failover window: the cluster holds client requests (the real
            # gateway parks them in ``_owner_for``) until a peer adopts, so
            # only notification fates and the adoption itself interleave
            acts: List[Tuple[str, ...]] = []
            if self.pending:
                if self.drops < cfg.max_drops:
                    acts.append(("drop",))
                if self.dups < cfg.max_dups:
                    acts.append(("dup",))
                acts.append(("deliver",))
            acts.extend(("gw_adopt", g) for g in self.gw_window)
            return acts
        acts = super().enabled_actions()
        if self.gw_crashes < cfg.max_gw_crashes and \
                len(self.ring.live()) > 1:
            acts.extend(("gw_crash", g) for g in cfg.gw_crashable
                        if g in self.ring.live())
        return acts

    def progress_possible(self, acts=None) -> bool:
        acts = self.enabled_actions() if acts is None else acts
        if any(a[0] == "gw_adopt" for a in acts):
            return True                  # adoption is what un-parks the rest
        return super().progress_possible(acts)

    def symmetry_possible(self) -> bool:
        # volunteers are distinguished by home gateway: relabeling them
        # would merge states whose forwarding (and op-log placement) differs
        return False

    def apply(self, action: Tuple[str, ...]) -> None:
        kind = action[0]
        if kind == "gw_crash":
            g = action[1]
            self.gw_crashes += 1
            pre = self._slice_state(g)
            rec = self._replay_slice(g)
            if rec != pre:
                log = self.gw_logs[g]
                dropped = sum(1 for _, d, _f in log if not d)
                fwd = sum(1 for _, d, f in log if not d and f)
                self.gw_lost.append(
                    f"gateway gw{g} crashed and op-log replay diverged "
                    f"from its live slice state: {dropped} acknowledged "
                    f"op(s) were never made durable ({fwd} of them "
                    f"forwarded from a peer gateway) — that work is lost "
                    f"at failover")
            self.ring.kill(g)
            self.gw_window.append(g)
            return
        if kind == "gw_adopt":
            g = action[1]
            adopter = self.ring.adopt(g)
            self.gw_window.remove(g)
            self.gw_owned[adopter] = sorted(
                set(self.gw_owned[adopter]) | set(self.gw_owned.get(g, ())))
            self.gw_owned[g] = []
            # the adopter re-bases over the merged slice (the real gateway
            # buffers a fresh base record after adoption) and starts a
            # clean log; the dead log is subsumed
            self.gw_base[adopter] = self._slice_snapshot(adopter)
            self.gw_logs[adopter] = []
            self.gw_logs[g] = []
            return
        super().apply(action)
        if kind == "rejoin":
            # the base world rebuilt the session on the shared port; hand
            # it back its own home-gateway port
            self.sessions[action[1]].port = self.ports[action[1]]

    # -- branch points ------------------------------------------------------
    def capture(self) -> Dict[str, Any]:
        cap = super().capture()
        cap["gw"] = {
            "dead": sorted(set(self.ring.gids) - set(self.ring.live())),
            "adopted": self.ring.adoptions(),
            "owned": {g: list(v) for g, v in self.gw_owned.items()},
            "logs": {g: list(v) for g, v in self.gw_logs.items()},
            "base": dict(self.gw_base),
            "window": list(self.gw_window),
            "counters": (self.gw_crashes, self.gw_seq, self.gw_forwards),
            "lost": list(self.gw_lost),
            "remote": dict(self.endpoint._remote_consumers),
        }
        return cap

    def restore(self, cap: Dict[str, Any]) -> None:
        gw = cap["gw"]
        ring = GatewayRing(range(self.cfg.n_gateways))
        for g in gw["dead"]:
            ring.kill(g)
        for dead, adopter in gw["adopted"].items():
            ring.adopt(dead, adopter)
        self.ring = ring
        self.gw_owned = {g: list(v) for g, v in gw["owned"].items()}
        self.gw_logs = {g: list(v) for g, v in gw["logs"].items()}
        self.gw_base = dict(gw["base"])
        self.gw_window = list(gw["window"])
        self.gw_crashes, self.gw_seq, self.gw_forwards = gw["counters"]
        self.gw_lost = list(gw["lost"])
        self.gw_forwarding = None
        super().restore(cap)
        self._rebind_sessions()
        # the remote-consumer table is connection state recorded at capture
        # time; the routed re-registration below rebuilds it for resolvable
        # consumers, but a capture taken inside a failover window has
        # unroutable slices — restore the captured truth verbatim
        self.endpoint._remote_consumers = dict(gw["remote"])

    def _reregister_waits(self, cap: Dict[str, Any]) -> None:
        # route each re-subscription through the consumer's own home
        # gateway: a remotely-homed consumer re-registers via a real
        # ``Forward``, repopulating the owner's remote-consumer table the
        # way reconnecting clients would. Inside a failover window the
        # route is legitimately unresolvable (the slice owner is dead and
        # unadopted) — fall back to direct registration on the truth;
        # ``restore`` reinstates the remote table from the capture after.
        def _subscribe(port, msg):
            try:
                port.call(msg)
            except LookupError:
                self.endpoint.handle(msg)

        for qname, kinds in cap["waiters"].items():
            for kind in ("any", "publish"):
                for c in kinds[kind]:
                    _subscribe(self._consumer_port(c),
                               SubscribeQueue(qname, c, kind))
        for consumer, version in cap["watches"]:
            _subscribe(self._consumer_port(consumer),
                       WatchVersion(version, consumer))

    def _consumer_port(self, consumer: str):
        return self.ports.get(consumer, self.port)

    # -- fingerprint overlay ------------------------------------------------
    def extra_state(self) -> Any:
        """The cluster overlay, hashed into the state fingerprint: two
        states whose truth matches but whose ring membership, serve map, or
        op-log/base content differs have different failover futures and
        must not merge."""
        logs = []
        for g in sorted(self.gw_logs):
            entries = self.gw_logs[g]
            digest = hashlib.blake2b(
                b"".join(r for r, _, _ in entries),
                digest_size=8).hexdigest() if entries else ""
            logs.append([g, len(entries),
                         sum(1 for _, d, _f in entries if d), digest])
        bases = [[g, hashlib.blake2b(self.gw_base[g],
                                     digest_size=8).hexdigest()]
                 for g in sorted(self.gw_base)] if self.gw_base else []
        return ["gw",
                sorted(set(self.ring.gids) - set(self.ring.live())),
                sorted(self.ring.adoptions().items()),
                list(self.gw_window),
                [[g, list(v)] for g, v in sorted(self.gw_owned.items())],
                self.gw_crashes, len(self.gw_lost), logs, bases]
