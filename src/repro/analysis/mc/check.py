"""The ``mc`` analysis pass: bounded model checking as a CI leg.

``run_mc`` explores one bounded world per aggregation policy (or a fixture's
world) under a fixed state/depth/time budget and converts every violation
into a ``repro.analysis.base.Violation`` — with the counterexample shrunk to
a 1-minimal trace and inlined as a replayable JSON payload, so a CI failure
IS the repro.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.analysis.base import Violation
from repro.analysis.mc.explore import MCReport, explore
from repro.analysis.mc.shrink import repro_payload, shrink
from repro.analysis.mc.world import MCConfig

RULES = {
    "MC-CONSERVE": "ticket conservation broke under some interleaving",
    "MC-ADMIT": "an applied update exceeded the policy's staleness bound",
    "MC-COMMIT": "a model version slot was committed twice (or skipped)",
    "MC-WAKE": "a parked volunteer had no live wake registration",
    "MC-SNAPSHOT": "server state did not survive snapshot/restore",
    "MC-DEADLOCK": "reachable state with no enabled action, run incomplete",
    "MC-ASSERT": "a protocol assertion failed during exploration",
    "MC-OWNER": "a ring slice had zero or multiple serving gateways",
    "MC-FORWARD": "acknowledged work lost across a gateway failover",
}

_RULE_BY_INVARIANT = {
    "ticket-conservation": "MC-CONSERVE",
    "admission-soundness": "MC-ADMIT",
    "single-commit-per-slot": "MC-COMMIT",
    "no-lost-wake": "MC-WAKE",
    "snapshot-durability": "MC-SNAPSHOT",
    "deadlock-freedom": "MC-DEADLOCK",
    "internal-assertion": "MC-ASSERT",
    "single-owner-per-slice": "MC-OWNER",
    "no-lost-forward": "MC-FORWARD",
}

DEFAULT_POLICIES: Tuple[str, ...] = ("sync", "staleness:1", "local:2")


def default_config(policy: str) -> MCConfig:
    """The shipped per-policy worlds the CI leg explores: 3 volunteers, the
    full fault alphabet on a small budget — one crash with rejoin, one
    dropped notification, lease expiry live (finite visibility timeout),
    heartbeat/release races enabled."""
    if policy == "sync":
        return MCConfig(policy=policy, n_volunteers=3, n_versions=2, n_mb=2,
                        visibility_timeout=10.0, crashable=("w0",),
                        max_crashes=1, rejoin=True, max_drops=1,
                        allow_release=True, allow_heartbeat=True)
    if policy.startswith("staleness"):
        return MCConfig(policy=policy, n_volunteers=3, n_versions=2, n_mb=2,
                        visibility_timeout=10.0, crashable=("w0",),
                        max_crashes=1, rejoin=True, max_dups=1,
                        allow_release=True, allow_heartbeat=True)
    return MCConfig(policy=policy, n_volunteers=3, n_versions=2, n_mb=2,
                    visibility_timeout=10.0, leavable=("w2",), max_leaves=1,
                    max_drops=1, gc_keep=2, allow_release=True)


def _load_fixture_config(path: str) -> MCConfig:
    import importlib.util
    import pathlib
    p = pathlib.Path(path)
    spec = importlib.util.spec_from_file_location(f"mc_fixture_{p.stem}", p)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod.configure()


def check_config(cfg: MCConfig, *, label: str, max_states: int,
                 max_depth: int, max_seconds: float,
                 fixture: Optional[str] = None,
                 do_shrink: bool = True) -> Tuple[List[Violation], MCReport]:
    # shallow-first: bugs in these bounded worlds sit at small depths, but a
    # DFS given a large depth budget dives down expiry-zombie tails before
    # trying the shallow fault corners — a cheap low-depth pre-pass finds
    # them whatever depth the caller configured, then the full-budget pass
    # provides the coverage the stats report
    shallow = min(16, max_depth)
    report = None
    if shallow < max_depth:
        report = explore(cfg, max_states=max_states, max_depth=shallow,
                         max_seconds=max(1.0, max_seconds / 3))
    if report is None or not report.violations:
        report = explore(cfg, max_states=max_states, max_depth=max_depth,
                         max_seconds=max_seconds)
    violations = []
    for v in report.violations:
        trace = v.trace
        if do_shrink and trace:
            trace = shrink(cfg, trace, v.invariant)
        payload = repro_payload(cfg, trace, v.invariant, v.message,
                                fixture=fixture)
        rule = _RULE_BY_INVARIANT.get(v.invariant, "MC-ASSERT")
        violations.append(Violation(
            rule, label, 0,
            f"[{v.invariant}] {v.message} — minimized {len(trace)}-step "
            f"counterexample (replay with repro.core.chaos --replay): "
            f"{json.dumps(payload, separators=(',', ':'))}"))
    return violations, report


def run_mc(policies: Optional[Sequence[str]] = None, *,
           max_states: int = 4000, max_depth: int = 50,
           max_seconds: float = 12.0,
           fixture: Optional[str] = None,
           stats_out: Optional[Dict[str, Any]] = None) -> List[Violation]:
    """The analysis-driver entry point: explore each policy's default world
    (or the fixture world) within budget; return analysis Violations."""
    out: List[Violation] = []
    if fixture is not None:
        cfg = _load_fixture_config(fixture)
        violations, report = check_config(
            cfg, label=fixture, max_states=max_states, max_depth=max_depth,
            max_seconds=max_seconds, fixture=fixture)
        out.extend(violations)
        if stats_out is not None:
            stats_out[fixture] = report.stats
        return out
    for policy in (policies or DEFAULT_POLICIES):
        cfg = default_config(policy)
        violations, report = check_config(
            cfg, label=f"mc({policy})", max_states=max_states,
            max_depth=max_depth, max_seconds=max_seconds)
        out.extend(violations)
        if stats_out is not None:
            stats_out[policy] = report.stats
    return out
