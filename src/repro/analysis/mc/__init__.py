"""repro.analysis.mc — explicit-state model checking of the volunteer
protocol.

Drives N real ``VolunteerSession`` objects against a real ``ServerEndpoint``
(no mocks — the shipped ``protocol.py`` is the model) through every enabled
interleaving of protocol moves, notification fates (deliver/drop/duplicate),
lease expiry, heartbeat/release races, crash/rejoin, and clean departure —
checking a declarative invariant catalog at every reached state, with
canonical-fingerprint dedup, symmetry + partial-order reduction, and
counterexample shrinking to runnable repro scripts.

Entry points: ``explore(MCConfig(...))`` for one world, ``run_mc`` for the
CI pass (``python -m repro.analysis --strict --mc``), ``replay``/``shrink``
for counterexample work. See docs/analysis.md ("Model checking").
"""
from repro.analysis.mc.check import (DEFAULT_POLICIES, RULES, check_config,
                                     default_config, run_mc)
from repro.analysis.mc.explore import MCReport, MCStats, explore
from repro.analysis.mc.fingerprint import canonical_state, fingerprint
from repro.analysis.mc.gateway_world import GatewayMCConfig, GatewayMCWorld
from repro.analysis.mc.invariants import (DEADLOCK, DEFAULT_INVARIANTS,
                                          Invariant, check_all)
from repro.analysis.mc.shrink import (Replay, load_payload_config, replay,
                                      replay_payload, repro_payload,
                                      repro_script, shrink)
from repro.analysis.mc.world import MCConfig, MCWorld

# Every REQUEST/NOTIFICATION wire type -> the model-checker action(s) that
# exercise it. ``analysis.schema.check_mc_coverage`` (rule SCHEMA-MC) fails
# --strict when a @wire type is missing here, so the model cannot silently
# under-model a growing protocol; the coverage test in tests/test_mc.py
# proves each entry is actually sent during exploration, so an entry cannot
# be an empty promise either.
COVERED_MESSAGES = {
    # requests
    "Hello": "world construction / rejoin (connection registration)",
    "LeaseReq": "lease action; reduce-barrier drain inside advance",
    "Ack": "finish action (map/reduce/commit acks); stale-duplicate ack",
    "Nack": "release action; incomplete-barrier putback; stale-update nack",
    "ExtendLease": "heartbeat action",
    "PublishResult": "finish action (sync map publishes its gradient)",
    "FetchModel": "advance action (map/barrierless model fetch)",
    "PublishModel": "finish action (reduce / commit_update publish)",
    "GcModels": "finish action with gc_keep configured",
    "WatchVersion": "advance -> Blocked(version) park; restore re-watch",
    "SubscribeQueue": "advance -> Blocked(queue) park; idle park; restore",
    "KickQueue": "rejoin action (abort passes on a consumed wake)",
    "DropConsumer": "rejoin action (requeue the dead incarnation's leases)",
    "DepthReq": "advance action (reduce barrier probe)",
    "DrainedReq": "lease action (NoTask -> retirement check)",
    "LatestReq": "advance/finish admission reads",
    "SubmitUpdate": "finish action under server_apply",
    "Bye": "leave action (clean departure)",
    "ExpireAll": "expire action (the lease sweep is a logged wire op)",
    "Forward": "gateway world: remotely-homed op routed to its slice owner",
    "ForwardNotify": "gateway world: wake crossing back to its origin",
    # notifications
    "Wake": "deliver/drop/dup fates + wake action",
    "VersionReady": "deliver/drop/dup fates + wake action",
}

__all__ = [
    "COVERED_MESSAGES", "DEADLOCK", "DEFAULT_INVARIANTS", "DEFAULT_POLICIES",
    "GatewayMCConfig", "GatewayMCWorld",
    "Invariant", "MCConfig", "MCReport", "MCStats", "MCWorld", "RULES",
    "Replay", "canonical_state", "check_all", "check_config",
    "default_config", "explore", "fingerprint", "load_payload_config",
    "replay", "replay_payload", "repro_payload", "repro_script", "run_mc",
    "shrink",
]
