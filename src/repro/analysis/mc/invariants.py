"""The declarative invariant catalog the explorer checks at every state.

An ``Invariant`` is a name plus a predicate over the concrete ``MCWorld``.
The predicate returns ``None`` when the property holds, or a human-readable
violation message when it does not (returning ``False`` is also accepted and
converted to a generic message). Every *generated* state is covered: the
explorer schedules history-dependent invariants on every transition (before
fingerprint dedup can prune it) and state-based ones once per distinct
fingerprint — see ``explore._split`` — so the observational abstractions in
``fingerprint.py`` can never hide a violation.

Each invariant names the protocol guarantee it verifies; ``docs/protocol.md``
cross-references these from the state-machine sections they formalize.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Union

from repro.core.queue import QueueServer
from repro.core.tasks import INITIAL_QUEUE

Verdict = Union[None, bool, str]


@dataclass(frozen=True)
class Invariant:
    name: str
    predicate: Callable[["MCWorld"], Verdict]  # noqa: F821 - runtime duck type

    def check(self, world) -> Optional[str]:
        out = self.predicate(world)
        if out is None or out is True:
            return None
        return out if isinstance(out, str) else f"{self.name} violated"


def ticket_conservation(world) -> Verdict:
    """pending + in-flight + done == scheduled: the at-least-once queue never
    loses or invents a ticket, across expiry, nack, crash, and restore."""
    for name in sorted(world.qs.queues):
        q = world.qs.queues[name]
        try:
            q.check_invariants()
        except AssertionError as e:
            return f"queue structural invariant broke: {e}"
    iq = world.qs.queues[INITIAL_QUEUE]
    if iq.published != world.n_scheduled:
        return (f"task queue published {iq.published} != "
                f"{world.n_scheduled} scheduled")
    outstanding = iq.acked + iq.depth + iq.in_flight
    if outstanding != world.n_scheduled:
        return (f"ticket conservation broke: acked {iq.acked} + pending "
                f"{iq.depth} + in-flight {iq.in_flight} != "
                f"{world.n_scheduled} scheduled")
    return None


def admission_soundness(world) -> Verdict:
    """Every applied barrierless update satisfies the policy's declared
    bound: ``applied_at - computed_at <= s`` (the BoundedStaleness contract).
    Policies without a finite declared bound (LocalSteps) are exempt; barrier
    policies never take this path (a sync gradient is applied at exactly the
    version it was computed on, enforced by the reduce barrier itself)."""
    if world.policy.barrier:
        return None
    bound = getattr(world.policy, "staleness", None)
    if bound is None:
        return None
    for computed_at, applied_at in world.applied:
        if applied_at - computed_at > bound:
            return (f"update computed at v{computed_at} applied at "
                    f"v{applied_at}: staleness {applied_at - computed_at} "
                    f"exceeds the declared bound {bound}")
    return None


def single_commit_per_slot(world) -> Verdict:
    """Each model version slot is committed exactly once, gaplessly: the
    version sequence 1..latest with no duplicates (v0 is the initiator's).
    Duplicate reduce publishes must be absorbed by the DataServer, not
    double-committed."""
    versions = [v for v, _ in world.commit_meta]
    if len(versions) != len(set(versions)):
        dup = sorted(v for v in set(versions) if versions.count(v) > 1)
        return f"model version(s) {dup} committed more than once"
    expect = set(range(1, world.ds.latest_version + 1))
    if set(versions) != expect:
        return (f"commit log {sorted(versions)} does not match committed "
                f"versions 1..{world.ds.latest_version}")
    return None


def no_lost_wake(world) -> Verdict:
    """A parked volunteer always has SOMETHING that will wake it: an
    undelivered/delivered notification in flight, a live queue-waiter
    registration of the right kind, or a live version watch. Volunteers that
    had a notification deliberately dropped on them (injected fault budget)
    are exempt — recovering those is the lease-expiry/watchdog path, not the
    wake chain."""
    for vid in world.vids:
        d = world.drivers[vid]
        if d.state not in ("parked", "parked_idle"):
            continue
        if d.dropped:
            continue
        if d.mailbox or any(c == vid for c, _ in world.pending):
            continue
        if d.state == "parked_idle":
            q = world.qs.queues.get(INITIAL_QUEUE)
            if q is None or vid not in q.waiter_view()["any"]:
                return (f"{vid} parked idle with no live task-queue waiter, "
                        f"no pending wake")
            continue
        b = d.blocked
        if b is None:
            return f"{vid} parked with no recorded wait condition"
        if b.version is not None:
            if (vid, b.version) not in world.endpoint.watch_view():
                return (f"{vid} parked on version v{b.version} with no live "
                        f"watch, no pending wake")
        else:
            q = world.qs.queues.get(b.queue)
            names = q.waiter_view().get(b.kind, ()) if q is not None else ()
            if vid not in names:
                return (f"{vid} parked on {b.queue}/{b.kind} with no live "
                        f"waiter, no pending wake")
    return None


def snapshot_durability(world) -> Verdict:
    """The full server state survives snapshot -> wire bytes -> restore with
    an identical second snapshot — the gateway's crash-recovery contract,
    probed at this exact state."""
    from repro.core.protocol import decode_message, encode_message
    snap = world.qs.snapshot()
    rebuilt = QueueServer(default_timeout=world.qs.default_timeout)
    rebuilt.restore(decode_message(encode_message(snap)), waiters_from={})
    snap2 = rebuilt.snapshot()
    if snap2 != snap:
        return "QueueServer snapshot did not survive a wire round-trip"
    return None


DEFAULT_INVARIANTS: List[Invariant] = [
    Invariant("ticket-conservation", ticket_conservation),
    Invariant("admission-soundness", admission_soundness),
    Invariant("single-commit-per-slot", single_commit_per_slot),
    Invariant("no-lost-wake", no_lost_wake),
    Invariant("snapshot-durability", snapshot_durability),
]

# deadlock-freedom is checked by the explorer itself (it needs the enabled
# action set), but it reports under this name so the catalog is uniform
DEADLOCK = "deadlock-freedom"


def check_all(world, invariants: List[Invariant]) -> Optional[tuple]:
    """First violated invariant as ``(name, message)``, else None."""
    for inv in invariants:
        msg = inv.check(world)
        if msg is not None:
            return (inv.name, msg)
    return None
