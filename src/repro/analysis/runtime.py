"""Lock-order race detector (pass "locks") — the runtime half.

The static graph (``repro.analysis.locks``) proves the DECLARED acquisition
order is acyclic; this module checks the claims static analysis cannot see:
the orders threads actually take, blocking calls made while a dispatch lock
is held, and the parked-holder invariant distilled from PR 5's step-aside
deadlock.

``repro.core.gateway`` creates its locks through a ``_make_lock`` seam.
With ``ANALYSIS_INSTRUMENT=1`` in the environment — which every spawned
server/volunteer subprocess inherits, so the whole ``gateway --smoke``
topology is covered — the seam returns ``MonitoredLock``s from the
process-wide ``Analysis`` singleton, and ``gateway.main()`` fails the
process if any violation was recorded. CI runs one smoke leg this way.

Named invariants:

- **LOCK-ORDER** — two locks observed in both orders across the run, or an
  observed order inverting the static graph: a deadlock waiting for the
  right thread interleaving.
- **LOCK-SELF** — re-acquiring a held non-reentrant lock. Raised
  immediately (certain deadlock) instead of hanging the process.
- **LOCK-BLOCK** — a blocking call (socket recv, snapshot fsync) while
  holding a *guard* lock (the gateway's dispatch lock): one slow client or
  disk stalls every other connection. Blocking sites self-report via
  ``note_blocking``.
- **PARKED-HOLDER** — a volunteer entered an UNTIMED notification wait
  while holding a leased ticket. If that ticket is the last progressable
  task, nothing can ever wake it — PR 5's step-aside deadlock. Timed waits
  + heartbeats (and the release-to-the-back step-aside) are the fix this
  regression guard protects.
"""
from __future__ import annotations

import sys
import threading
from typing import List, Optional, Set, Tuple

from repro.analysis.base import Violation


class MonitoredLock:
    """``threading.Lock`` wrapper that records acquisition order through its
    owning ``Analysis``. Covers the Lock surface the core uses (``with``,
    ``acquire``/``release``/``locked``)."""

    def __init__(self, mon: "Analysis", name: str, guard: bool = False):
        self._mon = mon
        self.name = name
        self.guard = guard
        self._lock = threading.Lock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        held = self._mon._held()
        if any(h is self for h in held):
            self._mon._record("LOCK-SELF",
                              f"re-acquiring held lock {self.name} — a "
                              f"non-reentrant lock self-deadlocks here")
            raise RuntimeError(f"analysis: re-acquire of held {self.name}")
        ok = self._lock.acquire(blocking, timeout)
        if ok:
            for h in held:
                self._mon._edge(h.name, self.name)
            held.append(self)
        return ok

    def release(self) -> None:
        held = self._mon._held()
        for i in range(len(held) - 1, -1, -1):
            if held[i] is self:
                del held[i]
                break
        self._lock.release()

    def locked(self) -> bool:
        return self._lock.locked()

    def __enter__(self) -> "MonitoredLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> bool:
        self.release()
        return False


class Analysis:
    """Process-wide runtime monitor. ``Analysis.instrument()`` is the
    singleton entry the gateway's ``_make_lock`` seam uses (it also loads
    the static lock graph to check observed orders against); tests
    construct instances directly with whatever static edges they want."""

    _singleton: Optional["Analysis"] = None

    def __init__(self,
                 static_edges: Optional[Set[Tuple[str, str]]] = None):
        self._tls = threading.local()
        self._mu = threading.Lock()          # guards edges + violations
        self._edges: Set[Tuple[str, str]] = set()
        self._static = set(static_edges or ())
        self.violations: List[Violation] = []
        self.locks_made = 0
        self.parks = 0
        self.blocking_notes = 0

    @classmethod
    def instrument(cls) -> "Analysis":
        if cls._singleton is None:
            from repro.analysis import locks as _locks
            try:
                static = _locks.static_edges(_locks.default_paths())
            except Exception as e:           # pragma: no cover - defensive
                # instrumentation must never take the server down; without
                # the static graph, runtime-vs-runtime inversions still fire
                print(f"# analysis-instrument: static graph unavailable "
                      f"({e!r})", file=sys.stderr)
                static = set()
            cls._singleton = cls(static)
        return cls._singleton

    @classmethod
    def reset(cls) -> None:
        """Drop the singleton (tests only)."""
        cls._singleton = None

    # -- lock bookkeeping ---------------------------------------------------
    def make_lock(self, name: str, guard: bool = False) -> MonitoredLock:
        self.locks_made += 1
        return MonitoredLock(self, name, guard)

    def _held(self) -> List[MonitoredLock]:
        held = getattr(self._tls, "held", None)
        if held is None:
            held = self._tls.held = []
        return held

    def _record(self, rule: str, message: str) -> None:
        with self._mu:
            self.violations.append(Violation(rule, "<runtime>", 0, message))

    def _edge(self, a: str, b: str) -> None:
        if a == b:
            return
        with self._mu:
            first = (a, b) not in self._edges
            self._edges.add((a, b))
            runtime_inv = first and (b, a) in self._edges
            static_inv = first and (b, a) in self._static
        if runtime_inv or static_inv:
            source = "the static graph" if static_inv and not runtime_inv \
                else "an earlier observed order"
            self._record("LOCK-ORDER",
                         f"acquired {b} while holding {a}, but {source} "
                         f"takes {a} after {b} — deadlock-prone inversion")

    # -- invariant hooks (called from instrumented core sites) ---------------
    def note_blocking(self, kind: str) -> None:
        """A blocking call (socket recv, snapshot fsync, lease wait) is about
        to run on this thread; violation if a guard lock is held."""
        self.blocking_notes += 1
        guards = [h.name for h in self._held() if h.guard]
        if guards:
            self._record("LOCK-BLOCK",
                         f"blocking call ({kind}) while holding "
                         f"{', '.join(guards)} — stalls every other "
                         f"connection behind the dispatch lock")

    def note_park(self, who: str, *, holding: bool, timed: bool) -> None:
        """A volunteer is about to block on a notification wait. Violation
        if it holds a leased ticket and the wait has no timeout: the
        PARKED-HOLDER (PR 5 step-aside deadlock) regression guard."""
        self.parks += 1
        if holding and not timed:
            self._record("PARKED-HOLDER",
                         f"{who}: untimed notification wait while holding a "
                         f"leased ticket — if that ticket is the last "
                         f"progressable task nothing can wake this "
                         f"volunteer (PR 5 step-aside deadlock)")

    # -- reporting ----------------------------------------------------------
    def report(self, stream=None) -> int:
        """Print findings; 1 if any violation was recorded, else 0."""
        stream = sys.stderr if stream is None else stream
        with self._mu:
            vs = list(self.violations)
            n_edges = len(self._edges)
        if vs:
            for v in vs:
                print(v, file=stream)
            print(f"# analysis-instrument: {len(vs)} violation(s)",
                  file=stream, flush=True)
            return 1
        print(f"# analysis-instrument: clean — {self.locks_made} lock(s), "
              f"{n_edges} order edge(s), {self.blocking_notes} blocking "
              f"site(s) checked, {self.parks} park(s) checked", flush=True)
        return 0
