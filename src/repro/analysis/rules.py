"""Layering & invariant linter (pass "rules"): the REPRO rule catalog.

AST-based, importing nothing from the code under test. The conventions PRs
1-5 established are load-bearing — all wall-clock time flows through
``LeaseClock``, engines reach ``QueueServer``/``DataServer`` only through
``VolunteerSession``/``ServerEndpoint``, session state is mutated only by
``VolunteerSession`` itself, and protocol dispatch never swallows errors —
but until this pass nothing enforced them. Each rule has an id; a finding
can be excused in place with ``# analysis: ignore[RULE-ID]`` (see
``repro.analysis.base``; strict mode fails on stale ignores). Rationale,
examples, and the full catalog live in docs/analysis.md.

The driver's default path set is ``src/repro/core/*.py`` — the protocol
kernel where these rules are invariants, not style. Seeded fixtures under
``tests/fixtures/analysis/`` prove every rule fires.
"""
from __future__ import annotations

import ast
import pathlib
from typing import Iterable, List, Tuple

from repro.analysis.base import Violation, apply_ignores, parse_ignores

# -- REPRO-TIME -------------------------------------------------------------
# Wall-clock reads outside queue.py's clock classes. Lease deadlines are
# meaningful only relative to ONE time authority; a stray time.monotonic()
# compares against the wrong clock in virtual-time engines and splits the
# authority in real-time ones. time.sleep is deliberately allowed: sleeping
# is pacing, not reading the lease clock.
WALL_CLOCK_FNS = {"time", "monotonic", "perf_counter",
                  "time_ns", "monotonic_ns", "perf_counter_ns"}
CLOCK_HOME_STEM = "queue"            # LeaseClock implementations live here
CLOCK_CLASS_SUFFIX = "Clock"

# -- REPRO-LAYER ------------------------------------------------------------
# Engine modules calling the consumer/producer protocol directly on a
# QueueServer/DataServer. Engines own time, compute, and waiting; protocol
# moves must go through VolunteerSession (client half) or ServerEndpoint
# (server half) so every rule lives in exactly one place. Server-AUTHORITY
# ops (expire_all, next_deadline, snapshot/restore, shard membership) and
# pure reads (depth, drained, latest_version, counters) are the owner's
# business and stay direct.
ENGINE_STEMS = {"coordinator", "simulator", "gateway", "chaos", "browser",
                "traces"}
SERVER_ATTRS = {"qs", "ds", "queue_server", "data_server"}
CONSUMER_OPS = {"lease", "ack", "nack", "extend", "publish", "subscribe",
                "unsubscribe", "kick", "drop_consumer", "declare",
                "publish_model", "watch_version", "put", "delete",
                "gc_models"}

# -- REPRO-SESSION ----------------------------------------------------------
# VolunteerSession state mutated from outside its own methods. The session
# is the protocol state machine; an engine poking e.g. ``sess.task = None``
# desynchronizes it from the server's lease table (the ticket stays leased
# with nobody driving it). Detected as any write/delete of these attributes
# on a receiver other than ``self``.
SESSION_ATTRS = {"task", "tag", "lease_latest", "_rtags", "_handed",
                 "_base", "_apply_version"}

# -- REPRO-EXCEPT -----------------------------------------------------------
# Bare ``except:`` anywhere, and ``except Exception/BaseException`` whose
# body is only ``pass``. In protocol dispatch a swallowed error turns a bug
# into a silent hang (a reply never sent, a lease never requeued); handlers
# must name the exception and do something with it.
SWALLOW_NAMES = {"Exception", "BaseException"}


#: rule id -> one-line summary (docs/analysis.md carries the full catalog)
RULES = {
    "REPRO-TIME": "wall-clock read outside queue.py's LeaseClock classes",
    "REPRO-LAYER": "engine calls a QueueServer/DataServer consumer op "
                   "directly instead of via VolunteerSession/ServerEndpoint",
    "REPRO-SESSION": "VolunteerSession state mutated outside its methods",
    "REPRO-EXCEPT": "bare except / silently swallowed exception",
}


def _iter_with_classes(node: ast.AST, stack: Tuple[str, ...] = ()):
    """Yield ``(child, enclosing_class_names)`` for every descendant."""
    for child in ast.iter_child_nodes(node):
        cstack = stack + (child.name,) if isinstance(child, ast.ClassDef) \
            else stack
        yield child, cstack
        yield from _iter_with_classes(child, cstack)


def _receiver_name(expr: ast.AST):
    """Last name segment of a call receiver: ``self.qs`` -> "qs"."""
    if isinstance(expr, ast.Attribute):
        return expr.attr
    if isinstance(expr, ast.Name):
        return expr.id
    return None


def _check_time(tree: ast.AST, path: str, stem: str) -> List[Violation]:
    mod_aliases, fn_aliases = set(), {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "time":
                    mod_aliases.add(a.asname or a.name)
        elif isinstance(node, ast.ImportFrom) and node.module == "time":
            for a in node.names:
                if a.name in WALL_CLOCK_FNS:
                    fn_aliases[a.asname or a.name] = a.name
    out = []
    for node, classes in _iter_with_classes(tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        called = None
        if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name) \
                and f.value.id in mod_aliases and f.attr in WALL_CLOCK_FNS:
            called = f.attr
        elif isinstance(f, ast.Name) and f.id in fn_aliases:
            called = fn_aliases[f.id]
        if called is None:
            continue
        if stem == CLOCK_HOME_STEM and \
                any(c.endswith(CLOCK_CLASS_SUFFIX) for c in classes):
            continue                 # a LeaseClock implementation itself
        out.append(Violation(
            "REPRO-TIME", path, node.lineno,
            f"time.{called}() outside queue.py's clock classes — all wall "
            f"time flows through a LeaseClock (WallClock/VirtualClock)"))
    return out


def _check_layer(tree: ast.AST, path: str, stem: str) -> List[Violation]:
    if stem not in ENGINE_STEMS:
        return []
    out = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)):
            continue
        recv = _receiver_name(node.func.value)
        if node.func.attr in CONSUMER_OPS and recv in SERVER_ATTRS:
            out.append(Violation(
                "REPRO-LAYER", path, node.lineno,
                f"engine calls {recv}.{node.func.attr}() directly — route "
                f"consumer-protocol ops through VolunteerSession or "
                f"ServerEndpoint"))
    return out


def _check_session(tree: ast.AST, path: str, stem: str) -> List[Violation]:
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AugAssign):
            targets = [node.target]
        elif isinstance(node, ast.Delete):
            targets = node.targets
        else:
            continue
        for t in targets:
            for sub in ast.walk(t):
                if not (isinstance(sub, ast.Attribute)
                        and sub.attr in SESSION_ATTRS):
                    continue
                base = sub.value
                if isinstance(base, ast.Name) and base.id == "self":
                    continue         # the session's own methods
                out.append(Violation(
                    "REPRO-SESSION", path, sub.lineno,
                    f"session state .{sub.attr} mutated from outside "
                    f"VolunteerSession — the session owns its protocol "
                    f"state; drive it through its methods"))
    return out


def _check_except(tree: ast.AST, path: str, stem: str) -> List[Violation]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if node.type is None:
            out.append(Violation(
                "REPRO-EXCEPT", path, node.lineno,
                "bare `except:` catches KeyboardInterrupt/SystemExit and "
                "hides protocol bugs — name the exception"))
            continue
        t = node.type
        elts = t.elts if isinstance(t, ast.Tuple) else [t]
        names = {e.id for e in elts if isinstance(e, ast.Name)}
        if names & SWALLOW_NAMES and len(node.body) == 1 \
                and isinstance(node.body[0], ast.Pass):
            out.append(Violation(
                "REPRO-EXCEPT", path, node.lineno,
                f"except {'/'.join(sorted(names & SWALLOW_NAMES))}: pass "
                f"swallows every error silently — handle it, log it, or "
                f"narrow the type"))
    return out


_CHECKS = (_check_time, _check_layer, _check_session, _check_except)


def check_file(path) -> Tuple[List[Violation], List[Violation]]:
    """Run every rule on one file. Returns ``(violations, stale_ignores)``
    after applying the ignore escape hatch."""
    p = pathlib.Path(path)
    source = p.read_text()
    tree = ast.parse(source, filename=str(p))
    raw: List[Violation] = []
    for check in _CHECKS:
        raw.extend(check(tree, str(p), p.stem))
    raw.sort(key=lambda v: (v.line, v.rule))
    return apply_ignores(raw, parse_ignores(source), str(p))


def check_paths(paths: Iterable) -> Tuple[List[Violation], List[Violation]]:
    violations: List[Violation] = []
    stale: List[Violation] = []
    for path in paths:
        vs, st = check_file(path)
        violations.extend(vs)
        stale.extend(st)
    return violations, stale
