"""repro.analysis — the repo-native static-analysis layer.

Three passes, each runnable standalone or together via
``python -m repro.analysis`` (CI runs ``--strict``, which also fails on
stale ignore comments):

- ``rules``  — layering & invariant linter over ``src/repro/core/``
  (REPRO-TIME / REPRO-LAYER / REPRO-SESSION / REPRO-EXCEPT).
- ``locks``  — lock-order race detector: static acquisition-graph cycle
  check, plus a runtime half (``repro.analysis.runtime``) active during
  ``ANALYSIS_INSTRUMENT=1 gateway --smoke`` (LOCK-ORDER / LOCK-SELF /
  LOCK-BLOCK / PARKED-HOLDER).
- ``schema`` — wire-schema exhaustiveness checker (SCHEMA-*).

Rule catalog and how-to: docs/analysis.md. Findings can be excused in
place with ``# analysis: ignore[RULE-ID]``.
"""
from repro.analysis.base import Violation
from repro.analysis.runtime import Analysis, MonitoredLock

__all__ = ["Violation", "Analysis", "MonitoredLock"]
