"""repro.analysis — the repo-native static-analysis layer.

Four passes, each runnable standalone or together via
``python -m repro.analysis`` (CI runs ``--strict``, which also fails on
stale ignore comments):

- ``rules``  — layering & invariant linter over ``src/repro/core/``
  (REPRO-TIME / REPRO-LAYER / REPRO-SESSION / REPRO-EXCEPT).
- ``locks``  — lock-order race detector: static acquisition-graph cycle
  check with transitive same-module call resolution, plus a runtime half
  (``repro.analysis.runtime``) active during
  ``ANALYSIS_INSTRUMENT=1 gateway --smoke`` (LOCK-ORDER / LOCK-SELF /
  LOCK-BLOCK / PARKED-HOLDER).
- ``schema`` — wire-schema exhaustiveness checker (SCHEMA-*), including
  the SCHEMA-MC cross-check that every wire type is modeled by the model
  checker.
- ``mc``     — bounded explicit-state model checker (``repro.analysis.mc``,
  opt-in via ``--mc``): exhaustive exploration of real sessions against a
  real endpoint under message reordering, drops/dups, lease expiry,
  crash/rejoin, and heartbeat/release races, checking the invariant
  catalog and shrinking any counterexample to a replayable trace (MC-*).

Rule catalog and how-to: docs/analysis.md. Findings can be excused in
place with ``# analysis: ignore[RULE-ID]``.
"""
from repro.analysis.base import Violation
from repro.analysis.runtime import Analysis, MonitoredLock

__all__ = ["Violation", "Analysis", "MonitoredLock"]
