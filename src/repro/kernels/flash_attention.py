"""Flash attention (GQA, causal/sliding-window) as a Pallas TPU kernel.

Adaptation of the paper-era WebGL "shader" idea to the TPU memory hierarchy:
instead of materializing [Sq, Skv] scores in HBM, each grid cell owns one
(batch, kv-head, q-tile) and streams kv tiles HBM->VMEM, carrying the online
softmax (m, l, acc) in VMEM scratch. MXU does the two matmuls per tile;
the rescaling is VPU work. Tiles are 128-aligned for the MXU.

Grid: (B, Kv, Sq/blk_q); the kv loop is a fori_loop inside the kernel with
a causal early-exit bound, so the quadratic term only pays for the lower
triangle. GQA is handled by folding the G = H/Kv group dim into the q tile
rows ([blk_q * G, hd] q block per kv head).

Forward-only: training uses the jnp flash path (layers.flash_attention,
custom_vjp); this kernel is the serving/prefill fast path. Validated in
interpret mode against ref.flash_attention.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, *, blk_q: int, blk_k: int,
               seq_kv: int, causal: bool, window: int, scale: float):
    """Block shapes (leading B/Kv dims are size-1 grid blocks):
      q [1, blk_q, G, hd] -> folded to [blk_q*G, hd]
      k [1, Skv, hd]   v [1, Skv, hd]   (full kv row of this head in VMEM;
                                         fori_loop slices blk_k tiles)
      o [1, blk_q, G, hd]
    """
    iq = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)                # [blk_q, G, hd]
    bq, G, hd = q.shape
    q2 = q.reshape(bq * G, hd) * scale

    q_start = iq * blk_q
    # causal upper bound on kv tiles this q tile can see
    if causal:
        hi = jnp.minimum(seq_kv, q_start + blk_q)
    else:
        hi = seq_kv
    n_tiles = pl.cdiv(hi, blk_k)

    def body(t, carry):
        m, l, acc = carry
        k_start = t * blk_k
        k = jax.lax.dynamic_slice(k_ref[0, 0], (k_start, 0),
                                  (blk_k, hd)).astype(jnp.float32)
        v = jax.lax.dynamic_slice(v_ref[0, 0], (k_start, 0),
                                  (blk_k, hd)).astype(jnp.float32)
        s = jax.lax.dot_general(q2, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        # mask: causal + sliding window + kv-padding
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, G), 0)
        qpos = qpos.reshape(bq * G, 1)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, blk_k), 1)
        ok = kpos < seq_kv
        if causal:
            ok &= kpos <= qpos
        if window > 0:
            ok &= kpos > qpos - window
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq * G,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq * G,), jnp.float32)
    a0 = jnp.zeros((bq * G, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_tiles, body, (m0, l0, a0))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[:, None]).reshape(bq, G, hd)
    o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "blk_q", "blk_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    blk_q: int = 128, blk_k: int = 128,
                    interpret: bool = True):
    """q [B,Sq,H,hd]; k/v [B,Skv,Kv,hd]; GQA G=H/Kv. Self-attention with
    q aligned to the end of kv (training/prefill: Sq == Skv)."""
    B, Sq, H, hd = q.shape
    Skv, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    assert Sq == Skv, "kernel assumes aligned self-attention"
    scale = 1.0 / math.sqrt(hd)

    bq = min(blk_q, Sq)
    pad_q = pl.cdiv(Sq, bq) * bq - Sq
    bk = min(blk_k, Skv)
    pad_k = pl.cdiv(Skv, bk) * bk - Skv

    # layout: q [B, Kv, Sq, G, hd]; kv [B, Kv, Skv, hd]
    qr = jnp.moveaxis(q.reshape(B, Sq, Kv, G, hd), 1, 2)
    kr = jnp.moveaxis(k, 2, 1)
    vr = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        kr = jnp.pad(kr, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vr = jnp.pad(vr, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sq_p, Skv_p = Sq + pad_q, Skv + pad_k

    out = pl.pallas_call(
        functools.partial(_fa_kernel, blk_q=bq, blk_k=bk, seq_kv=Skv,
                          causal=causal, window=window, scale=scale),
        grid=(B, Kv, Sq_p // bq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, G, hd), lambda b, h, i: (b, h, i, 0, 0)),
            pl.BlockSpec((1, 1, Skv_p, hd), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, Skv_p, hd), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, G, hd),
                               lambda b, h, i: (b, h, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Kv, Sq_p, G, hd), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    out = out[:, :, :Sq]                                # strip q padding
    return jnp.moveaxis(out, 2, 1).reshape(B, Sq, H, hd)
