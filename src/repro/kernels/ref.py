"""Pure-jnp oracles for every Pallas kernel (the allclose references).

These are deliberately the most literal implementation of the math — no
chunking, no online softmax — so a kernel bug cannot be masked by a
mirrored bug in the reference.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def lstm_cell(x, h, c, kernel, bias):
    """Keras-gate-order LSTM cell. x [B,Din], h/c [B,H], kernel [(Din+H),4H]."""
    z = jnp.concatenate([x, h], axis=-1) @ kernel + bias
    i, f, g, o = jnp.split(z, 4, axis=-1)
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def rmsnorm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0):
    """Exact softmax attention (materialized scores). q [B,Sq,H,hd];
    k/v [B,Skv,Kv,hd] with GQA head grouping. Assumes q positions are
    aligned to the end of kv (self-attention, q_pos = Skv - Sq + i)."""
    B, Sq, H, hd = q.shape
    Skv, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    qg = q.reshape(B, Sq, Kv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    qpos = jnp.arange(Sq) + (Skv - Sq)
    kpos = jnp.arange(Skv)
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= kpos[None, :] <= qpos[:, None]
    if window:
        ok &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(ok[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def ternary_encode(g, scale):
    """Threshold ternarization: t = sign(g) * (|g| >= scale/2), int8."""
    t = jnp.sign(g) * (jnp.abs(g) >= scale / 2)
    return t.astype(jnp.int8)


def ternary_pack(t_flat):
    """Pack int8 {-1,0,1} (len % 4 == 0) into uint8, 2 bits each:
    {0 -> 0b00, 1 -> 0b01, -1 -> 0b10}."""
    codes = jnp.where(t_flat < 0, 2, t_flat).astype(jnp.uint8)
    c = codes.reshape(-1, 4)
    return (c[:, 0] | (c[:, 1] << 2) | (c[:, 2] << 4) | (c[:, 3] << 6))


def ternary_unpack(packed, n):
    parts = [(packed >> (2 * i)) & 3 for i in range(4)]
    codes = jnp.stack(parts, axis=1).reshape(-1)[:n]
    return jnp.where(codes == 2, -1, codes).astype(jnp.int8)
