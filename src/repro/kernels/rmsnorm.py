"""Fused RMSNorm kernel (every transformer block runs it twice per layer).

Row-tile kernel: each block normalizes [rT, D] rows entirely in VMEM —
one read of x, one write of y, vs. the unfused mean/rsqrt/mul chain which
round-trips x three times. fp32 math inside regardless of storage dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * s_ref[...].astype(jnp.float32)[None]
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = True):
    """x [..., D]; scale [D]. Returns RMS-normalized x (same dtype)."""
    orig_shape = x.shape
    D = orig_shape[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    bR = min(block_rows, R)

    y = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(pl.cdiv(R, bR),),
        in_specs=[pl.BlockSpec((bR, D), lambda r: (r, 0)),
                  pl.BlockSpec((D,), lambda r: (0,))],
        out_specs=pl.BlockSpec((bR, D), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(xf, scale)
    return y.reshape(orig_shape)
