"""TernGrad compression kernel — the paper's §III bandwidth fix, fused.

The unfused path computes |g|, compares, signs, then packs in four separate
passes over the gradient. The kernel does threshold + sign + 2-bit packing
in one pass per block: read g once, write n/4 bytes once — exactly the
byte stream the DataServer/QueueServer wire protocol ships.

Encoding: {0 -> 0b00, +1 -> 0b01, -1 -> 0b10}, little-endian within the
byte, 4 values per uint8. Block = (rows of 4*lane) so each output byte's
4 inputs sit in one block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _encode_kernel(g_ref, s_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)                   # [rT, 4]
    s = s_ref[0]
    code = jnp.where(jnp.abs(g) >= s / 2,
                     jnp.where(g > 0, 1, 2), 0).astype(jnp.uint32)
    packed = (code[:, 0] | (code[:, 1] << 2) | (code[:, 2] << 4)
              | (code[:, 3] << 6))
    o_ref[...] = packed.astype(jnp.uint8)


def _decode_kernel(p_ref, s_ref, o_ref):
    packed = p_ref[...].astype(jnp.uint32)               # [rT]
    s = s_ref[0]
    parts = [(packed >> (2 * i)) & 3 for i in range(4)]
    code = jnp.stack(parts, axis=1)                      # [rT, 4]
    val = jnp.where(code == 1, 1.0, jnp.where(code == 2, -1.0, 0.0))
    o_ref[...] = (val * s).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def ternary_encode(g_flat, scale, *, block_rows: int = 4096,
                   interpret: bool = True):
    """g_flat [N] (N % 4 == 0), scale scalar fp32 -> packed uint8 [N/4]."""
    n = g_flat.shape[0]
    assert n % 4 == 0, n
    rows = n // 4
    bR = min(block_rows, rows)
    g2 = g_flat.reshape(rows, 4)
    return pl.pallas_call(
        _encode_kernel,
        grid=(pl.cdiv(rows, bR),),
        in_specs=[pl.BlockSpec((bR, 4), lambda r: (r, 0)),
                  pl.BlockSpec((1,), lambda r: (0,))],
        out_specs=pl.BlockSpec((bR,), lambda r: (r,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.uint8),
        interpret=interpret,
    )(g2, scale.reshape(1))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def ternary_decode(packed, scale, *, block_rows: int = 4096,
                   interpret: bool = True):
    """packed uint8 [N/4], scale scalar -> g_flat fp32 [N]."""
    rows = packed.shape[0]
    bR = min(block_rows, rows)
    out = pl.pallas_call(
        _decode_kernel,
        grid=(pl.cdiv(rows, bR),),
        in_specs=[pl.BlockSpec((bR,), lambda r: (r,)),
                  pl.BlockSpec((1,), lambda r: (0,))],
        out_specs=pl.BlockSpec((bR, 4), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 4), jnp.float32),
        interpret=interpret,
    )(packed, scale.reshape(1))
    return out.reshape(rows * 4)
