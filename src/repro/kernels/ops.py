"""Jit'd public wrappers over the Pallas kernels.

On this CPU container every kernel runs with ``interpret=True`` (the body is
executed in Python on CPU); on a real TPU set ``interpret=False`` (the
default flips automatically when a TPU backend is present).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import lstm_cell as _lstm
from repro.kernels import rmsnorm as _rms
from repro.kernels import ternary as _tern


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def lstm_cell(x, h, c, kernel, bias, *, interpret: bool | None = None):
    itp = _default_interpret() if interpret is None else interpret
    return _lstm.lstm_cell(x, h, c, kernel, bias, interpret=itp)


def rmsnorm(x, scale, *, eps: float = 1e-6, interpret: bool | None = None):
    itp = _default_interpret() if interpret is None else interpret
    return _rms.rmsnorm(x, scale, eps=eps, interpret=itp)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    blk_q: int = 128, blk_k: int = 128,
                    interpret: bool | None = None):
    itp = _default_interpret() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               blk_q=blk_q, blk_k=blk_k, interpret=itp)


def ternary_encode(g_flat, scale, *, interpret: bool | None = None):
    itp = _default_interpret() if interpret is None else interpret
    return _tern.ternary_encode(g_flat, scale, interpret=itp)


def ternary_decode(packed, scale, *, interpret: bool | None = None):
    itp = _default_interpret() if interpret is None else interpret
    return _tern.ternary_decode(packed, scale, interpret=itp)
