"""Fused LSTM cell — the paper's compute hot spot (2x50-cell stacked LSTM).

One kernel invocation computes, for a batch tile and a hidden tile:

    z = [x, h] @ W + b          (MXU: one [bT, Din+H] x [Din+H, 4*hT] matmul)
    c' = sigmoid(f) * c + sigmoid(i) * tanh(g)
    h' = sigmoid(o) * tanh(c')  (VPU: fused gate elementwise)

vs. the unfused path (tfjs semantics) which materializes z in HBM and
launches 6 elementwise kernels. The weight is laid out [Din+H, 4, H] so one
hidden tile covers all four gates of the same cells, keeping the gate
nonlinearity local to the block.

TPU notes: tiles default to (8, 128)-aligned; the paper's H=50 pads to one
lane tile. All accumulation is fp32 regardless of input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cell_kernel(xh_ref, w_ref, b_ref, c_ref, h_out_ref, c_out_ref):
    """Block shapes:
      xh [bT, Dxh]      (concatenated [x, h] tile — full feature dim)
      w  [Dxh, 4, hT]   b [4, hT]   c [bT, hT]
      out h/c [bT, hT]
    """
    xh = xh_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    dxh, _, ht = w.shape
    # one MXU matmul for all four gates of this tile
    z = jax.lax.dot_general(xh, w.reshape(dxh, 4 * ht),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    z = z.reshape(z.shape[0], 4, ht) + b[None]
    i, f, g, o = z[:, 0], z[:, 1], z[:, 2], z[:, 3]
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    h_out_ref[...] = h_new.astype(h_out_ref.dtype)
    c_out_ref[...] = c_new.astype(c_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "block_h", "interpret"))
def lstm_cell(x, h, c, kernel, bias, *, block_b: int = 128,
              block_h: int = 128, interpret: bool = True):
    """x [B, Din]; h, c [B, H]; kernel [(Din+H), 4H]; bias [4H].

    Returns (h_new, c_new), matching ref.lstm_cell (keras gate order).
    """
    B, H = h.shape
    dxh = kernel.shape[0]
    w4 = kernel.reshape(dxh, 4, H)
    b4 = bias.reshape(4, H)
    xh = jnp.concatenate([x, h], axis=-1)

    bB = min(block_b, B)
    bH = min(block_h, H)
    grid = (pl.cdiv(B, bB), pl.cdiv(H, bH))

    h_new, c_new = pl.pallas_call(
        _cell_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bB, dxh), lambda ib, ih: (ib, 0)),
            pl.BlockSpec((dxh, 4, bH), lambda ib, ih: (0, 0, ih)),
            pl.BlockSpec((4, bH), lambda ib, ih: (0, ih)),
            pl.BlockSpec((bB, bH), lambda ib, ih: (ib, ih)),
        ],
        out_specs=[
            pl.BlockSpec((bB, bH), lambda ib, ih: (ib, ih)),
            pl.BlockSpec((bB, bH), lambda ib, ih: (ib, ih)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, H), h.dtype),
                   jax.ShapeDtypeStruct((B, H), c.dtype)],
        interpret=interpret,
    )(xh, w4, b4, c)
    return h_new, c_new
