"""Pallas TPU kernels for the compute hot spots (validated in interpret mode):

- ``lstm_cell``        fused gate matmul + elementwise (the paper's model)
- ``flash_attention``  GQA online-softmax attention, causal/sliding-window
- ``rmsnorm``          fused row norm
- ``ternary``          TernGrad 2-bit gradient pack/unpack (paper §III fix)

Each has a jit'd wrapper in ``ops`` and a pure-jnp oracle in ``ref``.
"""
from repro.kernels import ops, ref  # noqa: F401
