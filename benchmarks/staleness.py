"""Aggregation-policy benchmark: makespan + final loss vs policy x staleness
bound x volunteer heterogeneity (ISSUE 4).

The paper's sync-BSP barrier makes every model version wait for the slowest
volunteer that took one of its map tasks; the async policies remove the
barrier. This benchmark quantifies the trade on the calibrated cluster cost
model (benchmarks/common.cluster_cost):

- **makespan**: simulated end-to-end time for the same total gradient work
  (n_versions x n_mb mini-batch gradients) under SyncBSP, BoundedStaleness
  at several bounds, and LocalSteps — over a uniform volunteer pool and a
  straggler-heavy one (a quarter of the pool at ~1/8 speed). BoundedStaleness
  must strictly beat SyncBSP under stragglers (asserted).
- **final loss**: real Coordinator training on the reduced paper problem per
  policy FAMILY — the statistical price of changing the update rule (one
  batch step vs per-gradient SGD vs k-step averaging). The Coordinator's
  round-robin scheduler serializes barrierless tickets (that is its
  determinism guarantee), so admission always sees a fresh model and the
  loss CANNOT depend on the staleness bound — the column is shared across
  staleness:<s> rows by construction, not re-measured per bound.

CSV: name,policy,hetero,volunteers,makespan_min,events,bytes_mb,
     stale_discards,final_loss

Usage: PYTHONPATH=src python benchmarks/staleness.py [--quick]
"""
from __future__ import annotations

import argparse
from typing import List, Optional

if __package__ in (None, ""):                  # `python benchmarks/staleness.py`
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.common import cluster_cost, paper_problem
from repro.core.aggregation import make_policy
from repro.core.coordinator import Coordinator
from repro.core.simulator import Simulator, VolunteerSpec

POLICIES = ("sync", "staleness:1", "staleness:2", "staleness:4", "local:4")


def hetero_specs(kind: str, n: int = 8) -> List[VolunteerSpec]:
    """Deterministic volunteer pools. "uniform": mild spread around 1x.
    "straggler": the last quarter of the pool runs at ~1/8 speed — the
    browser-on-a-phone case that gates every sync barrier."""
    specs = []
    for i in range(n):
        if kind == "straggler" and i >= (3 * n) // 4:
            speed = 0.12
        else:
            speed = 1.0 + 0.08 * (i % 4)
        specs.append(VolunteerSpec(f"v{i:02d}", speed=speed))
    return specs


def main(reduced: bool = True, loss_versions: Optional[int] = None):
    # the problem is ALWAYS the reduced one (the loss leg trains for real on
    # one CPU; paper-scale TrainParams are infeasible there) — `reduced`
    # only scales the sweep, capped at the problem's own version horizon
    problem = paper_problem(reduced=True)
    cost = cluster_cost(problem)
    n_versions = 4 if reduced else min(12, problem.n_versions)
    n_loss = loss_versions if loss_versions is not None else (2 if reduced
                                                              else 4)
    # fault-tolerance realism: leases expire at ~2.5x a healthy map time, so
    # a straggler-held task gets redone instead of gating the run forever.
    # Sync still pays the timeout SERIALLY (once per barrier round); the
    # barrierless policies amortize redos across the pipeline — that gap is
    # the benchmark's headline.
    vis_timeout = 2.5 * problem.flops_per_map() / cost.flops_per_sec
    print("name,policy,hetero,volunteers,makespan_min,events,bytes_mb,"
          "stale_discards,final_loss")
    records = []
    makespans = {}
    # real-training loss per policy FAMILY (see module docstring: the
    # Coordinator serializes barrierless tickets, so every staleness bound
    # yields the identical float stream — one run per family is the truth)
    losses = {}
    family_loss = {}
    for spec in POLICIES:
        family = spec.split(":")[0]
        if family not in family_loss:
            res = Coordinator(problem, n_workers=3, policy=spec,
                              n_versions=n_loss).run()
            family_loss[family] = res.losses[-1]
        losses[spec] = family_loss[family]
    for hetero in ("uniform", "straggler"):
        specs = hetero_specs(hetero)
        for spec in POLICIES:
            res = Simulator(problem, specs, cost=cost, policy=spec,
                            n_versions=n_versions,
                            visibility_timeout=vis_timeout).run()
            expected = make_policy(spec).n_updates(problem, n_versions)
            # >= : expiry-driven duplicate tickets may commit extra updates
            assert res.final_version >= expected, (spec, hetero,
                                                   res.final_version)
            makespans[(hetero, spec)] = res.makespan
            print(f"staleness,{spec},{hetero},{len(specs)},"
                  f"{round(res.makespan / 60.0, 2)},{res.events},"
                  f"{round(res.bytes_sent / 1e6, 1)},{res.stale_discards},"
                  f"{losses[spec]:.3f}")
            records.append({
                "name": "staleness",
                "params": {"policy": spec, "hetero": hetero,
                           "volunteers": len(specs),
                           "n_versions": n_versions,
                           "stale_discards": res.stale_discards,
                           "final_loss": losses[spec]},
                "makespan": res.makespan,
                "events": res.events,
                "bytes": res.bytes_sent,
            })
    # the headline claim: no barrier -> stragglers stop gating the run
    for s in ("staleness:1", "staleness:2", "staleness:4"):
        speedup = makespans[("straggler", "sync")] / makespans[("straggler", s)]
        print(f"# straggler pool: {s} is {speedup:.1f}x faster than sync")
        assert makespans[("straggler", s)] < makespans[("straggler", "sync")], \
            f"{s} did not beat SyncBSP under stragglers"
    # server-side applier (ISSUE 5): same barrierless run, but the SERVER
    # applies admitted results (one SubmitUpdate round-trip) instead of the
    # volunteer (admission fetch + apply + model push). Semantics identical —
    # the SimResult matches field-for-field — so the observable is bytes per
    # committed update: ``env`` is the MEASURED envelope traffic on the
    # byte-counting wire transport (the message-flow difference, real bytes);
    # ``logical`` adds the model/gradient payload sizes the synthetic blobs
    # stand in for (client apply moves the model down again at admission and
    # up at commit; server apply moves neither).
    print("name,policy,server_apply,updates,env_bytes_per_update,"
          "logical_bytes_per_update")
    contribution = {"staleness:2": problem.grad_bytes,
                    "local:4": problem.model_bytes}
    for spec in ("staleness:2", "local:4"):
        per_update = {}
        for server_apply in (False, True):
            res = Simulator(problem, hetero_specs("uniform"), cost=cost,
                            policy=spec, n_versions=n_versions,
                            visibility_timeout=vis_timeout, transport="wire",
                            server_apply=server_apply).run()
            env = res.wire_bytes / res.final_version
            # payload flow per committed update: model down + contribution up,
            # plus (client apply only) admission model down + model push up
            payload = problem.model_bytes + contribution[spec]
            if not server_apply:
                payload += 2 * problem.model_bytes
            per_update[server_apply] = env + payload
            print(f"staleness_applier,{spec},{server_apply},"
                  f"{res.final_version},{round(env)},"
                  f"{round(per_update[server_apply])}")
            records.append({
                "name": "staleness",
                "params": {"policy": spec, "leg": "server_apply",
                           "server_apply": server_apply,
                           "n_versions": n_versions,
                           "env_bytes_per_update": env,
                           "logical_bytes_per_update": per_update[server_apply]},
                "makespan": res.makespan,
                "events": res.events,
                "bytes": res.wire_bytes,
            })
        speedup = per_update[False] / per_update[True]
        print(f"# {spec}: server-side applier cuts bytes/update "
              f"{speedup:.1f}x (model push + admission fetch eliminated)")
        assert per_update[True] < per_update[False], \
            f"{spec}: server applier did not reduce bytes per update"
    print("# OK: every BoundedStaleness bound strictly reduced makespan vs "
          "SyncBSP on the straggler-heavy pool; server-side applier reduced "
          "wire bytes per update; final-loss deltas reported per policy above")
    return records


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweep (CI smoke)")
    args = ap.parse_args()
    main(reduced=args.quick)
