"""Shared benchmark plumbing: the paper's workload + calibrated cost model.

The CostModel is calibrated so the K=1 cluster runtime and the superlinear
2..16-worker shape match the paper's Fig. 4/5 (see EXPERIMENTS.md §Paper):
a lone worker cycles model+optimizer+the whole 128-batch working set through
fast memory and thrashes; k>=2 workers each hold ~1/k of the batch.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.configs.paper_lstm import PAPER_PARAMS, TrainParams
from repro.core.mapreduce import TrainingProblem
from repro.core.simulator import CostModel, Simulator, VolunteerSpec
from repro.data.text import synthetic_corpus

# Calibrated against paper Fig. 4 / Table 4 (177.1 min at K=1, 37.0 at K=2,
# 8.4 at K=32). flops_per_sec stands for "JS on a 2019 cluster node including
# per-task dispatch"; the cache threshold is problem-relative: a lone worker's
# working set (model+opt+grad+whole batch) exceeds it, two workers' does not —
# which is exactly the paper's explanation for its superlinear speedup.

def cluster_cost(problem: TrainingProblem, *, speed: float = 1.0) -> CostModel:
    batch_bytes = (problem.tp.batch_size * problem.tp.sample_len
                   * max(problem.cfg.vocab, 96) * 4)
    cache = (problem.model_bytes + problem.grad_bytes + 0.6 * batch_bytes)
    return CostModel(flops_per_sec=3.5e7 * speed,
                     latency=0.030, bandwidth=12.5e6,
                     cache_bytes=cache, thrash_penalty=0.37)


def classroom_cost(problem: TrainingProblem) -> CostModel:
    # classroom desktops are ~3x the cluster nodes (paper: 8.8 vs 2.5 min)
    return cluster_cost(problem, speed=3.0)


def paper_problem(*, reduced: bool = False, seed: int = 0,
                  d_model: Optional[int] = None) -> TrainingProblem:
    if reduced:
        tp = TrainParams(batch_size=32, examples_per_epoch=256, num_epochs=1,
                         sample_len=40, mini_batch_size=8,
                         mini_batches_to_accumulate=4)
        return TrainingProblem.paper_problem(
            corpus=synthetic_corpus(20_000), tp=tp, seed=seed,
            d_model=d_model)
    return TrainingProblem.paper_problem(tp=PAPER_PARAMS, seed=seed,
                                         d_model=d_model)


def simulate(problem: TrainingProblem, k: int, *, cost: CostModel,
             joins: Optional[List[float]] = None,
             leaves: Optional[List[float]] = None,
             speeds: Optional[List[float]] = None,
             n_versions: Optional[int] = None):
    specs = []
    for i in range(k):
        specs.append(VolunteerSpec(
            f"v{i:02d}",
            speed=speeds[i] if speeds else 1.0,
            join_time=joins[i] if joins else 0.0,
            leave_time=leaves[i] if leaves else float("inf")))
    sim = Simulator(problem, specs, cost=cost, n_versions=n_versions)
    return sim.run()


def fmt_minutes(seconds: float) -> float:
    return round(seconds / 60.0, 1)
