"""Paper §VI (bandwidth threat) — gradient-compression codecs on the wire.

Measures, for the paper's model: bytes/map-task on the wire, end-to-end
simulated makespan with each codec, and the real-training loss under each
codec (error feedback on) — i.e., both sides of the trade.

CSV: name,codec,bytes_per_map,compression_x,makespan_min,final_loss
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import cluster_cost, fmt_minutes, paper_problem, simulate
from repro.core.coordinator import Coordinator
from repro.optim import compression as CP


def main(reduced: bool = True):
    problem = paper_problem(reduced=reduced)
    dense = CP.dense_bytes(problem.params0)
    codecs = [("none", None),
              ("topk1%", CP.make_codec("topk", fraction=0.01)),
              ("ternary", CP.make_codec("ternary"))]
    print("name,codec,bytes_per_map,compression_x,makespan_min,final_loss")
    rows = []
    for cname, codec in codecs:
        if codec is None:
            nbytes = dense
        else:
            payload, nbytes = codec.encode(
                jax.tree.map(lambda p: p.astype("float32"), problem.params0))
        # timing: same schedule, smaller grad payloads
        res_t = simulate_with_gradbytes(problem, 8, nbytes)
        # learning: real coordinator run with the codec (EF inside)
        res_l = Coordinator(problem, n_workers=2, codec=codec,
                            n_versions=min(problem.n_versions, 8)).run()
        rows.append((cname, nbytes, dense / nbytes,
                     fmt_minutes(res_t.makespan), res_l.losses[-1]))
        print(f"compression,{cname},{nbytes},{dense / nbytes:.1f},"
              f"{fmt_minutes(res_t.makespan)},{res_l.losses[-1]:.3f}")
    assert rows[2][2] > 10, "ternary must be >10x smaller"
    return rows


def simulate_with_gradbytes(problem, k, grad_bytes):
    from repro.core.simulator import Simulator, VolunteerSpec
    specs = [VolunteerSpec(f"v{i}") for i in range(k)]
    sim = Simulator(problem, specs, cost=cluster_cost(problem),
                    grad_bytes=grad_bytes)
    return sim.run()


import jax  # noqa: E402  (used in main for tree map)

if __name__ == "__main__":
    main(reduced=False)
