"""Paper §VI (bandwidth threat) — gradient-compression codecs on the wire.

Measures, for the paper's model: bytes/map-task on the wire, end-to-end
simulated makespan + total traffic with each codec under both the sync-BSP
baseline and the policy-aware simulate path (BoundedStaleness async SGD —
whose cost model ships the compressed gradient up per update), and the
real-training loss under each codec (error feedback on) — i.e., both sides
of the trade. On the reduced problem compute dominates, so the codec shows
up mostly in the traffic columns; the makespan gap opens at paper scale.

CSV: name,codec,bytes_per_map,compression_x,makespan_min,makespan_async_min,
     sim_mb,sim_async_mb,final_loss
"""
from __future__ import annotations

import jax

if __package__ in (None, ""):              # `python benchmarks/compression.py`
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.common import cluster_cost, fmt_minutes, paper_problem
from repro.core.coordinator import Coordinator
from repro.optim import compression as CP


def main(reduced: bool = True):
    problem = paper_problem(reduced=reduced)
    dense = CP.dense_bytes(problem.params0)
    codecs = [("none", None),
              ("topk1%", CP.make_codec("topk", fraction=0.01)),
              ("ternary", CP.make_codec("ternary"))]
    print("name,codec,bytes_per_map,compression_x,makespan_min,"
          "makespan_async_min,sim_mb,sim_async_mb,final_loss")
    rows = []
    for cname, codec in codecs:
        if codec is None:
            nbytes = dense
        else:
            payload, nbytes = codec.encode(
                jax.tree.map(lambda p: p.astype("float32"), problem.params0))
        # timing: same schedule, smaller grad payloads — sync barrier AND the
        # policy-aware path (async SGD pushes the same compressed gradients)
        res_t = simulate_with_gradbytes(problem, 8, nbytes)
        res_a = simulate_with_gradbytes(problem, 8, nbytes,
                                        policy="staleness:2")
        # learning: real coordinator run with the codec (EF inside)
        res_l = Coordinator(problem, n_workers=2, codec=codec,
                            n_versions=min(problem.n_versions, 8)).run()
        rows.append((cname, nbytes, dense / nbytes,
                     fmt_minutes(res_t.makespan), fmt_minutes(res_a.makespan),
                     res_t.bytes_sent, res_a.bytes_sent, res_l.losses[-1]))
        print(f"compression,{cname},{nbytes},{dense / nbytes:.1f},"
              f"{fmt_minutes(res_t.makespan)},{fmt_minutes(res_a.makespan)},"
              f"{res_t.bytes_sent / 1e6:.1f},{res_a.bytes_sent / 1e6:.1f},"
              f"{res_l.losses[-1]:.3f}")
    assert rows[2][2] > 10, "ternary must be >10x smaller"
    # the codec must actually shrink simulated traffic on BOTH paths
    assert rows[1][5] < rows[0][5] and rows[2][5] < rows[0][5]
    assert rows[1][6] < rows[0][6] and rows[2][6] < rows[0][6]
    return rows


def simulate_with_gradbytes(problem, k, grad_bytes, *, policy=None):
    from repro.core.simulator import Simulator, VolunteerSpec
    specs = [VolunteerSpec(f"v{i}") for i in range(k)]
    sim = Simulator(problem, specs, cost=cluster_cost(problem),
                    grad_bytes=grad_bytes, policy=policy)
    return sim.run()


if __name__ == "__main__":
    main(reduced=False)
