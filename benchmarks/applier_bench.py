"""Server-apply throughput: single-dispatch vs batched drains.

Drives the REAL protocol surface — ``ServerEndpoint.submit_batch`` over a
``make_real_applier`` — with pre-staged gradient chains, and measures
updates/sec for the per-update pytree path (``batch=False``, the
pre-batching baseline) against the flat donated ``lax.scan`` path at drain
sizes 1/4/16/64. Gradient work is identical across paths (the same staged
chain is replayed), and every run's final model is bit-asserted against
``sequential_async`` before its time is accepted.

The d_model axis spans the paper's browser-device regime (tiny cells, where
the per-update jitted-dispatch overhead dominates and batching pays) up to
the paper's d50 cell (where the optimizer math itself dominates). On a
1-core host timings are noisy, so every figure is best-of-N.

CSV: name,d_model,batch,us_per_update,speedup_vs_single
"""
from __future__ import annotations

import argparse
import time
from typing import List

import jax
import numpy as np

from benchmarks.common import paper_problem
from repro.core.aggregation import make_policy
from repro.core.applier import make_real_applier
from repro.core.dataserver import DataServer
from repro.core.mapreduce import sequential_async
from repro.core.protocol import (FetchModel, ServerEndpoint, SubmitUpdate,
                                 UpdateCommitted)
from repro.core.queue import QueueServer
from repro.core.tasks import GradResult, INITIAL_QUEUE

POLICY = "staleness:2"


def _staged_chain(problem, n: int):
    """g_i computed at params_i along the exact reference chain — replaying
    these through any admission-clean apply path must land on the
    ``sequential_async`` bits."""
    p, s = problem.params0, problem.opt_state0
    grads = []
    for i in range(n):
        v, mb = problem.stream_slot(i)
        g, _ = problem.map_compute(p, v, mb)
        grads.append(g)
        p, s = problem.apply_one(p, s, g)
    return grads, (p, s)


def _run_once(problem, grads, batch_size: int, *, batched: bool):
    """One full replay: U updates in drains of ``batch_size`` through a fresh
    endpoint. Returns (seconds, final_blob, applier)."""
    qs, ds = QueueServer(), DataServer()
    qs.declare(INITIAL_QUEUE, timeout=float("inf"))
    ds.publish_model(0, (problem.params0, problem.opt_state0), nbytes=0)
    applier = make_real_applier(problem, make_policy(POLICY), batch=batched)
    endpoint = ServerEndpoint(qs, ds, applier=applier)
    # the one-shot wire-size measurement is server-lifetime cost (the size is
    # structure-constant and cached); don't charge it to a short replay
    applier.backend.measure((problem.params0, problem.opt_state0))
    drains: List[List[SubmitUpdate]] = []
    for base in range(0, len(grads), batch_size):
        msgs = []
        for i in range(base, min(base + batch_size, len(grads))):
            qs.publish(INITIAL_QUEUE, f"t{i}")
            tag, _ = qs.lease(INITIAL_QUEUE, "bench", 0.0)
            msgs.append(SubmitUpdate(INITIAL_QUEUE, tag, GradResult(
                version=i, mb_index=0, payload=grads[i], computed_at=i)))
        drains.append(msgs)
    t0 = time.perf_counter()
    for msgs in drains:
        replies = endpoint.submit_batch(msgs)
        assert all(isinstance(r, UpdateCommitted) for r in replies)
    # lazy blobs defer the final unflatten; materialize + sync before
    # stopping the clock so both paths pay their full cost
    blob = endpoint.handle(FetchModel(len(grads))).blob
    jax.block_until_ready(blob)
    dt = time.perf_counter() - t0
    return dt, blob, applier


def _bit_eq(a, b) -> bool:
    return bool(jax.tree.all(jax.tree.map(
        lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()), a, b)))


def main(quick: bool = False):
    d_models = (4, 8) if quick else (4, 8, 16, 50)
    batches = (1, 16) if quick else (1, 4, 16, 64)
    updates = 32 if quick else 64
    reps = 3 if quick else 5
    rows = []
    records = []
    print("name,d_model,batch,us_per_update,speedup_vs_single")
    for d in d_models:
        problem = paper_problem(reduced=True, d_model=d)
        grads, (ref_p, ref_s) = _staged_chain(problem, updates)
        # wire-deserialized payloads arrive as numpy; feeding device arrays
        # would charge the batched path a jax->host hop per leaf that the
        # real gateway never pays
        grads = [jax.tree.map(np.asarray, g) for g in grads]
        ref = sequential_async(problem, n_updates=updates)[:2]
        assert _bit_eq((ref_p, ref_s), ref), "staged chain drifted from ref"

        model_nbytes = 0

        def best(batch_size: int, batched: bool) -> float:
            nonlocal model_nbytes
            dts = []
            for _ in range(reps):
                dt, blob, applier = _run_once(problem, grads, batch_size,
                                              batched=batched)
                assert _bit_eq(blob, ref), \
                    f"d{d} B={batch_size} batched={batched}: bits diverged"
                assert applier.applied == updates
                model_nbytes = applier.model_nbytes
                dts.append(dt)
            return min(dts)

        single_us = best(1, batched=False) / updates * 1e6
        for b in batches:
            if b == 1:
                us, speed, path = single_us, 1.0, "single"
            else:
                us = best(b, batched=True) / updates * 1e6
                speed, path = single_us / us, "batched"
            print(f"applier,{d},{b},{us:.1f},{speed:.2f}")
            rows.append((d, b, us, speed))
            records.append({
                "name": f"applier_d{d}_b{b}",
                "params": {"d_model": d, "batch": b, "path": path,
                           "updates": updates,
                           "us_per_update": round(us, 1),
                           "speedup_vs_single": round(speed, 2)},
                "makespan": us * updates / 1e6,
                "events": updates,
                "bytes": model_nbytes * updates,
            })
    # the acceptance headline: at browser-regime model sizes, drains >= 16
    # must clear 3x (the big models are optimizer-math-bound and exempt)
    head = [s for d, b, us, s in rows if d <= 8 and b >= 16]
    if head:
        print(f"# batched speedup at batch>=16 (d_model<=8): "
              f"min {min(head):.2f}x, max {max(head):.2f}x")
    return records


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweep for CI (d8/d16, batch 1/16)")
    args = ap.parse_args()
    main(quick=args.quick)
