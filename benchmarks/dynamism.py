"""BEYOND-PAPER: the task-granularity vs churn trade-off the paper defers.

Paper §VI: "we must find a balance between a large task size to avoid
communication overhead, while at the same time avoiding a too large task
size that causes a high risk due to the failure rate ... it needs a
separate paper". The L1 simulator answers it directly: sweep the map-task
size (mini-batch size, at constant global batch) against volunteer churn
(mean session length), measure makespan + wasted (requeued) work.

CSV: name,mini_batch,churn_mean_s,makespan_min,requeues,waste_fraction
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import cluster_cost, fmt_minutes
from repro.configs.paper_lstm import TrainParams
from repro.core.mapreduce import TrainingProblem
from repro.core.simulator import Simulator, VolunteerSpec
from repro.data.text import synthetic_corpus


def run_point(mb_size: int, churn_mean: float, *, k: int = 16, seed: int = 0,
              reduced: bool = True):
    batch = 32 if reduced else 128
    epochs = 1 if reduced else 5
    examples = 256 if reduced else 2048
    tp = TrainParams(batch_size=batch, examples_per_epoch=examples,
                     num_epochs=epochs, sample_len=40,
                     mini_batch_size=mb_size,
                     mini_batches_to_accumulate=batch // mb_size)
    prob = TrainingProblem.paper_problem(corpus=synthetic_corpus(20_000),
                                         tp=tp, seed=seed)
    rng = np.random.RandomState(seed)
    specs = []
    t = 0.0
    # a rolling population: each volunteer stays ~churn_mean seconds, a
    # replacement joins when one leaves (constant expected population k)
    horizon = 3600.0
    for i in range(k * 12):
        join = (0.0 if i < k else float(rng.uniform(0, horizon)))
        stay = float(rng.exponential(churn_mean)) if np.isfinite(churn_mean) \
            else float("inf")
        specs.append(VolunteerSpec(f"v{i:03d}", join_time=join,
                                   leave_time=join + stay))
    sim = Simulator(prob, specs, cost=cluster_cost(prob),
                    visibility_timeout=60.0)
    res = sim.run()
    total_tasks = prob.n_versions * (tp.mini_batches_to_accumulate + 1)
    waste = res.requeues / max(total_tasks, 1)
    return res, waste


def main(reduced: bool = True):
    print("name,mini_batch,churn_mean_s,makespan_min,requeues,waste_fraction")
    rows = []
    for mb in (2, 8, 32):
        for churn in (30.0, 120.0, float("inf")):
            res, waste = run_point(mb, churn, reduced=reduced)
            label = "inf" if not np.isfinite(churn) else int(churn)
            rows.append((mb, label, fmt_minutes(res.makespan), res.requeues,
                         round(waste, 3)))
            print(f"dynamism,{mb},{label},{fmt_minutes(res.makespan)},"
                  f"{res.requeues},{round(waste, 3)}")
    # the paper's conjecture, quantified: under heavy churn small tasks win;
    # with stable volunteers large tasks win (less per-task overhead)
    by = {(r[0], r[1]): r[2] for r in rows}
    assert by[(2, 30)] <= by[(32, 30)] * 1.5, \
        "small tasks should not lose badly under heavy churn"
    return rows


if __name__ == "__main__":
    main(reduced=False)
