"""Multi-gateway control plane benchmark: forwarding overhead + failover gap.

Two questions the K-gateway replicated control plane (``repro.core.gateway
--gid``) must answer with numbers:

1. **Forwarding tax** — a volunteer homed on a gateway that does not own the
   slice its request targets pays one extra inter-gateway ``Forward`` hop.
   The sweep runs the same workload against in-process clusters of K=1
   (single gateway, op log on — the durability baseline), K=2 and K=3, and
   reports end-to-end task throughput (updates/sec through the full
   wire + fsync path).

2. **Failover gap** — when the MODEL-owning gateway is killed (``die()``:
   the in-process stand-in for kill -9, buffered ops dropped), how long
   until a request against the dead slice succeeds again through a
   survivor? That interval covers death detection, op-log replay by the
   deterministic adopter, and slice re-routing — measured by a probe client
   hammering ``LatestReq`` (ring-routed to the dead slice) through a
   surviving gateway.

CSV: leg,gateways,volunteers,tasks,wall_s,updates_per_sec,gap_ms

Usage: PYTHONPATH=src python benchmarks/multi_gateway.py [--quick]
"""
from __future__ import annotations

import argparse
import tempfile
import threading
import time
from typing import Dict, List, Optional, Tuple

from repro.core.elastic import MODEL_KEY, GatewayRing
from repro.core.gateway import (GatewayServer, SocketTransport,
                                run_volunteer_resilient)
from repro.core.protocol import LatestReq
from repro.core.simulator import SyntheticProblem

POLICY = "sync"


def _problem(n_versions: int, n_mb: int) -> SyntheticProblem:
    return SyntheticProblem(n_versions=n_versions, n_mb=n_mb,
                            model_bytes=1.0e4, grad_bytes=1.0e3,
                            map_flops=1.0e6, reduce_flops=1.0e5)


def _cluster(k: int, problem: SyntheticProblem, tmpdir: str,
             visibility_timeout: float = 2.0) -> List[GatewayServer]:
    servers = [GatewayServer(problem, policy=POLICY, gid=g, gateways=k,
                             cluster_dir=tmpdir,
                             visibility_timeout=visibility_timeout)
               for g in range(k)]
    for s in servers:
        s.start()
    return servers


def _drive(ports: List[int], n_volunteers: int, target: int, *,
           task_delay: float = 0.0) -> Tuple[float, int]:
    """Run ``n_volunteers`` resilient volunteers homed round-robin over the
    cluster ports until every one reaches ``target``. Returns
    (wall seconds, total tasks done)."""
    results: Dict[int, Tuple[int, int, int]] = {}

    def run(i: int) -> None:
        home = i % len(ports)
        order = [ports[home]] + [p for j, p in enumerate(ports)
                                 if j != home]
        results[i] = run_volunteer_resilient(
            "127.0.0.1", order[0], f"bench{i}", target, policy=POLICY,
            task_delay=task_delay, fallback_ports=tuple(order[1:]))

    threads = [threading.Thread(target=run, args=(i,), daemon=True)
               for i in range(n_volunteers)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=180)
        assert not t.is_alive(), "benchmark volunteer deadlocked"
    wall = time.perf_counter() - t0
    finals = [results[i][0] for i in sorted(results)]
    assert finals == [target] * n_volunteers, \
        f"run did not converge: {finals} != {target}"
    return wall, sum(results[i][1] for i in results)


def throughput_leg(k: int, n_versions: int, n_mb: int,
                   n_volunteers: int) -> dict:
    problem = _problem(n_versions, n_mb)
    target = n_versions                       # sync: one commit per version
    with tempfile.TemporaryDirectory() as td:
        servers = _cluster(k, problem, td)
        try:
            wall, tasks = _drive([s.port for s in servers], n_volunteers,
                                 target)
        finally:
            for s in servers:
                s.close()
    ups = tasks / wall if wall > 0 else 0.0
    print(f"throughput,{k},{n_volunteers},{tasks},{wall:.3f},{ups:.1f},")
    return {"name": f"multi_gateway_throughput_k{k}",
            "params": {"gateways": k, "volunteers": n_volunteers,
                       "policy": POLICY, "n_versions": n_versions,
                       "n_mb": n_mb, "updates_per_sec": round(ups, 1)},
            "makespan": round(wall, 3), "events": tasks, "bytes": None}


def _probe_gap(port: int, timeout: float = 30.0) -> float:
    """Seconds until a ``LatestReq`` against the dead slice succeeds again
    through the surviving gateway at ``port``."""
    t0 = time.perf_counter()
    deadline = t0 + timeout
    probe: Optional[SocketTransport] = None
    while True:
        try:
            if probe is None:
                probe = SocketTransport("127.0.0.1", port, "gap-probe",
                                        connect_timeout=5.0)
            probe.call(LatestReq())
            break
        except (ConnectionError, OSError):
            if probe is not None:
                try:
                    probe.close()
                except OSError:
                    pass
                probe = None
            if time.perf_counter() >= deadline:
                raise RuntimeError("failover never completed")
            time.sleep(0.01)
    gap = time.perf_counter() - t0
    try:
        probe.close()
    except OSError:
        pass
    return gap


def failover_leg(k: int, n_versions: int, n_mb: int,
                 n_volunteers: int) -> dict:
    """Kill the MODEL-owning gateway mid-run; measure the gap until the
    slice answers again, and require the run to still converge."""
    problem = _problem(n_versions, n_mb)
    target = n_versions
    victim = GatewayRing(range(k)).owner_of(MODEL_KEY)
    with tempfile.TemporaryDirectory() as td:
        servers = _cluster(k, problem, td)
        try:
            ports = [s.port for s in servers]
            survivor = next(p for g, p in enumerate(ports) if g != victim)
            done: Dict[int, Tuple[int, int, int]] = {}

            def run(i: int) -> None:
                home = i % k
                order = [ports[home]] + [p for j, p in enumerate(ports)
                                         if j != home]
                done[i] = run_volunteer_resilient(
                    "127.0.0.1", order[0], f"fv{i}", target, policy=POLICY,
                    task_delay=0.05, fallback_ports=tuple(order[1:]))

            threads = [threading.Thread(target=run, args=(i,), daemon=True)
                       for i in range(n_volunteers)]
            for t in threads:
                t.start()
            time.sleep(0.8)                   # mid-run
            servers[victim].die()
            gap = _probe_gap(survivor)
            for t in threads:
                t.join(timeout=120)
                assert not t.is_alive(), "failover volunteer deadlocked"
            finals = [done[i][0] for i in sorted(done)]
            assert finals == [target] * n_volunteers, \
                f"failover run did not converge: {finals}"
            reconnects = sum(done[i][2] for i in done)
        finally:
            for s in servers:
                s.close()
    gap_ms = gap * 1e3
    print(f"failover,{k},{n_volunteers},,,,{gap_ms:.1f}")
    return {"name": f"multi_gateway_failover_k{k}",
            "params": {"gateways": k, "volunteers": n_volunteers,
                       "policy": POLICY, "victim": victim,
                       "reconnects": reconnects,
                       "gap_ms": round(gap_ms, 1)},
            "makespan": round(gap, 3), "events": None, "bytes": None}


def main(quick: bool = False) -> List[dict]:
    n_versions, n_mb = (3, 4) if quick else (6, 8)
    n_volunteers = 3 if quick else 6
    print("leg,gateways,volunteers,tasks,wall_s,updates_per_sec,gap_ms")
    records = []
    for k in (1, 2, 3):
        records.append(throughput_leg(k, n_versions, n_mb, n_volunteers))
    for k in ((3,) if quick else (2, 3)):
        records.append(failover_leg(k, n_versions, n_mb, n_volunteers))
    base = next(r for r in records
                if r["name"] == "multi_gateway_throughput_k1")
    k3 = next(r for r in records
              if r["name"] == "multi_gateway_throughput_k3")
    print(f"# throughput scaling (k=1 -> k=3): "
          f"{base['params']['updates_per_sec']:.1f} -> "
          f"{k3['params']['updates_per_sec']:.1f} updates/sec "
          f"(forwarding hop vs parallel dispatch)")
    return records


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes (~seconds, the CI leg)")
    args = ap.parse_args()
    main(quick=args.quick)
