"""Volunteer-scale benchmark: event-driven subscriptions vs queue polling.

The paper stops at 32 browsers; the ROADMAP's north star is millions. The
blocker is coordination style: with client-side polling a discrete-event
simulation of N volunteers costs O(N x makespan / poll_interval) events, so
10k volunteers are intractable; with push subscriptions
(`Queue.subscribe` / `DataServer.watch_version`) events scale with the WORK.

This benchmark simulates 1k and 10k heterogeneous volunteers with churn (5%
leave mid-run, 5% join late) under both modes and verifies:

- identical semantics: same final model version and same total task count,
- >= 10x fewer simulator events in subscription mode (target from ISSUE 1),

and additionally runs the event mode over a 4-shard consistent-hash
QueueServer federation to show sharding is semantics-invisible while
spreading queue load.

CSV: name,volunteers,mode,shards,events,poll_events,wakeups,makespan_min,wall_s

Usage: PYTHONPATH=src python benchmarks/volunteer_scaling.py [--quick]
"""
from __future__ import annotations

import argparse
import math
import random
import time

from repro.core.simulator import (CostModel, Simulator, SyntheticProblem,
                                  VolunteerSpec)


def make_problem() -> SyntheticProblem:
    # ~a JSDoop-class LSTM: 2 MB model, 200 kB compressed gradient, 64-way
    # gradient accumulation, 20 model versions -> 1,300 tasks total
    return SyntheticProblem(n_versions=20, n_mb=64, model_bytes=2.0e6,
                            grad_bytes=2.0e5, map_flops=1.0e9,
                            reduce_flops=5.0e7)


def make_cost() -> CostModel:
    # browser-grade volunteers on home links; cache model disabled (working
    # sets here are all >> any browser cache, so speeds are the heterogeneity)
    return CostModel(flops_per_sec=2.0e9, latency=0.030, bandwidth=12.5e6,
                     poll_interval=0.200, cache_bytes=1e15)


def make_specs(n: int, *, seed: int = 0, churn_frac: float = 0.05):
    """Heterogeneous volunteers: speeds 0.5-2.5x, ~5% leave mid-run, ~5% join
    late. Deterministic per seed so every mode sees the identical population."""
    rng = random.Random(seed)
    specs = []
    for i in range(n):
        speed = 0.5 + 2.0 * rng.random()
        join = 0.0 if rng.random() < 0.8 else rng.uniform(0.0, 20.0)
        leave = math.inf
        if rng.random() < churn_frac:
            leave = rng.uniform(10.0, 60.0)
        specs.append(VolunteerSpec(f"v{i:05d}", speed=speed, join_time=join,
                                   leave_time=leave))
    return specs


def run_one(n_volunteers: int, mode: str, *, n_shards: int = 1,
            seed: int = 0, max_events: int = 30_000_000,
            transport: str = "inproc"):
    sim = Simulator(make_problem(), make_specs(n_volunteers, seed=seed),
                    cost=make_cost(), mode=mode, n_shards=n_shards,
                    visibility_timeout=1.0e9, max_events=max_events,
                    transport=transport)
    t0 = time.perf_counter()
    res = sim.run()
    wall = time.perf_counter() - t0
    return res, wall, sim.qs.total_wakeups


def main(quick: bool = False):
    sizes = [1_000] if quick else [1_000, 10_000]
    print("name,volunteers,mode,shards,events,poll_events,wakeups,"
          "makespan_min,wall_s")
    problem = make_problem()
    n_tasks = problem.n_versions * (problem.tp.mini_batches_to_accumulate + 1)
    ok = True
    ev1k = None
    records = []

    def record(res, **params):
        records.append({"name": "volunteer_scaling", "params": params,
                        "makespan": res.makespan, "events": res.events,
                        "bytes": res.bytes_sent})

    for n in sizes:
        rows = {}
        for mode, shards in (("poll", 1), ("event", 1), ("event", 4)):
            res, wall, wakeups = run_one(n, mode, n_shards=shards)
            rows[(mode, shards)] = res
            record(res, volunteers=n, mode=mode, shards=shards,
                   transport="inproc", wall_s=round(wall, 2))
            print(f"volunteer_scaling,{n},{mode},{shards},{res.events},"
                  f"{res.poll_events},{wakeups},"
                  f"{round(res.makespan / 60.0, 2)},{round(wall, 2)}")
        po, ev, ev4 = rows[("poll", 1)], rows[("event", 1)], rows[("event", 4)]
        if n == 1_000:
            ev1k = ev
        # identical semantics across modes and federation sizes
        for r in (po, ev, ev4):
            assert r.final_version == problem.n_versions, r.final_version
            assert sum(r.tasks_by_worker.values()) == n_tasks, \
                (n, sum(r.tasks_by_worker.values()), n_tasks)
        assert ev.poll_events == 0
        ratio = po.events / max(ev.events, 1)
        print(f"# {n} volunteers: {po.events} poll-mode events vs "
              f"{ev.events} event-mode events -> {ratio:.1f}x fewer")
        if ratio < 10.0:
            ok = False
            print(f"# FAIL: ratio {ratio:.1f}x below the 10x target")
    # wire-transport leg (1k): every protocol message round-trips through
    # bytes and MEASURED sizes feed the network cost model — semantics must
    # be unchanged (same versions, same task total), no event regression
    wire, wall, _ = run_one(1_000, "event", transport="wire")
    record(wire, volunteers=1_000, mode="event", shards=1, transport="wire",
           wall_s=round(wall, 2))
    print(f"volunteer_scaling_wire,1000,event,1,{wire.events},0,-,"
          f"{round(wire.makespan / 60.0, 2)},{round(wall, 2)}")
    assert wire.final_version == problem.n_versions
    assert sum(wire.tasks_by_worker.values()) == n_tasks
    assert wire.wire_bytes > 0
    # measured byte costs shift virtual timings (and thus churn interleaving),
    # but the protocol layer must not inflate the event count materially
    # (ev1k comes from the main sweep above — same seed, same population)
    assert wire.events <= 2 * ev1k.events, \
        f"wire transport inflated the event count: {wire.events} vs {ev1k.events}"
    if not ok:
        raise RuntimeError("event-driven coordination missed the 10x target")
    print("# OK: event-driven coordination meets the >=10x target at "
          "identical semantics")
    return records


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="1k volunteers only (CI smoke)")
    main(**vars(ap.parse_args()))
