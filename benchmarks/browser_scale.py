"""Browser-scale scenario sweeps: 100k-1M volunteers with session traces.

The paper stops at 32 browsers; the ROADMAP's north star is "millions of
heterogeneous, unreliable volunteers". This benchmark simulates fleets of
**devices behaving like people** — ``repro.core.traces`` session traces
with diurnal churn, heavy-tailed (lognormal) session lengths, and a
mobile/laptop/desktop speed mixture, calibrated to the paper's "users were
online ~6.5 h/day" — and sweeps two scenario families:

- **scale**: a fixed JSDoop-class workload served by fleets from 10k up to
  1M devices (a 4-hour steady-state slice of each fleet's day). Makespan
  should stay flat once task parallelism saturates while events/bytes track
  the coordination cost of an ever-larger, mostly-idle, churning fleet —
  per aggregation policy family (sync BSP / bounded staleness / local
  steps). The O(log N) active-fleet counting this sweep forced into the
  Simulator is what makes the million-device points tractable at all.
- **diurnal**: a small fleet, a compressed 10-minute "day", and a workload
  sized to span several days, run at diurnal amplitude 0 (flat arrivals)
  vs 0.7 (pronounced peak/trough). Makespan must track availability: the
  same work on the same devices takes measurably longer when the fleet
  breathes with the day cycle.

Every run asserts the protocol completed (final version == policy target)
despite thousands of mid-task departures. Records land in
``BENCH_browser_scale.json`` via ``benchmarks/run.py``.

CSV: name,family,policy,devices,sessions,events,requeues,makespan_min,wall_s

Usage: PYTHONPATH=src python benchmarks/browser_scale.py [--quick] [--flagship]
"""
from __future__ import annotations

import argparse
import time

from repro.core.simulator import CostModel, Simulator, SyntheticProblem
from repro.core.traces import TraceParams, generate_sessions, trace_stats

POLICIES = ("sync", "staleness:4", "local:4")

HEADER = ("name,family,policy,devices,sessions,events,requeues,"
          "makespan_min,wall_s")


def make_problem() -> SyntheticProblem:
    # a JSDoop-class LSTM with 128-way gradient accumulation: 2 MB model,
    # 200 kB compressed gradient, 20 model versions
    return SyntheticProblem(n_versions=20, n_mb=128, model_bytes=2.0e6,
                            grad_bytes=2.0e5, map_flops=1.0e9,
                            reduce_flops=5.0e7)


def make_cost() -> CostModel:
    # browser-grade devices on home links; the cache model is disabled so
    # the trace's device-speed mixture is the only heterogeneity
    return CostModel(flops_per_sec=2.0e9, latency=0.030, bandwidth=12.5e6,
                     cache_bytes=1e15)


def run_scale_point(policy: str, n_devices: int, *, horizon: float,
                    seed: int = 7):
    """One scale-family point: steady-state fleet slice, fixed workload."""
    params = TraceParams(n_devices=n_devices, horizon=horizon, seed=seed)
    specs = generate_sessions(params)
    problem = make_problem()
    sim = Simulator(problem, specs, cost=make_cost(), mode="event",
                    policy=policy, visibility_timeout=900.0,
                    max_events=80_000_000,
                    server_apply=not policy.startswith("sync"))
    t0 = time.perf_counter()
    res = sim.run()
    wall = time.perf_counter() - t0
    assert res.final_version == sim.n_updates, \
        (policy, n_devices, res.final_version, sim.n_updates)
    return res, len(specs), wall


def run_diurnal_point(amplitude: float, *, n_devices: int = 60,
                      n_versions: int = 60, seed: int = 11):
    """One diurnal-family point: compressed 10-minute day, work sized to
    span ~3 compressed days, sessions a handful of tasks long —
    availability breathes, the work must ride it out through lease expiry
    + requeue. Tasks are slow (10-70 s against 50 s median sessions) so
    the binding resource is who is ONLINE, which is the diurnal signal."""
    day = 600.0
    params = TraceParams(
        n_devices=n_devices, horizon=6 * day, day=day,
        diurnal_amplitude=amplitude, session_median=50.0, seed=seed)
    specs = generate_sessions(params)
    problem = SyntheticProblem(n_versions=n_versions, n_mb=32,
                               model_bytes=2.0e6, grad_bytes=2.0e5,
                               map_flops=2.0e10, reduce_flops=5.0e7)
    sim = Simulator(problem, specs, cost=make_cost(), mode="event",
                    policy="local:4", visibility_timeout=60.0,
                    max_events=80_000_000, server_apply=True)
    t0 = time.perf_counter()
    res = sim.run()
    wall = time.perf_counter() - t0
    assert res.final_version == sim.n_updates, \
        (amplitude, res.final_version, sim.n_updates)
    return res, len(specs), wall


def main(quick: bool = False, flagship: bool = False):
    """Sweep both families. ``flagship`` adds the 320k/1M-device points
    (minutes of wall time — used to refresh the committed records, not CI).
    Returns BENCH records."""
    print(HEADER)
    records = []

    def record(name: str, res, *, family: str, wall: float, **params):
        params.update(family=family, policy=res.policy,
                      requeues=res.requeues, wall_s=round(wall, 2))
        records.append({"name": name, "params": params,
                        "makespan": res.makespan, "events": res.events,
                        "bytes": res.bytes_sent})

    def emit(family, res, devices, sessions, wall):
        print(f"browser_scale,{family},{res.policy},{devices},{sessions},"
              f"{res.events},{res.requeues},"
              f"{round(res.makespan / 60.0, 2)},{round(wall, 2)}")

    # -- scale family -------------------------------------------------------
    # a 4 h steady-state slice of each fleet's day; the quick CI leg caps
    # the slice at 30 min and the fleet at 100k devices, one policy each
    horizon = 1800.0 if quick else 14_400.0
    fleets = [10_000, 100_000] if quick else [10_000, 32_000, 100_000]
    plan = [(p, n) for p in POLICIES
            for n in (fleets[-1:] if quick and p != "staleness:4" else fleets)]
    if flagship:
        plan += [("staleness:4", 320_000), ("staleness:4", 1_000_000)]
    makespans = {}
    for policy, n_devices in plan:
        res, sessions, wall = run_scale_point(policy, n_devices,
                                              horizon=horizon)
        makespans[(policy, n_devices)] = res.makespan
        record("browser_scale", res, family="scale", wall=wall,
               devices=n_devices, sessions=sessions, horizon=horizon)
        emit("scale", res, n_devices, sessions, wall)
    # growing the idle fleet must not blow up the coordination work: the
    # biggest fleet's makespan stays within 2x of the smallest's per policy
    for policy in POLICIES:
        ms = [makespans[k] for k in sorted(makespans) if k[0] == policy]
        assert max(ms) <= 2.0 * min(ms), (policy, ms)

    # -- diurnal family (cheap either way: runs identically in quick) -------
    flat_res, flat_sessions, flat_wall = run_diurnal_point(0.0)
    tide_res, tide_sessions, tide_wall = run_diurnal_point(0.8)
    for amp, res, sessions, wall in ((0.0, flat_res, flat_sessions,
                                      flat_wall),
                                     (0.8, tide_res, tide_sessions,
                                      tide_wall)):
        record("browser_scale_diurnal", res, family="diurnal", wall=wall,
               devices=60, amplitude=amp)
        emit("diurnal", res, 60, sessions, wall)
    ratio = tide_res.makespan / flat_res.makespan
    print(f"# diurnal: flat-arrival makespan {flat_res.makespan / 60:.1f} min "
          f"vs amplitude-0.8 {tide_res.makespan / 60:.1f} min "
          f"({ratio:.2f}x) — the same work rides the fleet's day cycle")
    assert ratio > 1.1, \
        f"diurnal churn left no availability signature: {ratio:.2f}x"
    print(f"# OK: every sweep point finished its run despite churn "
          f"({len(records)} records)")
    return records


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: capped fleet + short slice")
    ap.add_argument("--flagship", action="store_true",
                    help="add the 320k/1M-device points (slow)")
    main(**vars(ap.parse_args()))
