"""Run every benchmark (one per paper table/figure) in reduced mode.

  PYTHONPATH=src python -m benchmarks.run          # reduced (~minutes)
  PYTHONPATH=src python -m benchmarks.run --full   # paper-scale parameters

Artifacts covered:
  Fig. 4/5/6  cluster_scaling     runtime / relative speedup / efficiency
  Table 4     classroom           cluster vs classroom vs sequential + loss
  Fig. 7      timeline            per-volunteer task spans
  Fig. 8      sequential_baseline absolute speedup vs TFJS-Sequential-128/8
  §VI         compression         top-k / ternary wire bytes + convergence
  (kernels)   kernel_bench        us_per_call per Pallas kernel
  (roofline)  roofline            dry-run derived terms, if records exist
  (scale)     volunteer_scaling   event-driven vs polling at 1k/10k volunteers
  (elastic)   rebalance           live shard join/leave migration cost
  (policies)  staleness           makespan + loss vs aggregation policy

Perf trajectory: suites that return record lists additionally write
``BENCH_<name>.json`` — a JSON list of records, each with the schema
``{name, params, makespan, events, bytes}`` — so successive PRs can diff
machine-readable performance, not just eyeball CSV.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time
import traceback

# suites whose return value is a list of perf records to persist
BENCH_RECORD_SUITES = ("volunteer_scaling", "rebalance", "staleness")


def write_bench_records(name: str, records) -> None:
    path = pathlib.Path(f"BENCH_{name}.json")
    path.write_text(json.dumps(records, indent=1, default=float) + "\n")
    print(f"# {name}: wrote {len(records)} perf records to {path}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale parameters (slow on 1 CPU)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    reduced = not args.full

    from benchmarks import (classroom, cluster_scaling, compression,
                            dynamism, kernel_bench, rebalance, roofline,
                            sequential_baseline, staleness, timeline,
                            volunteer_scaling)
    suites = [
        ("volunteer_scaling", lambda: volunteer_scaling.main(quick=reduced)),
        ("cluster_scaling", lambda: cluster_scaling.main(reduced)),
        ("classroom", lambda: classroom.main(reduced)),
        ("timeline", lambda: timeline.main(reduced)),
        ("sequential_baseline", lambda: sequential_baseline.main(reduced)),
        ("compression", lambda: compression.main(reduced)),
        ("dynamism", lambda: dynamism.main(reduced)),
        ("kernel_bench", lambda: kernel_bench.main(reduced)),
        ("roofline", lambda: roofline.main()),
        ("rebalance", lambda: rebalance.main(quick=reduced)),
        ("staleness", lambda: staleness.main(reduced)),
    ]
    failed = []
    for name, fn in suites:
        if args.only and name != args.only:
            continue
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            out = fn()
            if name in BENCH_RECORD_SUITES and out:
                write_bench_records(name, out)
            print(f"# {name}: ok in {time.time() - t0:.1f}s")
        except Exception:
            failed.append(name)
            traceback.print_exc()
            print(f"# {name}: FAILED")
    print(f"\n{len(suites) - len(failed)}/{len(suites)} benchmarks ok"
          + (f"; failed: {failed}" if failed else ""))
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
