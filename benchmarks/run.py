"""Run every benchmark (one per paper table/figure) in reduced mode.

  PYTHONPATH=src python -m benchmarks.run          # reduced (~minutes)
  PYTHONPATH=src python -m benchmarks.run --full   # paper-scale parameters

Artifacts covered:
  Fig. 4/5/6  cluster_scaling     runtime / relative speedup / efficiency
  Table 4     classroom           cluster vs classroom vs sequential + loss
  Fig. 7      timeline            per-volunteer task spans
  Fig. 8      sequential_baseline absolute speedup vs TFJS-Sequential-128/8
  §VI         compression         top-k / ternary wire bytes + convergence
  (kernels)   kernel_bench        us_per_call + roofline terms per Pallas kernel
  (applier)   applier_bench       server-apply updates/sec, single vs batched
  (roofline)  roofline            dry-run derived terms, if records exist
  (scale)     volunteer_scaling   event-driven vs polling at 1k/10k volunteers
  (elastic)   rebalance           live shard join/leave migration cost
  (policies)  staleness           makespan + loss vs aggregation policy
  (browser)   browser_scale       100k-1M volunteer session-trace sweeps
  (cluster)   multi_gateway       K-gateway throughput + kill -9 failover gap

Perf trajectory: suites that return record lists additionally write
``BENCH_<name>.json`` — a JSON list of records, each with the schema
``{name, params, makespan, events, bytes}`` — so successive PRs can diff
machine-readable performance, not just eyeball CSV.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time
import traceback

# suites whose return value is a list of perf records to persist
BENCH_RECORD_SUITES = ("volunteer_scaling", "rebalance", "staleness",
                       "browser_scale", "mc", "applier", "kernels",
                       "multi_gateway")

# the BENCH_<name>.json record schema: field -> accepted types. ``params`` is
# free-form by design (each suite names its own axes) but must be a dict;
# ``bytes``/``events`` may be null when a suite has no byte/event observable
# (e.g. rebalance measures wall time of a migration, not traffic).
RECORD_SCHEMA = {
    "name": (str,),
    "params": (dict,),
    "makespan": (int, float),
    "events": (int, type(None)),
    "bytes": (int, float, type(None)),
}


def write_bench_records(name: str, records) -> None:
    path = pathlib.Path(f"BENCH_{name}.json")
    path.write_text(json.dumps(records, indent=1, default=float) + "\n")
    print(f"# {name}: wrote {len(records)} perf records to {path}")


def check_bench_records(paths=None) -> int:
    """``--check``: validate every committed BENCH_*.json against the record
    schema, so a suite that drifts (renamed field, stringly-typed number,
    truncated write) fails CI instead of silently breaking the cross-PR perf
    trajectory. Returns the number of problems found."""
    paths = list(paths) if paths else sorted(pathlib.Path(".").glob("BENCH_*.json"))
    problems = 0

    def complain(msg: str):
        nonlocal problems
        problems += 1
        print(f"BENCH-CHECK FAIL: {msg}")

    if not paths:
        complain("no BENCH_*.json files found")
    # a record name is the key of one cross-PR perf series; the same name in
    # two files makes the trajectory ambiguous (which suite owns the series?)
    owners: dict = {}               # record name -> file that first used it
    reported_pairs = set()
    for path in paths:
        problems_before = problems
        try:
            records = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            complain(f"{path}: unreadable ({e})")
            continue
        if not isinstance(records, list) or not records:
            complain(f"{path}: expected a non-empty JSON list")
            continue
        expected_name = path.stem[len("BENCH_"):]
        for i, rec in enumerate(records):
            if not isinstance(rec, dict):
                complain(f"{path}[{i}]: record is not an object")
                continue
            for field, types in RECORD_SCHEMA.items():
                if field not in rec:
                    complain(f"{path}[{i}]: missing field {field!r}")
                elif not isinstance(rec[field], types) or \
                        isinstance(rec[field], bool):
                    complain(f"{path}[{i}].{field}: {type(rec[field]).__name__}"
                             f" is not one of {[t.__name__ for t in types]}")
            extra = set(rec) - set(RECORD_SCHEMA)
            if extra:
                complain(f"{path}[{i}]: unknown fields {sorted(extra)}")
            name = rec.get("name")
            if isinstance(name, str) and name != expected_name and \
                    not name.startswith(expected_name + "_"):
                complain(f"{path}[{i}]: name {name!r} does not belong to "
                         f"{expected_name!r}")
            if isinstance(name, str):
                first = owners.setdefault(name, path)
                if first != path and (name, str(path)) not in reported_pairs:
                    reported_pairs.add((name, str(path)))
                    complain(f"{path}[{i}]: record name {name!r} already "
                             f"used by {first} — every perf series must "
                             f"belong to exactly one suite file")
        print(f"# {path}: {len(records)} records ok"
              if problems == problems_before
              else f"# {path}: {problems - problems_before} problem(s)")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale parameters (slow on 1 CPU)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--check", action="store_true",
                    help="validate committed BENCH_*.json records against "
                         "the schema and exit (no benchmarks run)")
    args = ap.parse_args(argv)
    if args.check:
        problems = check_bench_records()
        print("# OK: all BENCH_*.json records match the schema"
              if problems == 0 else f"# {problems} schema problem(s)")
        return 1 if problems else 0
    reduced = not args.full

    from benchmarks import (applier_bench, browser_scale, classroom,
                            cluster_scaling, compression, dynamism,
                            kernel_bench, mc, multi_gateway, rebalance,
                            roofline, sequential_baseline, staleness,
                            timeline, volunteer_scaling)
    suites = [
        ("volunteer_scaling", lambda: volunteer_scaling.main(quick=reduced)),
        ("cluster_scaling", lambda: cluster_scaling.main(reduced)),
        ("classroom", lambda: classroom.main(reduced)),
        ("timeline", lambda: timeline.main(reduced)),
        ("sequential_baseline", lambda: sequential_baseline.main(reduced)),
        ("compression", lambda: compression.main(reduced)),
        ("dynamism", lambda: dynamism.main(reduced)),
        ("kernels", lambda: kernel_bench.main(quick=reduced)),
        ("applier", lambda: applier_bench.main(quick=reduced)),
        ("roofline", lambda: roofline.main()),
        ("rebalance", lambda: rebalance.main(quick=reduced)),
        ("staleness", lambda: staleness.main(reduced)),
        ("browser_scale", lambda: browser_scale.main(quick=reduced)),
        ("mc", lambda: mc.main(quick=reduced)),
        ("multi_gateway", lambda: multi_gateway.main(quick=reduced)),
    ]
    failed = []
    for name, fn in suites:
        if args.only and name != args.only:
            continue
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            out = fn()
            if name in BENCH_RECORD_SUITES and out:
                write_bench_records(name, out)
            print(f"# {name}: ok in {time.time() - t0:.1f}s")
        except Exception:
            failed.append(name)
            traceback.print_exc()
            print(f"# {name}: FAILED")
    print(f"\n{len(suites) - len(failed)}/{len(suites)} benchmarks ok"
          + (f"; failed: {failed}" if failed else ""))
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
