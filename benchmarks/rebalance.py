"""Elastic federation rebalance benchmark: cost of a live shard join/leave.

The consistent-hash contract is that a membership change remaps ~1/K of queue
names (K = post-change shard count for a join, pre-change count for a leave)
and leaves every other queue untouched. This benchmark loads a federation with
N live queues — pending backlogs AND leased in-flight messages with visibility
deadlines — then walks the membership up and back down, measuring for every
change:

- fraction of queue names migrated vs the 1.5/K acceptance bound,
- wall time of the rebalance (full live-state migration included),
- a conservation census (publishes/acks/depth/in-flight/pending bodies) that
  must be bit-identical across the change: a leave loses zero messages.

CSV: op,shards_before,shards_after,queues,moved,frac,bound,wall_ms

Usage: PYTHONPATH=src python benchmarks/rebalance.py [--quick] [--queues N]
"""
from __future__ import annotations

import argparse
import time

from repro.core.chaos import federation_census
from repro.core.queue import ShardedQueueServer


def build_federation(k: int, n_queues: int) -> ShardedQueueServer:
    fed = ShardedQueueServer(k, default_timeout=30.0)
    for i in range(n_queues):
        name = f"queue-{i:05d}"
        fed.publish(name, f"{i}-a")
        fed.publish(name, f"{i}-b")
        if i % 2 == 0:                       # half the queues hold a live lease
            fed.lease(name, f"w{i % 17}", now=float(i % 9))
    return fed


def main(quick: bool = False, queues: int = 0) -> None:
    n = queues or (2_000 if quick else 20_000)
    k0, k_max = 4, (6 if quick else 10)
    fed = build_federation(k0, n)
    print("op,shards_before,shards_after,queues,moved,frac,bound,wall_ms")
    worst = 0.0
    records = []
    plan = [("join", None)] * (k_max - k0) + \
           [("leave", i % 3) for i in range(k_max - k0 + 1)]
    for op, arg in plan:
        k_before = len(fed.shards)
        before = federation_census(fed)
        t0 = time.perf_counter()
        if op == "join":
            moved = fed.add_shard()
        else:
            moved = fed.remove_shard(arg % k_before)
        wall_ms = (time.perf_counter() - t0) * 1e3
        k_after = len(fed.shards)
        k_bound = k_after if op == "join" else k_before
        frac, bound = len(moved) / n, 1.5 / k_bound
        worst = max(worst, frac * k_bound)
        print(f"rebalance_{op},{k_before},{k_after},{n},{len(moved)},"
              f"{frac:.4f},{bound:.4f},{wall_ms:.1f}")
        records.append({"name": f"rebalance_{op}",
                        "params": {"shards_before": k_before,
                                   "shards_after": k_after, "queues": n,
                                   "moved_frac": round(frac, 4)},
                        "makespan": wall_ms / 1e3,
                        "events": len(moved), "bytes": None})
        assert frac <= bound, \
            f"{op}: moved {frac:.3f} of names, above the {bound:.3f} bound"
        assert federation_census(fed) == before, \
            f"{op}: rebalance changed live queue state"
        for q in fed.queues.values():
            q.check_invariants()
    print(f"# OK: every membership change moved <= {worst:.2f}/K of {n} "
          f"queue names (bound 1.5/K), conserved all live state, and kept "
          f"per-queue invariants")
    return records


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="2k queues, 4->6 shards (CI smoke)")
    ap.add_argument("--queues", type=int, default=0,
                    help="override queue count")
    main(**vars(ap.parse_args()))
