"""Per-kernel microbenchmarks: us_per_call (interpret-mode CPU — structural,
not TPU wall-clock) + oracle agreement + roofline-derived terms.

Two sizes: the default shapes exercise the kernels at meaningful extents
(flash_attention at S=256 runs ~0.8s/call in interpret mode — fine offline,
too slow for a CI leg), and ``--quick`` shrinks every kernel to CI scale.
Records land in ``BENCH_kernels.json`` via ``benchmarks.run``.

CSV: name,us_per_call,derived
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from benchmarks.roofline import derive
from repro.kernels import ops, ref


def _time(fn, *args, iters=3):
    fn(*args)                                   # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def _record(name, us, flops, bytes_moved, shape, maxerr=None, **extra):
    params = {"shape": shape, "us_per_call": round(us, 1)}
    if maxerr is not None:
        params["maxerr"] = float(maxerr)
    params.update({k: (round(v, 6) if isinstance(v, float) else v)
                   for k, v in derive(flops, bytes_moved).items()})
    params.update(extra)
    return {"name": f"kernels_{name}", "params": params,
            "makespan": us / 1e6, "events": 1, "bytes": int(bytes_moved)}


def main(quick: bool = False):
    k = jax.random.PRNGKey(0)
    rows = []
    records = []

    # the paper's layer-1 cell is small enough to run at full shape always
    B, Din, H = (8, 98, 50) if quick else (32, 98, 50)
    x = jax.random.normal(k, (B, Din))
    h = jax.random.normal(k, (B, H))
    c = jax.random.normal(k, (B, H))
    W = jax.random.normal(k, (Din + H, 4 * H)) * 0.1
    b = jnp.zeros((4 * H,))
    us = _time(lambda *a: ops.lstm_cell(*a, interpret=True), x, h, c, W, b)
    flops = 2 * B * (Din + H) * 4 * H
    moved = sum(a.nbytes for a in (x, h, c, W, b)) + 2 * h.nbytes
    err = float(jnp.abs(ops.lstm_cell(x, h, c, W, b, interpret=True)[0]
                        - ref.lstm_cell(x, h, c, W, b)[0]).max())
    rows.append(("lstm_cell", us, f"flops={flops};maxerr={err:.1e}"))
    records.append(_record("lstm_cell", us, flops, moved,
                           f"B{B}xD{Din}xH{H}", err))

    S, Hh, Kv, hd = (128, 4, 2, 32) if quick else (256, 8, 4, 64)
    q = jax.random.normal(k, (1, S, Hh, hd)) * 0.5
    kk = jax.random.normal(k, (1, S, Kv, hd)) * 0.5
    vv = jax.random.normal(k, (1, S, Kv, hd)) * 0.5
    us = _time(lambda *a: ops.flash_attention(*a, interpret=True), q, kk, vv,
               iters=1)
    flops = 4 * S * S * Hh * hd // 2            # causal half
    moved = q.nbytes + kk.nbytes + vv.nbytes + q.nbytes
    err = float(jnp.abs(ops.flash_attention(q, kk, vv, interpret=True)
                        - ref.flash_attention(q, kk, vv)).max())
    rows.append(("flash_attention", us, f"flops={flops};maxerr={err:.1e}"))
    records.append(_record("flash_attention", us, flops, moved,
                           f"S{S}xH{Hh}xKV{Kv}xhd{hd}", err))

    R, C = (512, 256) if quick else (4096, 1024)
    xx = jax.random.normal(k, (R, C))
    sc = jnp.ones((C,))
    us = _time(lambda *a: ops.rmsnorm(*a, interpret=True), xx, sc)
    moved = xx.nbytes * 2
    err = float(jnp.abs(ops.rmsnorm(xx, sc, interpret=True)
                        - ref.rmsnorm(xx, sc)).max())
    rows.append(("rmsnorm", us, f"bytes={moved};maxerr={err:.1e}"))
    records.append(_record("rmsnorm", us, 4 * R * C, moved, f"{R}x{C}", err))

    n = (1 << 14) if quick else (1 << 16)
    g = jax.random.normal(k, (n,))
    s = jnp.max(jnp.abs(g))
    us = _time(lambda *a: ops.ternary_encode(*a, interpret=True), g, s)
    packed = ops.ternary_encode(g, s, interpret=True)
    rows.append(("ternary_encode", us,
                 f"in={g.nbytes};out={packed.nbytes};"
                 f"ratio={g.nbytes / packed.nbytes:.0f}x"))
    records.append(_record("ternary_encode", us, 2 * n,
                           g.nbytes + packed.nbytes, f"n{n}",
                           ratio=round(g.nbytes / packed.nbytes, 1)))

    print("name,us_per_call,derived")
    for name, us, derived_s in rows:
        print(f"{name},{us:.0f},{derived_s}")
    return records


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI-scale shapes for every kernel")
    args = ap.parse_args()
    main(quick=args.quick)
