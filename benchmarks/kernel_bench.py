"""Per-kernel microbenchmarks: us_per_call (interpret-mode CPU — structural,
not TPU wall-clock) + derived FLOPs and oracle agreement.

CSV: name,us_per_call,derived
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, iters=3):
    fn(*args)                                   # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def main(reduced: bool = True):
    k = jax.random.PRNGKey(0)
    rows = []

    B, Din, H = (32, 98, 50)                    # the paper's layer-1 cell
    x = jax.random.normal(k, (B, Din))
    h = jax.random.normal(k, (B, H))
    c = jax.random.normal(k, (B, H))
    W = jax.random.normal(k, (Din + H, 4 * H)) * 0.1
    b = jnp.zeros((4 * H,))
    us = _time(lambda *a: ops.lstm_cell(*a, interpret=True), x, h, c, W, b)
    flops = 2 * B * (Din + H) * 4 * H
    err = float(jnp.abs(ops.lstm_cell(x, h, c, W, b, interpret=True)[0]
                        - ref.lstm_cell(x, h, c, W, b)[0]).max())
    rows.append(("lstm_cell", us, f"flops={flops};maxerr={err:.1e}"))

    S, Hh, Kv, hd = (256, 8, 4, 64) if reduced else (1024, 16, 8, 128)
    q = jax.random.normal(k, (1, S, Hh, hd)) * 0.5
    kk = jax.random.normal(k, (1, S, Kv, hd)) * 0.5
    vv = jax.random.normal(k, (1, S, Kv, hd)) * 0.5
    us = _time(lambda *a: ops.flash_attention(*a, interpret=True), q, kk, vv,
               iters=1)
    flops = 4 * S * S * Hh * hd // 2            # causal half
    err = float(jnp.abs(ops.flash_attention(q, kk, vv, interpret=True)
                        - ref.flash_attention(q, kk, vv)).max())
    rows.append(("flash_attention", us, f"flops={flops};maxerr={err:.1e}"))

    xx = jax.random.normal(k, (4096, 1024))
    sc = jnp.ones((1024,))
    us = _time(lambda *a: ops.rmsnorm(*a, interpret=True), xx, sc)
    err = float(jnp.abs(ops.rmsnorm(xx, sc, interpret=True)
                        - ref.rmsnorm(xx, sc)).max())
    rows.append(("rmsnorm", us, f"bytes={xx.nbytes * 2};maxerr={err:.1e}"))

    g = jax.random.normal(k, (1 << 16,))
    s = jnp.max(jnp.abs(g))
    us = _time(lambda *a: ops.ternary_encode(*a, interpret=True), g, s)
    packed = ops.ternary_encode(g, s, interpret=True)
    rows.append(("ternary_encode", us,
                 f"in={g.nbytes};out={packed.nbytes};"
                 f"ratio={g.nbytes / packed.nbytes:.0f}x"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
    return rows


if __name__ == "__main__":
    main(reduced=False)
