"""Paper Fig. 7 — per-volunteer task timeline (Compute / Accumulate spans)
for the 32-volunteer sync-start classroom run.

CSV: name,volunteer,kind,start_s,end_s,version
Also prints an ASCII strip chart and checks the paper's "tasks are evenly
distributed" observation (no volunteer starves).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import classroom_cost, paper_problem, simulate


def run(reduced: bool = True, k: int = 32):
    problem = paper_problem(reduced=reduced)
    res = simulate(problem, k, cost=classroom_cost(problem))
    return res


def main(reduced: bool = True, k: int = 32, emit_rows: int = 40):
    res = run(reduced, k)
    print("name,volunteer,kind,start_s,end_s,version")
    for ev in res.timeline[:emit_rows]:
        print(f"timeline,{ev.vid},{ev.kind},{ev.start:.2f},{ev.end:.2f},"
              f"{ev.version}")
    if len(res.timeline) > emit_rows:
        print(f"# ... {len(res.timeline) - emit_rows} more spans")

    # ASCII strip chart (10 volunteers x 60 cols)
    T = res.makespan
    vids = sorted(res.tasks_by_worker)[:10]
    for vid in vids:
        row = [" "] * 60
        for ev in res.timeline:
            if ev.vid != vid:
                continue
            a = int(ev.start / T * 59)
            b = max(int(ev.end / T * 59), a)
            ch = "#" if ev.kind == "Compute" else "R"
            for i in range(a, min(b + 1, 60)):
                row[i] = ch
        print(f"# {vid} |{''.join(row)}|")

    counts = np.array(list(res.tasks_by_worker.values()))
    print(f"# tasks/volunteer: min={counts.min()} max={counts.max()} "
          f"mean={counts.mean():.1f}")
    assert counts.min() > 0, "a volunteer starved"
    return res


if __name__ == "__main__":
    main(reduced=False)
