"""Roofline table (deliverable g) — reads dry-run JSONL records and prints
the per-(arch x shape x mesh) three-term roofline with the dominant term.

CSV: name,arch,shape,mesh,t_compute,t_memory,t_collective,bottleneck,
     useful_fraction,temp_gib
"""
from __future__ import annotations

import json
import pathlib
import sys

DEFAULT_PATH = pathlib.Path(__file__).resolve().parents[1] / "results" / \
    "dryrun_baseline.jsonl"

# Nominal single-core host peaks for turning a kernel's counted flops/bytes
# into the same three-term split the dry-run records carry. Structural
# numbers (which term dominates, at what intensity), not TPU wall-clock.
HOST_FLOPS_PER_SEC = 5.0e9
HOST_BYTES_PER_SEC = 1.0e10


def derive(flops: float, bytes_moved: float, *,
           flops_per_sec: float = HOST_FLOPS_PER_SEC,
           bytes_per_sec: float = HOST_BYTES_PER_SEC) -> dict:
    """Roofline terms for one kernel: compute time, memory time, which of
    the two binds, and arithmetic intensity (flops/byte)."""
    t_compute = flops / flops_per_sec
    t_memory = bytes_moved / bytes_per_sec
    return {"t_compute": t_compute, "t_memory": t_memory,
            "bottleneck": "compute" if t_compute >= t_memory else "memory",
            "intensity": flops / max(bytes_moved, 1.0)}


def load(path=DEFAULT_PATH):
    recs = []
    p = pathlib.Path(path)
    if not p.exists():
        return recs
    for line in p.read_text().splitlines():
        if line.strip():
            recs.append(json.loads(line))
    return recs


def main(path=DEFAULT_PATH):
    recs = load(path)
    if not recs:
        print(f"# no dry-run records at {path}; run:")
        print("#   PYTHONPATH=src python -m repro.launch.dryrun --all "
              "--mesh single --out results/dryrun_baseline.jsonl")
        return []
    print("name,arch,shape,mesh,t_compute,t_memory,t_collective,"
          "bottleneck,useful_fraction,temp_gib")
    for r in recs:
        if "error" in r:
            print(f"roofline,{r['arch']},{r['shape']},{r['mesh']},"
                  f"ERROR,{r['error'][:60]},,,,")
            continue
        print(f"roofline,{r['arch']},{r['shape']},{r['mesh']},"
              f"{r['t_compute']:.3e},{r['t_memory']:.3e},"
              f"{r['t_collective']:.3e},{r['bottleneck']},"
              f"{r.get('useful_fraction', 0):.3f},"
              f"{r['memory']['temp_size_in_bytes'] / 2**30:.2f}")
    return recs


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else DEFAULT_PATH)
