"""Paper Fig. 8 / Table 4 sequential rows — absolute speedup of distributed
JSDoop vs TFJS-Sequential-128 and TFJS-Sequential-8.

The sequential baseline ran in ONE browser on a (fast, WebGL) machine with
no queue/network cost; its per-step time is dispatch-overhead dominated,
which is why the paper's Sequential-8 (16x more optimizer steps) is ~24x
slower than Sequential-128 despite identical total FLOPs.

CSV: name,reference,workers,runtime_min,abs_speedup
"""
from __future__ import annotations

from benchmarks.common import classroom_cost, fmt_minutes, paper_problem, simulate

SEQ_THROUGHPUT = 6.0e9     # WebGL-accelerated browser (vs 3.5e7 JS cluster node)
SEQ_STEP_OVERHEAD = 0.95   # per-optimizer-step JS/WebGL dispatch (s)


def sequential_time(problem, batch_size: int) -> float:
    tp = problem.tp
    steps = problem.n_versions * (tp.batch_size // batch_size)
    flops_grad = problem.flops_per_map() / tp.mini_batch_size * batch_size
    return steps * (SEQ_STEP_OVERHEAD + flops_grad / SEQ_THROUGHPUT)


def main(reduced: bool = True):
    problem = paper_problem(reduced=reduced)
    cost = classroom_cost(problem)
    t128 = sequential_time(problem, problem.tp.batch_size)
    t8 = sequential_time(problem, problem.tp.mini_batch_size)
    print(f"# TFJS-Sequential-{problem.tp.batch_size}: {fmt_minutes(t128)} min"
          f" ; TFJS-Sequential-{problem.tp.mini_batch_size}: "
          f"{fmt_minutes(t8)} min")
    print("name,reference,workers,runtime_min,abs_speedup")
    rows = []
    for k in (1, 2, 4, 8, 16, 32):
        res = simulate(problem, k, cost=cost)
        for ref_name, tref in ((f"seq{problem.tp.batch_size}", t128),
                               (f"seq{problem.tp.mini_batch_size}", t8)):
            s = tref / res.makespan
            rows.append((ref_name, k, fmt_minutes(res.makespan), round(s, 2)))
            print(f"sequential_baseline,{ref_name},{k},"
                  f"{fmt_minutes(res.makespan)},{round(s, 2)}")
    # paper qualitative claims (Fig. 8): distributed-32 beats Sequential-8
    # by a wide margin; absolute speedup vs Sequential-128 stays sublinear.
    seq8 = f"seq{problem.tp.mini_batch_size}"
    seq128 = f"seq{problem.tp.batch_size}"
    by = {(r[0], r[1]): r[3] for r in rows}
    assert by[(seq8, 32)] > by[(seq8, 1)], "scaling must help vs seq-8"
    assert by[(seq128, 32)] < 32, "absolute speedup must be sublinear"
    assert t8 > t128, "small-batch sequential must be slower (Table 4)"
    return rows


if __name__ == "__main__":
    main(reduced=False)
