"""Paper Table 4 — cluster vs classroom (sync/async start) vs sequential,
plus the loss column from REAL execution (the invariance result).

CSV: name,system,workers,runtime_min,loss
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (classroom_cost, cluster_cost, fmt_minutes,
                               paper_problem, simulate)
from repro.core.coordinator import Coordinator
from repro.core.mapreduce import sequential_accumulated, sequential_fullbatch


def timing_rows(reduced: bool = True):
    problem = paper_problem(reduced=reduced)
    cl, cr = cluster_cost(problem), classroom_cost(problem)
    rows = []
    for k in (1, 2, 4, 8, 16, 32):
        res = simulate(problem, k, cost=cl)
        rows.append(("JSDoop-cluster", k, fmt_minutes(res.makespan)))
    res = simulate(problem, 16, cost=cr)
    rows.append(("JSDoop-classroom-sync-start", 16, fmt_minutes(res.makespan)))
    res = simulate(problem, 32, cost=cr)
    rows.append(("JSDoop-classroom-sync-start", 32, fmt_minutes(res.makespan)))
    # async-start: volunteers trickle in over the first minute (paper scen. 1)
    joins = [3.0 * i for i in range(32)]
    res = simulate(problem, 32, cost=cr, joins=joins)
    rows.append(("JSDoop-classroom-async-start", 32, fmt_minutes(res.makespan)))
    return rows


def loss_rows(reduced: bool = True):
    """REAL training: the loss is identical for every worker count (Table 4),
    and differs for the mini-batch-8 sequential variant."""
    problem = paper_problem(reduced=reduced)
    _, _, losses_seq = sequential_accumulated(problem)
    out = [("sequential-accumulated", 1, round(losses_seq[-1], 3))]
    for k in (2, 5):
        res = Coordinator(problem, n_workers=k).run()
        out.append((f"coordinator-k{k}", k, round(res.losses[-1], 3)))
    _, _, losses_8 = sequential_fullbatch(
        problem, batch_size=problem.tp.mini_batch_size)
    out.append((f"sequential-mb{problem.tp.mini_batch_size}", 1,
                round(float(np.mean(losses_8[-4:])), 3)))
    return out


def main(reduced: bool = True):
    print("name,system,workers,runtime_min")
    rows = timing_rows(reduced)
    for sys_, k, t in rows:
        print(f"classroom,{sys_},{k},{t}")
    print("name,system,workers,final_loss")
    lrows = loss_rows(reduced)
    for sys_, k, l in lrows:
        print(f"classroom_loss,{sys_},{k},{l}")
    # invariance: every distributed loss equals the sequential-accumulated one
    base = lrows[0][2]
    for sys_, k, l in lrows[1:-1]:
        assert l == base, (sys_, l, base)
    return rows, lrows


if __name__ == "__main__":
    main(reduced=False)
