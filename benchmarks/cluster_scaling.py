"""Paper Figs. 4/5/6 — runtime, relative speedup, relative efficiency on the
cluster, K in {1,2,4,8,16,32}.

CSV: name,workers,runtime_min,speedup,efficiency
"""
from __future__ import annotations

from benchmarks.common import cluster_cost, fmt_minutes, paper_problem, simulate


def run(reduced: bool = True):
    problem = paper_problem(reduced=reduced)
    cost = cluster_cost(problem)
    rows = []
    t1 = None
    for k in (1, 2, 4, 8, 16, 32):
        res = simulate(problem, k, cost=cost)
        if t1 is None:
            t1 = res.makespan
        speedup = t1 / res.makespan
        rows.append(dict(workers=k, runtime_min=fmt_minutes(res.makespan),
                         speedup=round(speedup, 2),
                         efficiency=round(speedup / k, 2)))
    return rows


def main(reduced: bool = True):
    rows = run(reduced)
    print("name,workers,runtime_min,speedup,efficiency")
    for r in rows:
        print(f"cluster_scaling,{r['workers']},{r['runtime_min']},"
              f"{r['speedup']},{r['efficiency']}")
    # the paper's qualitative claims
    by_k = {r["workers"]: r for r in rows}
    assert by_k[2]["efficiency"] > 1.0, "superlinear regime lost (Fig. 5)"
    assert by_k[16]["speedup"] > by_k[2]["speedup"]
    assert by_k[32]["speedup"] < 2 * by_k[16]["speedup"], \
        "32 workers must saturate (16-way reduce barrier)"
    return rows


if __name__ == "__main__":
    main(reduced=False)
