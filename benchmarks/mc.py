"""Model-checker throughput benchmark: states/sec and reduction factor.

One record per CI policy world (the same three ``repro.analysis.mc``
explores in the MC CI leg), exploring under a fixed state/time budget and
reporting what the exhaustive-search machinery actually achieved: states
stored, transitions executed, dedup + partial-order savings (the reduction
factor), search depth, and raw states/sec. Successive PRs diff these in
``BENCH_mc.json`` — a protocol change that silently explodes the state
space, or an optimization that regresses throughput, shows up as a record
delta rather than a mysteriously slower CI leg.

CSV: name,policy,states,transitions,states_per_sec,depth,reduction,truncated

Usage: PYTHONPATH=src python benchmarks/mc.py [--quick]
"""
from __future__ import annotations

import argparse
import time

from repro.analysis.mc import DEFAULT_POLICIES, default_config, explore

HEADER = ("name,policy,states,transitions,states_per_sec,depth,"
          "reduction,truncated")


def run_point(policy: str, *, max_states: int, max_depth: int,
              max_seconds: float) -> dict:
    cfg = default_config(policy)
    t0 = time.time()
    report = explore(cfg, max_states=max_states, max_depth=max_depth,
                     max_seconds=max_seconds, first_violation=False)
    wall = time.time() - t0
    s = report.stats
    assert report.ok, [v.invariant for v in report.violations]
    label = policy.replace(":", "").replace(".", "")
    return {
        "name": f"mc_{label}",
        "params": {
            "policy": policy,
            "n_volunteers": cfg.n_volunteers,
            "max_states": max_states,
            "max_depth": max_depth,
            "states": s.states,
            "transitions": s.transitions,
            "dedup_hits": s.dedup_hits,
            "symmetry_hits": s.symmetry_hits,
            "por_skipped": s.por_skipped,
            "states_per_sec": round(s.states_per_sec, 1),
            "depth": s.max_depth,
            "reduction_factor": round(s.reduction_factor, 2),
            "truncated": int(s.truncated),
        },
        "makespan": round(wall, 3),
        "events": s.states,
        "bytes": None,
    }


def main(quick: bool = True):
    budget = dict(max_states=2000 if quick else 20000,
                  max_depth=24 if quick else 50,
                  max_seconds=6.0 if quick else 60.0)
    print(HEADER)
    records = []
    for policy in DEFAULT_POLICIES:
        rec = run_point(policy, **budget)
        p = rec["params"]
        print(f"{rec['name']},{policy},{p['states']},{p['transitions']},"
              f"{p['states_per_sec']},{p['depth']},{p['reduction_factor']},"
              f"{p['truncated']}")
        records.append(rec)
    return records


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(quick=args.quick)
