"""End-to-end integration: drivers, examples and a small-mesh dry-run.

The 512-device production dry-run runs out of process (XLA_FLAGS must be set
before jax init); here we exercise the identical lower+compile+analyze path
on a small faked mesh in a subprocess.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import pathlib

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
ENV = {**os.environ, "PYTHONPATH": str(ROOT / "src")}


def _run(args, timeout=480, env=None):
    return subprocess.run([sys.executable] + args, cwd=ROOT, env=env or ENV,
                          capture_output=True, text=True, timeout=timeout)


def test_train_driver_paper_mode():
    r = _run(["-m", "repro.launch.train", "--paper", "--workers", "2",
              "--versions", "2"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "done v2" in r.stdout


def test_train_driver_arch_mode():
    r = _run(["-m", "repro.launch.train", "--arch", "deepseek-moe-16b",
              "--steps", "3", "--batch", "4", "--seq", "32"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "loss" in r.stdout


def test_serve_driver():
    r = _run(["-m", "repro.launch.serve", "--arch", "whisper-base",
              "--requests", "2", "--batch", "2", "--prompt", "8",
              "--tokens", "4"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "tok/s" in r.stdout


def test_quickstart_example():
    r = _run(["examples/quickstart.py"], timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "BIT-IDENTICAL" in r.stdout


def test_classroom_example():
    r = _run(["examples/classroom_simulation.py"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "training completed despite churn" in r.stdout


def test_dryrun_small_mesh_subprocess(tmp_path):
    """The dry-run path on a faked 4x4 mesh: must lower, compile and emit
    roofline terms for a dense and an SSM arch."""
    out = tmp_path / "rec.jsonl"
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import json, jax
from repro.launch import dryrun as DR
from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((4, 4), ("data", "model"))
for arch, shape in [("stablelm-1.6b", "train_4k"),
                    ("falcon-mamba-7b", "decode_32k")]:
    rec = DR.lower_one(arch, shape, mesh)
    with open({str(out)!r}, "a") as f:
        f.write(json.dumps(rec) + "\\n")
print("DRYRUN_OK")
"""
    r = _run(["-c", code], timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "DRYRUN_OK" in r.stdout
    recs = [json.loads(l) for l in out.read_text().splitlines()]
    assert len(recs) == 2
    for rec in recs:
        assert rec["flops_per_device"] > 0
        assert rec["bottleneck"] in ("compute", "memory", "collective")
        assert rec["memory"]["temp_size_in_bytes"] >= 0
    train = recs[0]
    # useful fraction must be sane (remat <=1, >0.05)
    assert 0.05 < train["useful_fraction"] <= 1.2, train["useful_fraction"]
