"""Checkpoint store + wire serialization (the durable DataServer)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.serialize import dumps, loads
from repro.checkpoint.store import CheckpointStore


def _tree():
    return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": {"b": jnp.full((2, 2), 1.5, jnp.bfloat16),
                       "i": jnp.arange(3, dtype=jnp.int32)},
            "meta": "hello", "n": 7}


def test_serialize_roundtrip_dtypes():
    t = _tree()
    t2 = loads(dumps(t))
    assert t2["meta"] == "hello" and t2["n"] == 7
    np.testing.assert_array_equal(np.asarray(t["w"]), t2["w"])
    assert np.asarray(t2["nested"]["b"]).dtype == np.asarray(
        t["nested"]["b"]).dtype
    np.testing.assert_array_equal(
        np.asarray(t["nested"]["b"], np.float32),
        np.asarray(t2["nested"]["b"], np.float32))


def test_serialize_compression_smaller_on_redundant_data():
    big = {"w": jnp.zeros((1000, 100), jnp.float32)}
    assert len(dumps(big)) < len(dumps(big, compress=False)) / 10


def test_serialize_codec_recorded_in_header():
    """The zlib fallback works without zstandard and the header byte lets the
    reader pick the right decoder."""
    t = _tree()
    blob = dumps(t, codec="zlib")
    assert blob[:1] == b"D"
    t2 = loads(blob)
    np.testing.assert_array_equal(np.asarray(t["w"]), t2["w"])
    assert loads(dumps(t, compress=False))["n"] == 7
    assert dumps(t, compress=False)[:1] == b"R"


def test_store_versions_and_retention(tmp_path):
    st = CheckpointStore(str(tmp_path), keep=2)
    for v in range(1, 5):
        st.save(v, {"x": jnp.full((3,), float(v))}, meta={"step": v * 10})
    assert st.versions() == [3, 4]
    assert st.latest() == 4
    tree, meta = st.load(4)
    assert meta["step"] == 40
    np.testing.assert_array_equal(tree["x"], np.full((3,), 4.0))


def test_store_resume_cycle(tmp_path):
    """save -> load -> keep training: the paper's availability story."""
    import repro.configs as C
    from repro.models import model as M
    from repro.models.runtime import Runtime
    from repro.optim import make as make_opt
    cfg = C.get_smoke("stablelm-1.6b").replace(dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = make_opt("sgd", 0.1)
    state = opt.init(params)
    st = CheckpointStore(str(tmp_path))
    st.save(1, {"params": params, "opt": state})
    tree, _ = st.load(1)
    rt = Runtime(remat=False)
    batch = {"tokens": jnp.zeros((2, 9), jnp.int32)}
    l1, _ = M.loss_fn(params, cfg, rt, batch)
    # restored params produce the identical loss
    restored = jax.tree.map(jnp.asarray, tree["params"])
    l2, _ = M.loss_fn(restored, cfg, rt, batch)
    assert float(l1) == float(l2)
