"""repro.analysis.mc — the model checker itself: exhaustive clean runs,
seeded-bug rediscovery with shrunk bit-deterministic counterexamples,
capture/restore soundness, symmetry/dedup fingerprints, and the honesty of
the COVERED_MESSAGES wire-coverage ledger."""
import importlib.util
import json
import pathlib

import pytest

from repro.analysis.mc import (COVERED_MESSAGES, DEADLOCK, DEFAULT_INVARIANTS,
                               GatewayMCConfig, Invariant, MCConfig, MCWorld,
                               check_all, explore, fingerprint, replay,
                               replay_payload, repro_payload, repro_script,
                               shrink)
from repro.core.chaos import replay_mc_trace

ROOT = pathlib.Path(__file__).resolve().parents[1]
MC_FIXTURES = ROOT / "tests" / "fixtures" / "analysis" / "mc"


def _fixture(name: str):
    p = MC_FIXTURES / f"{name}.py"
    spec = importlib.util.spec_from_file_location(name, p)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


TINY = MCConfig(policy="sync", n_volunteers=2, n_versions=1, n_mb=2,
                visibility_timeout=10.0)


# ---------------------------------------------------------------------------
# exhaustive clean exploration
# ---------------------------------------------------------------------------

def test_tiny_sync_world_explores_exhaustively_clean():
    # unbounded expiry makes every world inexhaustible (expire/re-lease
    # cycles never dedup); a finite expiry budget turns the tiny world into
    # a genuinely exhaustive search
    cfg = MCConfig.from_json({**TINY.to_json(), "max_expiries": 1})
    report = explore(cfg, max_states=50000, max_depth=60, max_seconds=60.0)
    s = report.stats
    assert report.ok, report.violations
    assert not s.truncated, "tiny world must exhaust, not truncate"
    assert s.completes > 0, "no interleaving reached the version target"
    assert s.dedup_hits > 0, "dedup never fired on a converging lattice"


def test_expiry_budget_gates_the_expire_fault():
    cfg = MCConfig.from_json({**TINY.to_json(), "max_expiries": 0})
    world = MCWorld(cfg)
    world.apply(("lease", "w0"))
    assert world.qs.next_deadline() is not None
    assert ("expire",) not in world.enabled_actions()
    unbounded = MCWorld(TINY)
    unbounded.apply(("lease", "w0"))
    assert ("expire",) in unbounded.enabled_actions()


def test_explore_reports_por_savings_with_faults():
    cfg = MCConfig(policy="sync", n_volunteers=2, n_versions=1, n_mb=2,
                   visibility_timeout=10.0, max_drops=1, max_dups=1)
    report = explore(cfg, max_states=1500, max_depth=30, max_seconds=8.0)
    assert report.ok, report.violations
    assert report.stats.reduction_factor > 1.0


# ---------------------------------------------------------------------------
# seeded historical bugs: rediscovery + shrunk replayable counterexamples
# ---------------------------------------------------------------------------

def test_stepaside_deadlock_rediscovered_and_fix_is_clean():
    fx = _fixture("stepaside_deadlock")
    cfg = fx.configure()
    report = explore(cfg, **fx.BUDGET)
    assert [v.invariant for v in report.violations] == [DEADLOCK]
    # the shipped engines' behavior (step-aside release) explores clean
    # under the same bounded budget
    fixed = MCConfig.from_json({**cfg.to_json(), "allow_release": True})
    ok = explore(fixed, max_states=2500, max_depth=16, max_seconds=8.0)
    assert ok.violations == []


def test_stale_admission_rediscovered_and_honest_policy_is_clean():
    fx = _fixture("stale_admission")
    cfg = fx.configure()
    report = explore(cfg, **fx.BUDGET)
    assert [v.invariant for v in report.violations] == ["admission-soundness"]
    assert "exceeds the declared bound 1" in report.violations[0].message
    honest = MCConfig.from_json(cfg.to_json())      # policy_object dropped
    ok = explore(honest, max_states=2500, max_depth=24, max_seconds=8.0)
    assert ok.violations == []


@pytest.mark.parametrize("name", ["stepaside_deadlock", "stale_admission",
                                  "gateway_fsync_drop"])
def test_shrunk_counterexample_replays_bit_deterministically(name):
    fx = _fixture(name)
    cfg = fx.configure()
    report = explore(cfg, **fx.BUDGET)
    v = report.violations[0]
    small = shrink(cfg, v.trace, v.invariant)
    assert 0 < len(small) <= len(v.trace)
    # 1-minimality: dropping any single remaining action loses the violation
    for i in range(len(small)):
        cand = small[:i] + small[i + 1:]
        assert replay(cfg, cand).invariant != v.invariant, i
    # bit-determinism: two replays agree on violation, step, AND final state
    r1 = replay(cfg, small)
    r2 = replay(cfg, small)
    assert r1.invariant == v.invariant
    assert (r1.step, r1.final_fingerprint) == (r2.step, r2.final_fingerprint)
    # ...and through the chaos harness entry point, from the JSON payload
    payload = repro_payload(cfg, small, v.invariant, v.message,
                            fixture=str(MC_FIXTURES / f"{name}.py"))
    payload = json.loads(json.dumps(payload))       # a real wire round-trip
    r3 = replay_mc_trace(payload)
    assert r3.invariant == v.invariant
    assert r3.final_fingerprint == r1.final_fingerprint
    script = repro_script(payload)
    assert "replay_mc_trace" in script
    assert v.invariant in script


# ---------------------------------------------------------------------------
# capture/restore and fingerprints
# ---------------------------------------------------------------------------

def test_capture_restore_roundtrips_fingerprint():
    world = MCWorld(TINY)
    world.apply(("lease", "w0"))
    world.apply(("advance", "w0"))
    cap = world.capture()
    fp = fingerprint(world)
    world.apply(("lease", "w1"))
    world.apply(("expire",))
    assert fingerprint(world) != fp
    world.restore(cap)
    assert fingerprint(world) == fp
    assert check_all(world, DEFAULT_INVARIANTS) is None


def test_symmetric_volunteers_merge_under_relabeling():
    w1 = MCWorld(TINY)
    w2 = MCWorld(TINY)
    w1.apply(("lease", "w0"))
    w2.apply(("lease", "w1"))
    # w0 and w1 are interchangeable in TINY: leasing with either must land
    # on the same canonical state
    assert TINY.crashable == () and TINY.leavable == ()
    assert fingerprint(w1) == fingerprint(w2)


def test_asymmetric_volunteers_do_not_merge():
    cfg = MCConfig(policy="sync", n_volunteers=2, n_versions=1, n_mb=2,
                   visibility_timeout=10.0, crashable=("w0",), max_crashes=1)
    w1 = MCWorld(cfg)
    w2 = MCWorld(cfg)
    assert not w1.symmetry_possible()
    w1.apply(("lease", "w0"))
    w2.apply(("lease", "w1"))
    assert fingerprint(w1) != fingerprint(w2)


# ---------------------------------------------------------------------------
# invariant API
# ---------------------------------------------------------------------------

def test_invariant_api_verdict_forms():
    good = Invariant("ok", lambda w: None)
    also_good = Invariant("ok2", lambda w: True)
    bad_msg = Invariant("bad", lambda w: "broke")
    bad_bool = Invariant("bad2", lambda w: False)
    world = MCWorld(TINY)
    assert good.check(world) is None and also_good.check(world) is None
    assert bad_msg.check(world) == "broke"
    assert bad_bool.check(world) == "bad2 violated"
    assert check_all(world, [good, bad_msg]) == ("bad", "broke")
    assert check_all(world, DEFAULT_INVARIANTS) is None


def test_custom_invariant_violation_carries_trace():
    # a predicate that fails once any volunteer computes: the trace must be
    # exactly the actions that got there, and replay must agree
    inv = Invariant("no-compute", lambda w: not any(
        d.state == "computing" for d in w.drivers.values()))
    report = explore(TINY, invariants=[inv], max_states=500, max_depth=10,
                     max_seconds=10.0)
    assert report.violations and report.violations[0].invariant == "no-compute"
    trace = report.violations[0].trace
    assert replay(TINY, trace, invariants=[inv]).invariant == "no-compute"


# ---------------------------------------------------------------------------
# gateway micro-world: cross-gateway routing + op-log failover
# ---------------------------------------------------------------------------

GW = GatewayMCConfig(policy="sync", n_volunteers=2, n_versions=1, n_mb=2,
                     visibility_timeout=10.0, n_gateways=2)


def test_gateway_world_roundtrips_through_config_json():
    cfg = GatewayMCConfig.from_json(GW.to_json())
    assert isinstance(cfg, GatewayMCConfig) and cfg == GW
    # the base from_json dispatches on the "world" tag, so a payload's
    # config rehydrates to the right world type without the caller knowing
    assert isinstance(MCConfig.from_json(GW.to_json()), GatewayMCConfig)


def test_gateway_world_explores_clean_and_actually_forwards():
    world = GW.make_world()
    report = explore(GW, max_states=4000, max_depth=30, max_seconds=20.0,
                     world=world)
    assert report.ok, report.violations
    assert report.stats.completes > 0
    # in a 2-gateway ring the model + queue slices land on gw0, so the
    # gw1-homed volunteer's ops must cross gateways: the run is only a
    # multi-gateway test if Forward traffic really happened
    assert world.gw_forwards > 0
    assert {"Forward", "ForwardNotify"} <= world.sent_types


def test_gateway_crash_opens_window_then_peer_adopts_slice():
    cfg = GatewayMCConfig.from_json(
        {**GW.to_json(), "gw_crashable": [0], "max_gw_crashes": 1})
    world = cfg.make_world()
    world.apply(("lease", "w1"))        # forwarded: w1 is homed on gw1
    assert world.gw_forwards == 1
    world.apply(("gw_crash", 0))
    # failover window: every volunteer/protocol move is held until a peer
    # adopts the dead slice — the only enabled actions are adoption (and
    # note fates, none pending here)
    assert world.enabled_actions() == [("gw_adopt", 0)]
    world.apply(("gw_adopt", 0))
    assert world.ring.owner_of("__model__") == 1
    assert world.gw_owned[1] == [0, 1] and world.gw_owned[0] == []
    # the cluster serves again: volunteer moves re-enable
    assert any(a[0] in ("lease", "advance") for a in world.enabled_actions())


def test_gateway_capture_restore_roundtrips_mid_window():
    cfg = GatewayMCConfig.from_json(
        {**GW.to_json(), "gw_crashable": [0], "max_gw_crashes": 1})
    world = cfg.make_world()
    world.apply(("lease", "w1"))
    world.apply(("gw_crash", 0))        # capture INSIDE the failover window
    cap = world.capture()
    fp = fingerprint(world)
    world.apply(("gw_adopt", 0))
    assert fingerprint(world) != fp
    world.restore(cap)
    assert fingerprint(world) == fp
    assert world.gw_window == [0]
    world.apply(("gw_adopt", 0))        # the restored window still resolves


def test_single_owner_invariant_rejects_a_doubly_served_slice():
    from repro.analysis.mc.gateway_world import single_owner_per_slice
    world = GW.make_world()
    assert single_owner_per_slice(world) is None
    world.gw_owned[1] = [0, 1]          # gw1 claims gw0's slice too
    msg = single_owner_per_slice(world)
    assert msg is not None and "served by 2 gateways" in msg


def test_gateway_fsync_drop_rediscovered_and_fsync_is_clean():
    fx = _fixture("gateway_fsync_drop")
    cfg = fx.configure()
    report = explore(cfg, **fx.BUDGET)
    assert [v.invariant for v in report.violations] == ["no-lost-forward"]
    assert "never made durable" in report.violations[0].message
    # the shipped behavior — fsync before acknowledging — explores clean
    # under the same bounded budget
    fixed = MCConfig.from_json({**cfg.to_json(), "oplog_fsync": True})
    assert isinstance(fixed, GatewayMCConfig)
    ok = explore(fixed, max_states=4000, max_depth=12, max_seconds=20.0)
    assert ok.violations == []


def test_gateway_rules_are_registered_for_ci():
    from repro.analysis.mc import RULES
    from repro.analysis.mc.check import _RULE_BY_INVARIANT
    assert {"MC-OWNER", "MC-FORWARD"} <= set(RULES)
    assert _RULE_BY_INVARIANT["single-owner-per-slice"] == "MC-OWNER"
    assert _RULE_BY_INVARIANT["no-lost-forward"] == "MC-FORWARD"


# ---------------------------------------------------------------------------
# wire coverage: COVERED_MESSAGES is honest
# ---------------------------------------------------------------------------

def test_covered_messages_ledger_is_honest():
    """Every wire type COVERED_MESSAGES claims the checker exercises must
    actually be sent during exploration of the shipped worlds (plus a
    server-apply world — SubmitUpdate's rung)."""
    from repro.analysis.mc import default_config
    sent = set()
    worlds = [default_config("sync"), default_config("staleness:1"),
              default_config("local:2"),
              # fault-free sync world: the DFS dives straight down the happy
              # path, reaching the version-wait park (WatchVersion) and the
              # commit notification (VersionReady) within a small budget
              MCConfig(policy="sync", n_volunteers=2, n_versions=2, n_mb=1,
                       visibility_timeout=10.0),
              MCConfig(policy="staleness:1", n_volunteers=2, n_versions=2,
                       n_mb=2, visibility_timeout=10.0, server_apply=True,
                       gc_keep=2),
              # fault-free 2-gateway world: the gw1-homed volunteer's ops
              # cross gateways (Forward / ForwardNotify) and lease expiry
              # goes over the wire as ExpireAll
              GW]
    for cfg in worlds:
        world = cfg.make_world()
        explore(cfg, max_states=1500, max_depth=40, max_seconds=15.0,
                first_violation=False, world=world)
        sent |= world.sent_types
    missing = set(COVERED_MESSAGES) - sent
    assert not missing, f"claimed covered but never sent: {sorted(missing)}"


def test_schema_mc_coverage_cross_check_is_clean_on_tree():
    from repro.analysis.schema import check_mc_coverage
    assert check_mc_coverage() == []
