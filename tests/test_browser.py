"""The browser-tier thin client (core/browser) against a live gateway."""
import pytest

from repro.core import browser as browser_mod
from repro.core.browser import BrowserClient
from repro.core.gateway import GatewayServer
from repro.core.simulator import SyntheticProblem

N_VERSIONS, N_MB = 3, 4
POLICY = "staleness:2"


def _problem():
    return SyntheticProblem(n_versions=N_VERSIONS, n_mb=N_MB)


@pytest.fixture
def server():
    s = GatewayServer(_problem(), n_versions=N_VERSIONS, policy=POLICY)
    s.start()
    yield s
    s.close()


def test_browser_client_refuses_barrier_policy():
    # refused at construction, BEFORE any connection attempt: port 1 is
    # never dialed
    with pytest.raises(ValueError, match="barrierless"):
        BrowserClient("127.0.0.1", 1, "b0", policy="sync")


def test_browser_client_completes_a_run_with_zero_model_pushes(server):
    client = BrowserClient("127.0.0.1", server.port, "b0", policy=POLICY)
    final, tasks = client.run(server.n_updates)
    sent = dict(client.transport.sent)
    client.close()
    assert final == server.n_updates == N_VERSIONS * N_MB
    assert tasks == server.n_updates
    assert client.transport.dialect == "ws"
    assert sent.get("SubmitUpdate") == tasks
    assert "PublishModel" not in sent          # thin: gradients up, never models
    assert server.done.is_set()


def test_browser_client_enforces_thin_contract_at_runtime(server,
                                                          monkeypatch):
    """If the volunteer loop ever sent a PublishModel, run() must raise —
    the contract is checked against the wire histogram, not assumed."""
    client = BrowserClient("127.0.0.1", server.port, "b1", policy=POLICY)

    def fat_volunteer(transport, vid, n_updates, **kw):
        transport.sent["PublishModel"] = 1     # simulate a fat client bug
        return 0, 0

    monkeypatch.setattr(browser_mod, "run_volunteer", fat_volunteer)
    with pytest.raises(RuntimeError, match="thin-client contract"):
        client.run(server.n_updates)
    client.close()
