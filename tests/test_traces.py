"""Session traces (core/traces) + the simulator's O(log N) fleet counting."""
import pytest

from repro.core.simulator import (CostModel, Simulator, SyntheticProblem,
                                  VolunteerSpec)
from repro.core.traces import (DEVICE_MIX, TraceParams, generate_sessions,
                               trace_stats)

# small but statistically meaningful fleet; a compressed 1-hour "day"
PARAMS = TraceParams(n_devices=400, horizon=4 * 3600.0, day=3600.0,
                     session_median=120.0, seed=3)


@pytest.fixture(scope="module")
def specs():
    return generate_sessions(PARAMS)


def test_trace_is_deterministic(specs):
    again = generate_sessions(PARAMS)
    assert specs == again
    # and genuinely sensitive to the seed
    other = generate_sessions(
        TraceParams(**{**PARAMS.__dict__, "seed": 4}))
    assert specs != other


def test_sessions_are_valid_intervals(specs):
    assert specs, "empty trace"
    for s in specs:
        assert 0.0 <= s.join_time < s.leave_time <= PARAMS.horizon
    joins = [s.join_time for s in specs]
    assert joins == sorted(joins)
    assert len({s.vid for s in specs}) == len(specs)   # vids unique


def test_duty_cycle_matches_online_frac(specs):
    stats = trace_stats(specs, PARAMS)
    target = PARAMS.online_frac
    # Jensen's inequality on the diurnal gap division costs a few percent;
    # ±20 % still cleanly separates 6.5 h/day from e.g. always-on or 1 h/day
    assert 0.8 * target < stats.duty_cycle < 1.2 * target, stats.duty_cycle


def test_session_lengths_are_heavy_tailed(specs):
    stats = trace_stats(specs, PARAMS)
    # lognormal with sigma 1.2: p95 is ~7x the median; anything light-tailed
    # (exponential ~ 4.3x, uniform ~ 1.9x) fails this
    assert stats.p95_session / stats.median_session > 3.0


def test_warm_start_opens_in_steady_state(specs):
    online_at_zero = sum(1 for s in specs if s.join_time == 0.0)
    # ~online_frac of the fleet should already be mid-session at t=0
    assert online_at_zero > 0.5 * PARAMS.online_frac * PARAMS.n_devices


def test_diurnal_amplitude_shapes_arrivals(specs):
    tide = trace_stats(specs, PARAMS)
    flat_params = TraceParams(**{**PARAMS.__dict__, "diurnal_amplitude": 0.0})
    flat = trace_stats(generate_sessions(flat_params), flat_params)
    assert tide.peak_to_trough > 1.5           # arrivals bunch into "evening"
    assert tide.peak_to_trough > 1.3 * flat.peak_to_trough


def test_device_mixture_fractions(specs):
    stats = trace_stats(specs, PARAMS)
    total = sum(stats.speed_counts.values())
    for cls in DEVICE_MIX:
        frac = stats.speed_counts.get(cls.speed, 0) / total
        # session counts track device weights (sessions per device is
        # speed-independent); generous tolerance for 400 devices
        assert abs(frac - cls.weight) < 0.12, (cls.name, frac)


@pytest.mark.parametrize("bad", [
    {"n_devices": 0},
    {"online_frac": 0.0},
    {"online_frac": 1.0},
    {"diurnal_amplitude": 1.0},
    {"diurnal_amplitude": -0.1},
])
def test_invalid_params_rejected(bad):
    with pytest.raises(ValueError):
        generate_sessions(TraceParams(**{**PARAMS.__dict__, **bad}))


# ---------------------------------------------------------------------------
# the simulator's bisect-based active-fleet counting
# ---------------------------------------------------------------------------

def _linear_active(specs, now):
    return sum(1 for s in specs if s.join_time <= now < s.leave_time)


def test_active_count_matches_linear_scan(specs):
    sim = Simulator(SyntheticProblem(n_versions=1, n_mb=1), specs,
                    cost=CostModel(), mode="event")
    probes = [0.0, 1.0, PARAMS.horizon / 3, PARAMS.horizon - 1.0,
              PARAMS.horizon, PARAMS.horizon + 100.0]
    probes += [s.join_time for s in specs[::37]]       # boundary-exact probes
    probes += [s.leave_time for s in specs[::41]]
    for now in probes:
        assert sim._active_count(now) == _linear_active(specs, now), now


def test_active_count_handles_degenerate_intervals():
    """A spec whose leave precedes its join (can arise from chaos editing
    leave_time mid-run) must count as never-active, not negative."""
    specs = [VolunteerSpec("ok", join_time=0.0, leave_time=10.0),
             VolunteerSpec("gone", join_time=5.0, leave_time=2.0)]
    sim = Simulator(SyntheticProblem(n_versions=1, n_mb=1), specs,
                    cost=CostModel(), mode="event")
    for now, want in ((0.0, 1), (3.0, 1), (6.0, 1), (20.0, 0)):
        assert sim._active_count(now) == want, now


def test_active_count_cache_invalidated_on_spec_mutation(specs):
    sim = Simulator(SyntheticProblem(n_versions=1, n_mb=1), list(specs),
                    cost=CostModel(), mode="event")
    now = PARAMS.horizon / 2
    before = sim._active_count(now)
    extra = VolunteerSpec("late", join_time=now - 1.0,
                          leave_time=PARAMS.horizon)
    sim.specs[extra.vid] = extra
    sim._active_cache = None                   # what chaos does on mutation
    assert sim._active_count(now) == before + 1
