"""Paper Table 4's headline result as exact tests: the trained model is
bit-identical for ANY number of volunteers, ANY churn pattern, ANY transport
(direct in-process calls or every protocol message round-tripped through
canonical bytes), and for the simulator's execution order — because the
reduce rebuilds the same batch-128 update the sequential algorithm applies.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs.paper_lstm import TrainParams
from repro.core.coordinator import Coordinator
from repro.core.mapreduce import TrainingProblem, sequential_accumulated
from repro.core.simulator import Simulator, VolunteerSpec
from repro.data.text import synthetic_corpus

TP = TrainParams(batch_size=16, examples_per_epoch=64, num_epochs=1,
                 sample_len=20, mini_batch_size=4,
                 mini_batches_to_accumulate=4)


@pytest.fixture(scope="module")
def problem():
    return TrainingProblem.paper_problem(corpus=synthetic_corpus(6000), tp=TP)


@pytest.fixture(scope="module")
def sequential(problem):
    return sequential_accumulated(problem)


def _bitmatch(a, b) -> bool:
    return all(bool((np.asarray(x) == np.asarray(y)).all())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.mark.parametrize("transport", ["inproc", "wire"])
@pytest.mark.parametrize("k", [1, 2, 3, 5, 8])
def test_worker_count_invariance(problem, sequential, k, transport):
    res = Coordinator(problem, n_workers=k, transport=transport).run()
    assert res.final_version == problem.n_versions
    assert _bitmatch(res.params, sequential[0])


@pytest.mark.parametrize("transport", ["inproc", "wire"])
def test_churn_invariance(problem, sequential, transport):
    # volunteers leave mid-run (their leased tasks requeue) and others join —
    # the paper's classroom scenario 3
    churn = [(5, "leave", "w0"), (9, "leave", "w1"), (12, "join", "w9"),
             (20, "join", "w10")]
    res = Coordinator(problem, n_workers=4, churn=churn,
                      transport=transport).run()
    assert _bitmatch(res.params, sequential[0])
    assert res.requeues >= 0


def test_visibility_timeout_recovers_frozen_worker(problem, sequential):
    # w0 leaves while holding tasks and never acks; the timeout requeues them
    churn = [(3, "leave", "w0")]
    res = Coordinator(problem, n_workers=2, churn=churn,
                      visibility_timeout=10.0).run()
    assert _bitmatch(res.params, sequential[0])


def test_simulator_completes_protocol(problem):
    # the simulator is timing-only (no real grads) but drives the identical
    # queue/dataserver protocol: all versions must commit, exactly once
    specs = [VolunteerSpec(f"v{i}", speed=1.0 + 0.3 * i) for i in range(3)]
    sim = Simulator(problem, specs)
    res = sim.run()
    assert res.final_version == problem.n_versions
    n_maps = problem.n_versions * TP.mini_batches_to_accumulate
    assert sum(res.tasks_by_worker.values()) == n_maps + problem.n_versions


def test_simulator_survives_churn(problem):
    import math
    specs = [VolunteerSpec("v0", leave_time=20.0),
             VolunteerSpec("v1"),
             VolunteerSpec("v2", join_time=10.0)]
    res = Simulator(problem, specs, visibility_timeout=30.0).run()
    assert res.final_version == problem.n_versions
    assert math.isfinite(res.makespan)


def test_losses_match_sequential(problem, sequential):
    res = Coordinator(problem, n_workers=3).run()
    np.testing.assert_allclose(res.losses, sequential[2], rtol=1e-6)
