"""Per-architecture smoke tests (assignment requirement) + model math checks.

Every assigned architecture instantiates its REDUCED variant (<=2 layers,
d_model<=512, <=4 experts), runs one forward/train step on CPU, and asserts
output shapes + finite values. Decode equivalence checks prefill+decode
against the full-sequence forward.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import model as M
from repro.models import moe as MoE
from repro.models import ssm as SSM
from repro.models.runtime import Runtime
from repro.optim import make as make_opt

RT = Runtime(remat=False)


def _batch(cfg, B, S, key=0):
    rng = np.random.RandomState(key)
    out = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab, size=(B, S + 1)), jnp.int32)}
    if cfg.family == "encdec":
        out["frames"] = jnp.asarray(
            rng.randn(B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        out["patches"] = jnp.asarray(
            rng.randn(B, cfg.vision_prefix, cfg.d_model), jnp.dtype(cfg.dtype))
    return out


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = C.get_smoke(arch)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    assert cfg.moe.num_experts <= 4
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    batch = _batch(cfg, B, S)

    loss, mets = M.loss_fn(params, cfg, RT, batch)
    assert jnp.isfinite(loss), arch

    # one full optimizer step
    opt = make_opt("adamw", 1e-3)
    state = opt.init(params)
    g = jax.grad(lambda p: M.loss_fn(p, cfg, RT, batch)[0])(params)
    new_params, _ = opt.update(params, state, g)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)):
        assert a.shape == b.shape
        assert bool(jnp.all(jnp.isfinite(b.astype(jnp.float32))))
    loss2, _ = M.loss_fn(new_params, cfg, RT, batch)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_smoke_decode_shapes(arch):
    cfg = C.get_smoke(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S, MAXS = 2, 12, 24
    cache = M.init_cache(cfg, B, MAXS)
    batch = dict(_batch(cfg, B, S - 1))
    batch["tokens"] = batch["tokens"][:, :S]
    if cfg.family == "vlm":
        batch["tokens"] = batch["tokens"][:, :S - cfg.vision_prefix]
    logits, cache = M.prefill(params, cfg, RT, batch, cache)
    assert logits.shape == (B, cfg.vocab)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = M.decode_step(params, cfg, RT, tok, cache, jnp.int32(S))
    assert logits2.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "falcon-mamba-7b",
                                  "jamba-v0.1-52b", "deepseek-moe-16b"])
def test_decode_matches_forward(arch):
    """prefill(S) then decode(1) must equal forward(S+1) last-token logits."""
    cfg = C.get_smoke(arch).replace(dtype="float32")
    rt = Runtime(remat=False, moe_impl="dense")  # dense moe: no cap-dropping
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 10
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (B, S + 1)), jnp.int32)

    logits_full, _ = M.forward(params, cfg, rt, {"tokens": toks})

    cache = M.init_cache(cfg, B, S + 4, dtype=jnp.float32)
    logits_pre, cache = M.prefill(params, cfg, rt, {"tokens": toks[:, :S]},
                                  cache)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_full[:, S - 1]),
                               rtol=2e-4, atol=2e-4)
    logits_dec, _ = M.decode_step(params, cfg, rt, toks[:, S], cache,
                                  jnp.int32(S))
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, S]),
                               rtol=2e-4, atol=2e-4)


def test_moe_sort_matches_dense_when_capacity_ample():
    cfg = C.get_smoke("deepseek-moe-16b").replace(dtype="float32")
    p = MoE.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    out_d, aux_d = MoE.apply_moe_dense(p, x, cfg)
    # capacity high enough that nothing drops -> must match the oracle
    out_s, aux_s = MoE.apply_moe_sort(p, x, cfg, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_d),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(aux_s), float(aux_d), rtol=1e-5)


def test_moe_capacity_drops_tokens():
    cfg = C.get_smoke("arctic-480b").replace(dtype="float32")
    p = MoE.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    out_tight, _ = MoE.apply_moe_sort(p, x, cfg, capacity_factor=0.25)
    out_ample, _ = MoE.apply_moe_sort(p, x, cfg, capacity_factor=8.0)
    # dropping must change some outputs (and zero some rows' contribution)
    assert not np.allclose(np.asarray(out_tight), np.asarray(out_ample))


def test_ssm_chunked_scan_matches_step_recurrence():
    """The chunked associative scan must equal the naive per-step recurrence."""
    cfg = C.get_smoke("falcon-mamba-7b").replace(dtype="float32")
    p = SSM.init_ssm(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 23          # not a multiple of the chunk
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.3
    rt = Runtime(remat=False, ssm_chunk=8)
    y_chunked, _ = SSM.apply_ssm(p, x, cfg, rt)

    state = SSM.init_ssm_state(cfg, B, jnp.float32)
    ys = []
    for t in range(S):
        y_t, state = SSM.apply_ssm_step(p, x[:, t:t + 1], cfg, state)
        ys.append(y_t)
    y_steps = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_steps),
                               rtol=2e-4, atol=2e-4)


def test_ssm_prefill_state_continues_decode():
    cfg = C.get_smoke("falcon-mamba-7b").replace(dtype="float32")
    p = SSM.init_ssm(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 1, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S + 1, cfg.d_model)) * 0.3
    rt = Runtime(remat=False, ssm_chunk=4)
    y_full, _ = SSM.apply_ssm(p, x, cfg, rt)

    st = SSM.init_ssm_state(cfg, B, jnp.float32)
    y_pre, st = SSM.apply_ssm(p, x[:, :S], cfg, rt, state=st)
    y_dec, _ = SSM.apply_ssm_step(p, x[:, S:], cfg, st)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full[:, S:]),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_matches_plain():
    from repro.models import layers as L
    B, S, H, Kv, hd = 2, 37, 4, 2, 16
    k0 = jax.random.PRNGKey(0)
    q = jax.random.normal(k0, (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Kv, hd))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    kpos = jnp.arange(S)
    plain = L.plain_attention(q, k, v, pos, kpos, causal=True)
    flash = L.flash_attention(q, k, v, pos, kpos, True, 0, 16)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(plain),
                               rtol=2e-4, atol=2e-5)
    # sliding window
    plain_w = L.plain_attention(q, k, v, pos, kpos, causal=True, window=9)
    flash_w = L.flash_attention(q, k, v, pos, kpos, True, 9, 16)
    np.testing.assert_allclose(np.asarray(flash_w), np.asarray(plain_w),
                               rtol=2e-4, atol=2e-5)


def test_flash_attention_grads_match_plain():
    from repro.models import layers as L
    B, S, H, Kv, hd = 1, 19, 2, 1, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Kv, hd))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    kpos = jnp.arange(S)

    def f_plain(q, k, v):
        return jnp.sum(L.plain_attention(q, k, v, pos, kpos, causal=True) ** 2)

    def f_flash(q, k, v):
        return jnp.sum(L.flash_attention(q, k, v, pos, kpos, True, 0, 8) ** 2)

    gp = jax.grad(f_plain, argnums=(0, 1, 2))(q, k, v)
    gf = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gp, gf):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=5e-4, atol=5e-5)


def test_param_counts_match_published_scale():
    """Full configs should land near their nameplate parameter counts."""
    expect = {"stablelm-1.6b": (1.4e9, 1.9e9),
              "minitron-4b": (3.5e9, 5.0e9),
              "falcon-mamba-7b": (6.5e9, 8.5e9),
              "qwen1.5-110b": (95e9, 125e9),
              "nemotron-4-340b": (300e9, 380e9),
              "deepseek-moe-16b": (14e9, 20e9),
              "internvl2-1b": (0.4e9, 1.2e9)}
    for arch, (lo, hi) in expect.items():
        n = C.get(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:,}"


def test_vocab_padding_is_semantics_preserving():
    """Padded logits are masked: loss identical to the published vocab."""
    cfg = C.get_smoke("internvl2-1b").replace(dtype="float32")
    cfgp = cfg.replace(vocab_pad_to=64)
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    pp = M.init_params(cfgp, jax.random.PRNGKey(0))
    pp["embed"] = pp["embed"].at[:cfg.vocab].set(p["embed"])
    pp["unembed"] = pp["unembed"].at[:, :cfg.vocab].set(p["unembed"])
    for k in ("blocks", "final_norm"):
        pp[k] = p[k]
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (2, 13)),
                                   jnp.int32),
             "patches": jnp.asarray(rng.randn(2, cfg.vision_prefix,
                                              cfg.d_model), jnp.float32)}
    l1, _ = M.loss_fn(p, cfg, RT, batch)
    l2, _ = M.loss_fn(pp, cfgp, RT, batch)
    assert abs(float(l1) - float(l2)) < 1e-5


def test_encdec_decode_matches_forward():
    """whisper: prefill (with cross-kv projection) + decode == full forward."""
    cfg = C.get_smoke("whisper-base").replace(dtype="float32")
    rt = Runtime(remat=False)
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    B, S = 2, 9
    rng = np.random.RandomState(1)
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (B, S + 1)), jnp.int32)
    frames = jnp.asarray(rng.randn(B, cfg.encoder_seq, cfg.d_model),
                         jnp.float32)

    logits_full, _ = M.forward(params, cfg, rt,
                               {"tokens": toks, "frames": frames})
    cache = M.init_cache(cfg, B, S + 4, dtype=jnp.float32)
    logits_pre, cache = M.prefill(
        params, cfg, rt, {"tokens": toks[:, :S], "frames": frames}, cache)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_full[:, S - 1]),
                               rtol=2e-4, atol=2e-4)
    logits_dec, _ = M.decode_step(params, cfg, rt, toks[:, S], cache,
                                  jnp.int32(S))
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, S]),
                               rtol=2e-4, atol=2e-4)


def test_vlm_decode_matches_forward():
    """internvl2: patch-prefix prefill + decode == full forward."""
    cfg = C.get_smoke("internvl2-1b").replace(dtype="float32")
    rt = Runtime(remat=False)
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    B, St = 2, 7
    P = cfg.vision_prefix
    rng = np.random.RandomState(2)
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (B, St + 1)), jnp.int32)
    patches = jnp.asarray(rng.randn(B, P, cfg.d_model), jnp.float32)

    logits_full, _ = M.forward(params, cfg, rt,
                               {"tokens": toks, "patches": patches})
    cache = M.init_cache(cfg, B, P + St + 4, dtype=jnp.float32)
    logits_pre, cache = M.prefill(
        params, cfg, rt, {"tokens": toks[:, :St], "patches": patches}, cache)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_full[:, St - 1]),
                               rtol=2e-4, atol=2e-4)
    # decode position is absolute: prefix + text length
    logits_dec, _ = M.decode_step(params, cfg, rt, toks[:, St], cache,
                                  jnp.int32(P + St))
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, St]),
                               rtol=2e-4, atol=2e-4)
