"""Byte-for-byte tests of the sans-IO RFC 6455 framer (core/wsframing)."""
import pytest

from repro.core import wsframing as wf

# deterministic mask for byte-exact assertions
MASK = bytes([0x37, 0xFA, 0x21, 0x3D])


def masked_client(payload_mask: bytes = MASK) -> wf.Framer:
    return wf.client_framer(mask_source=lambda n: payload_mask)


# ---------------------------------------------------------------------------
# handshake
# ---------------------------------------------------------------------------

def test_accept_key_rfc_vector():
    # the worked example in RFC 6455 section 1.3
    assert wf.accept_key("dGhlIHNhbXBsZSBub25jZQ==") == \
        "s3pPLMBiTxaQ9kYGzzhZRbK+xOo="


def upgrade_request(key: str = "dGhlIHNhbXBsZSBub25jZQ==") -> bytes:
    return (f"GET /train HTTP/1.1\r\n"
            f"Host: localhost\r\n"
            f"Upgrade: websocket\r\n"
            f"Connection: keep-alive, Upgrade\r\n"
            f"Sec-WebSocket-Key: {key}\r\n"
            f"Sec-WebSocket-Version: 13\r\n\r\n").encode()


def test_server_handshake_accepts_and_computes_key():
    hs = wf.ServerHandshake()
    resp = hs.feed(upgrade_request())
    assert resp is not None
    assert b"101 Switching Protocols" in resp
    assert b"Sec-WebSocket-Accept: s3pPLMBiTxaQ9kYGzzhZRbK+xOo=\r\n" in resp
    assert hs.path == "/train"
    assert hs.leftover == b""


def test_server_handshake_incremental_with_leftover():
    data = upgrade_request() + b"\x82\x00"      # frame bytes glued on
    hs = wf.ServerHandshake()
    assert hs.feed(data[:40]) is None           # mid-header: incomplete
    assert hs.feed(data[40:100]) is None
    resp = hs.feed(data[100:])                  # rest + glued frame bytes
    assert resp is not None and b"101" in resp
    assert hs.leftover == b"\x82\x00"


@pytest.mark.parametrize("mutate", [
    lambda r: r.replace(b"GET", b"POST"),
    lambda r: r.replace(b"Upgrade: websocket\r\n", b""),
    lambda r: r.replace(b"Sec-WebSocket-Key", b"X-Key"),
    lambda r: r.replace(b"Version: 13", b"Version: 8"),
    lambda r: r.replace(b"Connection: keep-alive, Upgrade\r\n",
                        b"Connection: close\r\n"),
])
def test_server_handshake_rejects_bad_upgrades(mutate):
    with pytest.raises(wf.WsProtocolError):
        wf.ServerHandshake().feed(mutate(upgrade_request()))


def test_server_handshake_header_block_cap():
    hs = wf.ServerHandshake()
    with pytest.raises(wf.WsProtocolError) as ei:
        hs.feed(b"GET / HTTP/1.1\r\nX: " + b"a" * 10_000)
    assert ei.value.code == wf.CLOSE_TOO_BIG


def test_client_handshake_round_trip():
    request, key = wf.client_handshake_request("localhost:1234", "/x")
    assert request.startswith(b"GET /x HTTP/1.1\r\n")
    hs = wf.ServerHandshake()
    resp = hs.feed(request)
    ch = wf.ClientHandshake(key)
    assert ch.feed(resp + b"\x89\x00")          # a ping glued to the 101
    assert ch.done and ch.leftover == b"\x89\x00"


def test_client_handshake_rejects_wrong_accept():
    _, key = wf.client_handshake_request("h", key="dGhlIHNhbXBsZSBub25jZQ==")
    bad = (b"HTTP/1.1 101 Switching Protocols\r\n"
           b"Sec-WebSocket-Accept: bogus\r\n\r\n")
    with pytest.raises(wf.WsProtocolError):
        wf.ClientHandshake(key).feed(bad)
    with pytest.raises(wf.WsProtocolError):
        wf.ClientHandshake(key).feed(b"HTTP/1.1 403 Forbidden\r\n\r\n")


def test_preamble_sniff():
    assert wf.is_ws_preamble(b"GET / HTTP/1.1")
    assert wf.is_ws_preamble(b"G")              # one byte disambiguates
    assert not wf.is_ws_preamble(b"")
    assert not wf.is_ws_preamble(b"\x00\x00\x01\x00")
    # a native length prefix below MAX_FRAME can never start with 'G'
    assert (wf.MAX_FRAME).to_bytes(4, "big")[0] < ord("G")


# ---------------------------------------------------------------------------
# framing: byte-exact vectors
# ---------------------------------------------------------------------------

def test_rfc_masked_hello_example():
    # RFC 6455 section 5.7: a masked single-frame text "Hello" from client
    frame = bytes([0x81, 0x85, 0x37, 0xFA, 0x21, 0x3D,
                   0x7F, 0x9F, 0x4D, 0x51, 0x58])
    assert wf.server_framer().feed(frame) == [wf.Message(b"Hello")]


def test_rfc_unmasked_hello_example():
    # section 5.7: the unmasked server variant
    frame = bytes([0x81, 0x05]) + b"Hello"
    assert wf.client_framer().feed(frame) == [wf.Message(b"Hello")]


def test_client_send_bytes_are_exact():
    frame = masked_client().send_message(b"Hello")
    want = bytes([0x82, 0x85]) + MASK + bytes(
        b ^ MASK[i % 4] for i, b in enumerate(b"Hello"))
    assert frame == want
    assert wf.server_framer().feed(frame) == [wf.Message(b"Hello")]


def test_server_send_is_unmasked():
    frame = wf.server_framer().send_message(b"Hi")
    assert frame == bytes([0x82, 0x02]) + b"Hi"


@pytest.mark.parametrize("n", [0, 125, 126, 127, 65_535, 65_536, 100_000])
def test_length_encodings_round_trip(n):
    payload = bytes(i % 251 for i in range(n))
    for tx, rx in ((masked_client(), wf.server_framer()),
                   (wf.server_framer(), wf.client_framer())):
        assert rx.feed(tx.send_message(payload)) == [wf.Message(payload)]


def test_mask_direction_enforced_both_ways():
    unmasked = wf.server_framer().send_message(b"x")    # no mask bit
    with pytest.raises(wf.WsProtocolError):
        wf.server_framer().feed(unmasked)               # client must mask
    masked = masked_client().send_message(b"x")
    with pytest.raises(wf.WsProtocolError):
        wf.client_framer().feed(masked)                 # server must not


def test_rsv_bits_rejected():
    with pytest.raises(wf.WsProtocolError):
        wf.client_framer().feed(bytes([0xC2, 0x01, 0x40]))


def test_unknown_opcode_rejected():
    with pytest.raises(wf.WsProtocolError):
        wf.client_framer().feed(bytes([0x83, 0x00]))


# ---------------------------------------------------------------------------
# fragmentation
# ---------------------------------------------------------------------------

def test_fragmentation_reassembles():
    payload = bytes(range(256)) * 5
    frame = masked_client().send_message(payload, fragment_size=100)
    assert wf.server_framer().feed(frame) == [wf.Message(payload)]


def test_fragments_interleaved_with_ping():
    cf = masked_client()
    sf = wf.server_framer()
    frags = cf.send_message(b"abcdef", fragment_size=2)
    # each masked 2-byte fragment is 8 wire bytes (2 header + 4 mask + 2);
    # interleave a control frame between fragments (RFC 5.4 allows it)
    events = []
    events += sf.feed(frags[:8])                # first fragment exactly
    events += sf.feed(cf.ping(b"hb"))
    events += sf.feed(frags[8:])
    assert events == [wf.Ping(b"hb"), wf.Message(b"abcdef")]


def test_continuation_without_start_rejected():
    frame = masked_client()._frame(wf.OP_CONT, b"x", fin=True)
    with pytest.raises(wf.WsProtocolError):
        wf.server_framer().feed(frame)


def test_new_data_frame_mid_fragment_rejected():
    cf = masked_client()
    sf = wf.server_framer()
    sf.feed(cf._frame(wf.OP_BINARY, b"a", fin=False))
    with pytest.raises(wf.WsProtocolError):
        sf.feed(cf._frame(wf.OP_BINARY, b"b", fin=True))


def test_fragmented_control_frame_rejected():
    frame = masked_client()._frame(wf.OP_PING, b"x", fin=False)
    with pytest.raises(wf.WsProtocolError):
        wf.server_framer().feed(frame)


def test_oversize_control_frame_rejected():
    frame = masked_client()._frame(wf.OP_PING, b"x" * 126)
    with pytest.raises(wf.WsProtocolError):
        wf.server_framer().feed(frame)


# ---------------------------------------------------------------------------
# torn delivery: resync at every split point
# ---------------------------------------------------------------------------

def test_byte_by_byte_feed_equals_one_shot():
    cf = masked_client()
    stream = (cf.send_message(b"first") + cf.ping(b"p")
              + cf.send_message(bytes(range(200)), fragment_size=64)
              + cf.close(wf.CLOSE_NORMAL, b"done"))
    one_shot = wf.server_framer().feed(stream)
    dribble = wf.server_framer()
    events = []
    for i in range(len(stream)):
        events.extend(dribble.feed(stream[i:i + 1]))
    assert events == one_shot
    assert events == [wf.Message(b"first"), wf.Ping(b"p"),
                      wf.Message(bytes(range(200))),
                      wf.Closed(wf.CLOSE_NORMAL, b"done")]


def test_mid_frame_flag_tracks_partial_input():
    sf = wf.server_framer()
    frame = masked_client().send_message(b"hello world")
    assert not sf.mid_frame
    sf.feed(frame[:5])
    assert sf.mid_frame                          # header consumed, body not
    sf.feed(frame[5:])
    assert not sf.mid_frame
    # a pending fragmented message also counts as mid-frame
    sf.feed(masked_client()._frame(wf.OP_BINARY, b"a", fin=False))
    assert sf.mid_frame


# ---------------------------------------------------------------------------
# size caps: refused before allocation
# ---------------------------------------------------------------------------

def test_oversize_frame_rejected_with_1009():
    sf = wf.Framer(masking=False, require_masked=True, max_frame=64)
    cf = wf.Framer(masking=True, require_masked=False, max_frame=1 << 40,
                   mask_source=lambda n: MASK)
    with pytest.raises(wf.WsProtocolError) as ei:
        sf.feed(cf.send_message(b"x" * 65))
    assert ei.value.code == wf.CLOSE_TOO_BIG


def test_oversize_header_rejected_without_payload():
    # only the 10-byte header of a "1 TB" frame arrives: the length field
    # alone must kill it (no waiting for, or buffering of, the payload)
    sf = wf.server_framer()
    header = bytes([0x82, 0x80 | 127]) + (1 << 40).to_bytes(8, "big") + MASK
    with pytest.raises(wf.WsProtocolError) as ei:
        sf.feed(header)
    assert ei.value.code == wf.CLOSE_TOO_BIG


def test_fragment_total_capped():
    sf = wf.Framer(masking=False, require_masked=True, max_frame=100)
    cf = wf.Framer(masking=True, require_masked=False,
                   mask_source=lambda n: MASK)
    sf.feed(cf._frame(wf.OP_BINARY, b"x" * 60, fin=False))
    with pytest.raises(wf.WsProtocolError) as ei:
        sf.feed(cf._frame(wf.OP_CONT, b"x" * 60, fin=True))
    assert ei.value.code == wf.CLOSE_TOO_BIG


def test_send_refuses_oversize_message():
    f = wf.Framer(masking=False, require_masked=True, max_frame=10)
    with pytest.raises(wf.WsProtocolError):
        f.send_message(b"x" * 11)


# ---------------------------------------------------------------------------
# close handshake
# ---------------------------------------------------------------------------

def test_close_frame_parses_code_and_reason():
    frame = masked_client().close(wf.CLOSE_TOO_BIG, b"fat")
    events = wf.server_framer().feed(frame)
    assert events == [wf.Closed(wf.CLOSE_TOO_BIG, b"fat")]


def test_close_without_code():
    events = wf.server_framer().feed(
        masked_client()._frame(wf.OP_CLOSE, b""))
    assert events == [wf.Closed(None, b"")]


def test_one_byte_close_payload_rejected():
    with pytest.raises(wf.WsProtocolError):
        wf.server_framer().feed(masked_client()._frame(wf.OP_CLOSE, b"\x03"))


def test_framer_ignores_input_after_close():
    sf = wf.server_framer()
    cf = masked_client()
    sf.feed(cf.close())
    assert sf.closed
    assert sf.feed(cf.send_message(b"late")) == []
