import os

# Tests run on the real single CPU device (the dry-run is the only place that
# fakes 512 devices). Force deterministic, quiet JAX.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")

# Queue-heavy test modules get every Queue constructed during the test
# checked for structural invariants (tag disjointness, deadline-heap cover,
# publish conservation) at teardown — see Queue.check_invariants.
_QUEUE_INVARIANT_MODULES = ("test_queue", "test_chaos", "test_elastic")


@pytest.fixture(autouse=True)
def _queue_invariants(request, monkeypatch):
    modname = request.module.__name__
    if not any(m in modname for m in _QUEUE_INVARIANT_MODULES):
        yield
        return
    from repro.core.queue import Queue

    created = []
    orig_init = Queue.__init__

    def tracking_init(self, *args, **kwargs):
        orig_init(self, *args, **kwargs)
        created.append(self)

    monkeypatch.setattr(Queue, "__init__", tracking_init)
    yield
    for q in created:
        q.check_invariants()
