import os

# Tests run on the real single CPU device (the dry-run is the only place that
# fakes 512 devices). Force deterministic, quiet JAX.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")
